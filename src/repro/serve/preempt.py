"""Page-growth and preemption executor over the layered core.

Free functions over a :class:`~repro.serve.scheduler.Scheduler`. Victim
choice is a plan-layer decision (:func:`repro.serve.plan.pick_victim`);
page reclamation goes through the memory layer; swap snapshots run
through the program registry. With a data-partitioned pool, reclamation
for a growing slot only considers victims in the *same* data shard —
pages never migrate across shards.
"""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import plan as planlib
from repro.serve.request import RequestState, RequestStatus


def apply_cow(s, forks: list[tuple[int, int, int]]) -> None:
    """Materialise ``MemoryManager.prepare_write`` forks on device (the
    table mirror is already re-pointed)."""
    if not forks:
        return
    src = jnp.asarray([old for _, old, _ in forks], jnp.int32)
    dst = jnp.asarray([new for _, _, new in forks], jnp.int32)
    s._states["layers"] = s.programs.cow(s._states["layers"], src, dst)


def ensure_pages(s, slot: int, n_total: int, rid: int | None = None) -> bool:
    """Make ``slot``'s reservation cover ``n_total`` pages. Under worst-case
    reservations this always holds; reservation-free, extend incrementally
    and reclaim victims' pages (within the slot's data shard) until it can
    be backed."""
    if s.sched.preemption == "off":
        return True  # admission reserved the worst case
    shard = s.mem.shard_of(slot) if s.mem.data_shards > 1 else None
    while not s.mem.extend_to(slot, n_total):
        if not preempt_lru(s, protect=slot, requester_rid=rid, shard=shard):
            return False
    return True


def grow_pages(s, skip: set[int] = frozenset()) -> None:
    """Allocate the page backing the position each decoding slot writes
    this step — preempting first when reservation-free, including the
    growing slot *itself* when everyone else's pages are pinned."""
    for slot, rs in list(s._active.items()):
        if rs.status is not RequestStatus.ACTIVE or slot in skip:
            continue
        need = s.mem.pages_for_len(int(s._pos_host[slot]) + 1)
        if need <= s.mem.held(slot):
            continue
        if not ensure_pages(s, slot, need, rid=rs.rid):
            if can_preempt(s, rs):
                preempt_slot(s, slot)
                continue
            raise RuntimeError(
                f"slot {slot}: cannot back page growth to {need} and the "
                "request is not preemptable (recompute cannot replay "
                "modality extras); use preemption=\"swap\" or a larger "
                "pool for such workloads"
            )
        s.mem.grow(slot, need)


def can_preempt(s, rs: RequestState) -> bool:
    """Swap restores any slot verbatim; recompute replays tokens through
    chunked streaming, which cannot re-feed modality extras."""
    if s.sched.preemption == "swap":
        return True
    return s._stream_capable and not rs.request.extras


def preempt_lru(
    s, protect: int, requester_rid: int | None = None, shard: int | None = None
) -> bool:
    """Reclaim a victim's pages: plan-layer pick (least-recently-(re)admitted
    preemptable ACTIVE slot, else a *younger* PREFILLING streamer — see
    plan.pick_victim). Returns False when none exists."""
    views = [
        planlib.SlotView(
            slot=sl, rid=rs.rid,
            status="active" if rs.status is RequestStatus.ACTIVE
            else "prefilling",
            t_admit=rs.t_admit, preemptable=can_preempt(s, rs),
            shard=s.mem.shard_of(sl) if s._paged else 0,
        )
        for sl, rs in s._active.items()
    ]
    victim = s._plan(
        planlib.pick_victim, views,
        protect=protect, requester_rid=requester_rid, shard=shard,
    )
    if victim is None:
        return False
    preempt_slot(s, victim)
    return True


def preempt_slot(s, slot: int) -> None:
    rs = s._active[slot]
    if rs.status is RequestStatus.PREFILLING:
        # A parked streamer restarts from chunk 0 on resume under either
        # policy; pages it registered in the prefix index survive in the
        # pool's cached list, so the restart re-adopts them.
        rs.chunk_pos = 0
    elif s.sched.preemption == "swap":
        snap = s.programs.swap_out(
            s._states["layers"], s._put(s.mem.pt[slot]),
            jnp.asarray(slot, jnp.int32),
        )
        rs.swap = (jax.tree.map(np.asarray, snap), int(s._pos_host[slot]))
    else:  # recompute
        rs.replay_tokens = np.concatenate(
            [np.asarray(rs.request.prompt, np.int32),
             np.asarray(rs.tokens[:-1], np.int32)]
        )
        rs.chunk_pos = 0
    rs.status = RequestStatus.PREEMPTED
    rs.preemptions += 1
    s.preemptions_total += 1
    s._ev["preempted"].append(rs.rid)
    s._active_mask[slot] = False
    s._tokens[slot, 0] = 0
    del s._active[slot]
    heapq.heappush(s._free_slots, slot)
    s.mem.release(slot)
    s._pos_host[slot] = 0
    s._slot_worst.pop(slot, None)
    rs.slot = None
    s._preempted.append(rs)
