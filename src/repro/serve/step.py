"""Serving step factories: prefill and single-token decode.

``decode``/``long`` shapes lower these (never train_step). Params are bf16
(no masters/optimizer); decode states follow the arch's decode sharding
profile (KV seq over model when kv-heads can't split; recurrent state
matrices over (data, model) for the batch=1 500k cell).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.schema import (
    ParamSpec,
    abstract_params,
    init_params,
    is_spec,
    shard_tree,
    sharding_tree,
)
from repro.sharding.rules import ShardingCtx, pspec_for


def serve_param_specs(cfg: ModelConfig, sctx: ShardingCtx) -> Any:
    """bf16 serving weights (abstract)."""
    schema = lm.model_schema(cfg)
    return abstract_params(schema, sctx, dtype=jnp.bfloat16)


def decode_state_specs(
    cfg: ModelConfig, shape: ShapeConfig, sctx: ShardingCtx
) -> Any:
    schema = lm.decode_state_schema(cfg, shape.global_batch, shape.seq_len)
    return abstract_params(schema, sctx)


def init_decode_state(
    cfg: ModelConfig,
    batch: int,
    s_max: int,
    start_pos: int = 0,
    sctx: ShardingCtx | None = None,
) -> dict[str, Any]:
    """Real zeroed decode state (smoke tests / serving engine). With a
    meshed ``sctx`` every layer leaf is placed at its profile-resolved
    NamedSharding (heads/kv over model, replicated fallback)."""
    schema = lm.decode_state_schema(cfg, batch, s_max)
    state = init_params(schema, jax.random.PRNGKey(0))
    if sctx is not None and sctx.mesh is not None:
        state["layers"] = shard_tree(state["layers"], schema["layers"], sctx)
    state["pos"] = jnp.asarray(start_pos, jnp.int32)
    return state


def init_paged_decode_state(
    cfg: ModelConfig,
    batch: int,
    s_max: int,
    pages,
    sctx: ShardingCtx | None = None,
) -> dict[str, Any]:
    """Decode state whose dense/windowed KV leaves are shared page pools
    (``pages``: a serve.pages.PageLayout); other state kinds stay per-slot.
    With a meshed ``sctx`` the pool leaves shard on kv_heads/head_dim over
    ``model`` (page axes replicated): every device owns its slice of every
    page, so page-table indirection stays a device-local gather."""
    schema = lm.decode_state_schema(cfg, batch, s_max, pages=pages)
    state = init_params(schema, jax.random.PRNGKey(0))
    if sctx is not None and sctx.mesh is not None:
        state["layers"] = shard_tree(state["layers"], schema["layers"], sctx)
    state["pos"] = jnp.zeros((batch,), jnp.int32)
    return state


def decode_state_shardings(
    cfg: ModelConfig, batch: int, s_max: int, sctx: ShardingCtx, pages=None
) -> Any:
    """NamedShardings for the batched decode state's ``layers`` subtree
    (None without a mesh) — the scheduler pins every step program's output
    layout to these so state placement never drifts between steps."""
    if sctx.mesh is None:
        return None
    schema = lm.decode_state_schema(cfg, batch, s_max, pages=pages)
    return sharding_tree(schema["layers"], sctx)


def fresh_slot_layers(cfg: ModelConfig, s_max: int) -> Any:
    """Batch-1 layer states a chunked prefill (re)starts a slot from.

    Zeroed storage with the recurrence log-stabilisers at their
    empty-recurrence values (xLSTM ``m`` at -1e30, sLSTM ``n`` at 1e-6) —
    the state a from-scratch prefill would initialise internally, so
    streaming chunk 0 against a freshly reset (or recompute-resumed) slot
    is numerically the same computation."""
    from repro.models import blocks as blk

    layers = init_decode_state(cfg, 1, s_max)["layers"]
    return blk.fresh_stack_states(cfg, layers)


def token_specs(shape: ShapeConfig, sctx: ShardingCtx) -> jax.ShapeDtypeStruct:
    B = shape.global_batch
    if sctx.mesh is None:
        return jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return jax.ShapeDtypeStruct(
        (B, 1),
        jnp.int32,
        sharding=NamedSharding(
            sctx.mesh, pspec_for((B, 1), ("batch", None), sctx.profile, sctx.mesh)
        ),
    )


def make_decode_step(cfg: ModelConfig, sctx: ShardingCtx) -> Callable:
    def serve_step(params, states, token):
        logits, new_states = lm.decode_step(params, cfg, states, token, sctx)
        # Greedy next token: keeps the lowered program end-to-end (sampling
        # strategies live in the engine, not the hot step).
        next_tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
        return next_tok, logits, new_states

    return serve_step


def make_prefill_step(cfg: ModelConfig, sctx: ShardingCtx) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, sctx)

    return prefill_step
