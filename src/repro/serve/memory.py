"""Memory layer of the serving core: a host-side facade over the page
machinery.

:class:`MemoryManager` owns everything the scheduler used to reach into
directly — the :class:`~repro.serve.pages.PageLayout`, the refcounted
:class:`~repro.serve.pages.PagePool` (one per ``data`` shard), the host
page-table mirror, and the per-slot prefix-index bookkeeping — behind a
narrow interface split in two:

  * **capacity queries** (``can_reserve_for`` / ``available_for`` /
    ``pages_for_len`` / ``held``): what the pure planner
    (serve/plan.py) consults; read-only, no device work, no JAX.
  * **mutations** (``reserve`` / ``grow`` / ``extend_to`` / ``adopt`` /
    ``prepare_write`` / ``truncate`` / ``release``): what the executor
    applies when a plan runs. Each mutation keeps the page-table mirror
    in sync, so callers never touch page ids directly.

Everything here is numpy + stdlib — property tests drive the planner
against a real ``MemoryManager`` without compiling anything.

**Data-axis pool partitioning.** With ``data_shards = D > 1`` the
allocatable pages split into ``D`` equal sub-pools, each with its own
trash row, laid out so physical page ids align with the GSPMD blocks of
a page-axis-sharded pool leaf: shard ``d`` owns rows
``[d * (P/D + 1), (d + 1) * (P/D + 1))`` with the block's last row as
its trash page. Slot ``s`` maps to shard ``s * D // n_slots`` — the same
contiguous ranges the batch axis shards into — and allocates pages only
from its shard's sub-pool, so steady-state decode reads and writes stay
on the device that owns both the slot row and the page slice. Prefix
indexing and preemption victims are shard-local. ``D = 1`` (the default
and every unmeshed configuration) is bit-for-bit the single-pool
behavior.
"""
from __future__ import annotations

import numpy as np

from repro.serve.pages import PageLayout, PagePool, prefix_page_keys


class MemoryManager:
    """Facade over layout + pools + page-table mirror + prefix bookkeeping.

    ``layout`` is the *global* page geometry (``total_pages`` rows
    including one trash row per data shard); ``pt`` is the host mirror of
    the device page table, ``(n_slots, max_pages)`` int32 global ids.
    A ``layout`` of None builds a no-op manager for unpaged models.
    """

    def __init__(self, layout: PageLayout | None, n_slots: int):
        self.layout = layout
        self.n_slots = n_slots
        self.paged = layout is not None
        if not self.paged:
            self.pools: list[PagePool] = []
            self.pt = None
            self.slot_keys: dict[int, list[bytes]] = {}
            self.slot_reg: dict[int, int] = {}
            return
        D = layout.data_shards
        if layout.n_pages % D:
            raise ValueError(
                f"n_pages {layout.n_pages} not divisible by data_shards {D}"
            )
        per = layout.n_pages // D
        local = PageLayout(
            page_size=layout.page_size, n_pages=per, span=layout.span
        )
        self.pools = [PagePool(local) for _ in range(D)]
        self._per = per  # allocatable pages per shard
        self._stride = per + 1  # rows per shard block (incl. its trash row)
        self.pt = np.empty((n_slots, layout.max_pages), np.int32)
        for s in range(n_slots):
            self.pt[s, :] = self.trash_of(s)
        self.slot_keys = {}  # slot -> prompt page keys (prefix sharing)
        self.slot_reg = {}  # slot -> leading pages registered in the index

    # -- shard geometry ------------------------------------------------------
    @property
    def data_shards(self) -> int:
        return self.layout.data_shards if self.paged else 1

    def shard_of(self, slot: int) -> int:
        """Data shard owning ``slot`` (same ranges the batch axis splits)."""
        return slot * self.data_shards // self.n_slots

    def trash_of(self, slot: int) -> int:
        """Global id of ``slot``'s shard-local trash row."""
        return self.shard_of(slot) * self._stride + self._per

    def _pool(self, slot: int) -> PagePool:
        return self.pools[self.shard_of(slot)]

    def _to_global(self, slot: int, pids: list[int]) -> list[int]:
        off = self.shard_of(slot) * self._stride
        return [off + p for p in pids]

    # -- compatibility: the single-pool view (tests, stats) ------------------
    @property
    def pool(self) -> PagePool | None:
        """The sole pool when unsharded (every pre-existing test and the
        unmeshed serving path); sharded callers go through the facade."""
        if not self.paged:
            return None
        if len(self.pools) != 1:
            raise AttributeError(
                "MemoryManager.pool is single-shard only; use the facade "
                "methods (the pool is partitioned across data shards)"
            )
        return self.pools[0]

    # -- capacity queries (planner-facing, read-only) ------------------------
    @property
    def max_pages(self) -> int:
        return self.layout.max_pages if self.paged else 0

    @property
    def page_size(self) -> int:
        return self.layout.page_size if self.paged else 0

    @property
    def n_pages(self) -> int:
        return self.layout.n_pages if self.paged else 0

    def pages_for_len(self, length: int) -> int:
        return self.layout.pages_for_len(length) if self.paged else 0

    def held(self, slot: int) -> int:
        """Pages currently allocated to ``slot``."""
        return len(self._pool(slot).allocated(slot)) if self.paged else 0

    def available_for(self, slot: int) -> int:
        """Pages admissible to a new reservation in ``slot``'s shard."""
        return self._pool(slot).available()

    def can_reserve_for(self, slot: int, n: int) -> bool:
        return self._pool(slot).can_reserve(n)

    def lookup_prefix_len(self, slot: int, prompt: np.ndarray) -> int:
        """Indexed-prefix pages a prompt would adopt in ``slot``'s shard."""
        keys = prefix_page_keys(prompt, self.layout.page_size)
        return self._pool(slot).lookup_prefix(keys)

    @property
    def in_use(self) -> int:
        return sum(p.in_use for p in self.pools)

    @property
    def peak_in_use(self) -> int:
        return sum(p.peak_in_use for p in self.pools)

    def available_total(self) -> int:
        return sum(p.available() for p in self.pools)

    def reset_peaks(self) -> None:
        """Reset every shard pool's peak-usage watermark (benchmarks scope
        peak bytes past warmup/primer phases). No-op when unpaged."""
        for p in self.pools:
            p.reset_peaks()

    # -- mutations (executor-facing) -----------------------------------------
    def reserve(self, slot: int, n: int) -> None:
        """Open ``slot``'s reservation and point its table row at trash."""
        self._pool(slot).reserve(slot, n)
        self.pt[slot, :] = self.trash_of(slot)

    def extend_to(self, slot: int, n_total: int) -> bool:
        return self._pool(slot).extend_to(slot, n_total)

    def grow(self, slot: int, n_total: int) -> None:
        """Allocate up to ``n_total`` pages and map them in the mirror."""
        pool = self._pool(slot)
        held = len(pool.allocated(slot))
        if n_total > held:
            new = self._to_global(slot, pool.grow_to(slot, n_total))
            self.pt[slot, held:n_total] = new

    def adopt(self, slot: int, prompt: np.ndarray, src_len: int) -> int:
        """Adopt the longest indexed prefix of ``prompt`` (capped below
        ``src_len`` so at least one token still streams); returns adopted
        *tokens*. Must run right after ``reserve``."""
        P = self.layout.page_size
        keys = prefix_page_keys(prompt, P)
        pool = self._pool(slot)
        adopted = pool.adopt_prefix(slot, keys[: (src_len - 1) // P])
        if adopted:
            self.pt[slot, :adopted] = self._to_global(
                slot, pool.allocated(slot)
            )
        self.slot_keys[slot] = keys
        self.slot_reg[slot] = adopted
        return adopted * P

    def register_progress(self, slot: int, tokens_done: int) -> None:
        """Index ``slot``'s newly-completed full prompt pages."""
        keys = self.slot_keys.get(slot)
        if keys is None:
            return
        pool = self._pool(slot)
        done = min(tokens_done // self.layout.page_size, len(keys))
        for j in range(self.slot_reg.get(slot, 0), done):
            pool.register_page(slot, j, keys[j])
        self.slot_reg[slot] = max(self.slot_reg.get(slot, 0), done)

    def prepare_write(
        self, slot: int, start: int, stop: int
    ) -> list[tuple[int, int, int]]:
        """CoW-fork shared pages in the write range; re-points the mirror
        and returns global ``(logical, old, new)`` triples for the device
        copy (empty in the steady state)."""
        forks = self._pool(slot).prepare_write(slot, start, stop)
        if not forks:
            return []
        off = self.shard_of(slot) * self._stride
        out = [(j, off + old, off + new) for j, old, new in forks]
        for j, _, new in out:
            self.pt[slot, j] = new
        return out

    def truncate(
        self, slot: int, n_total: int, keep_reservation: bool
    ) -> int:
        """Drop trailing pages to ``n_total`` (spec rollback); trash-points
        the vacated mirror entries. Returns the number removed."""
        removed = self._pool(slot).truncate_to(
            slot, n_total, keep_reservation=keep_reservation
        )
        if removed:
            self.pt[slot, n_total : n_total + len(removed)] = self.trash_of(
                slot
            )
        return len(removed)

    def release(self, slot: int) -> None:
        """Free the slot's pages, reservation, mirror row, and prefix
        bookkeeping (indexed pages park in the shard's cached list)."""
        self._pool(slot).release(slot)
        self.pt[slot, :] = self.trash_of(slot)
        self.slot_keys.pop(slot, None)
        self.slot_reg.pop(slot, None)

    def drop_slot_keys(self, slot: int) -> None:
        self.slot_keys.pop(slot, None)
        self.slot_reg.pop(slot, None)

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict[str, int]:
        if not self.paged:
            return {}
        agg = dict(self.pools[0].stats())
        for p in self.pools[1:]:
            for k, v in p.stats().items():
                if k == "page_size":
                    continue
                agg[k] += v
        agg["page_size"] = self.layout.page_size
        agg["data_shards"] = self.data_shards
        return agg
