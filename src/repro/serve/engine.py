"""Batched serving engine: prefill -> iterative decode with ring/window and
recurrent states, greedy or temperature sampling, per-sequence stop.

The engine owns the non-jitted policy (request batching, sampling, stop
conditions, cache sizing); the jitted hot path is ``serve.step`` exactly as
lowered by the dry-run, so what we benchmark is what serves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.step import init_decode_state
from repro.sharding.rules import ShardingCtx


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    cache_len: int = 256  # decode cache slots (>= prompt + new tokens for dense)
    temperature: float = 0.0  # 0 => greedy
    stop_token: int = -1  # -1 => never stop early
    seed: int = 0


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, <=max_new_tokens)
    steps: int
    prefill_logits: np.ndarray


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sctx = sctx
        self.serve = serve
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b, sctx))
        self._decode = jax.jit(lambda p, s, t: lm.decode_step(p, cfg, s, t, sctx))

    # -- state surgery -------------------------------------------------------
    def _grow_states(self, states: dict[str, Any], prompt_len: int, batch: int) -> dict[str, Any]:
        """Move prefill caches (length S) into serving caches (cache_len).

        Dense caches are left-aligned; window ring buffers are filled so slot
        ``p % W`` holds position p for the last W prompt positions; recurrent
        states copy through untouched.
        """
        target = init_decode_state(self.cfg, batch, self.serve.cache_len, start_pos=prompt_len)

        def graft(dst, src):
            if isinstance(dst, dict) and isinstance(src, dict):
                return {k: graft(dst[k], src[k]) for k in dst}
            d, s = jnp.asarray(dst), jnp.asarray(src)
            if d.shape == s.shape:
                return s
            if d.ndim != s.ndim:
                raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
            diff = [i for i in range(d.ndim) if d.shape[i] != s.shape[i]]
            if len(diff) != 1:
                raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
            ax = diff[0]  # the cache-sequence axis (works for stacked groups too)
            dm = jnp.moveaxis(d, ax, 0)
            sm = jnp.moveaxis(s, ax, 0)
            W = dm.shape[0]
            if sm.shape[0] >= W:
                # ring buffer: the last W prompt positions land at slot p % W
                tail = sm[-W:]
                pos = jnp.arange(prompt_len - W, prompt_len) % W
                dm = dm.at[pos].set(tail.astype(dm.dtype))
            else:
                # dense cache longer than the prompt: left-aligned
                dm = dm.at[: sm.shape[0]].set(sm.astype(dm.dtype))
            return jnp.moveaxis(dm, 0, ax)

        grafted = graft(target["layers"], states["layers"])
        return {"layers": grafted, "pos": jnp.asarray(prompt_len, jnp.int32)}

    # -- generation ---------------------------------------------------------
    def generate(self, batch: dict[str, Any]) -> GenerationResult:
        cfg, serve = self.cfg, self.serve
        B = batch["tokens"].shape[0]
        prompt_len = batch["tokens"].shape[1] + (cfg.prefix_len or 0)
        assert prompt_len + serve.max_new_tokens <= serve.cache_len or cfg.supports_long_context or cfg.window_size, (
            f"cache_len {serve.cache_len} too small for {prompt_len}+{serve.max_new_tokens}"
        )
        logits, states = self._prefill(self.params, batch)
        states = self._grow_states(states, prompt_len, B)

        key = jax.random.PRNGKey(serve.seed)
        tok = self._sample(logits[:, -1], key)
        out = [np.asarray(tok)[:, 0]]
        done = np.zeros(B, bool)
        steps = 1
        for i in range(serve.max_new_tokens - 1):
            logits, states = self._decode(self.params, states, tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1], sub)
            col = np.asarray(tok)[:, 0]
            out.append(col)
            steps += 1
            if serve.stop_token >= 0:
                done |= col == serve.stop_token
                if done.all():
                    break
        return GenerationResult(
            tokens=np.stack(out, axis=1), steps=steps, prefill_logits=np.asarray(logits)
        )

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        logits = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        if self.serve.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / self.serve.temperature, axis=-1)[
            :, None
        ].astype(jnp.int32)
