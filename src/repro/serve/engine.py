"""Serving engine: a thin client of the continuous-batching scheduler.

``generate()`` submits one request per batch row to a ``Scheduler`` and
drains it; requests retire independently (per-request stop token and
max_new_tokens), and the decode hot path is the scheduler's fixed-shape
``(n_slots, 1)`` step. ``generate_static()`` keeps the original static-batch
loop — all rows march in lockstep until every one finishes — as the
reference implementation the scheduler is tested token-for-token against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import lm
from repro.serve.cache import graft_states
from repro.serve.request import Request
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.serve.step import init_decode_state
from repro.sharding.rules import ShardingCtx


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    cache_len: int = 256  # decode cache slots (>= prompt + new tokens for dense)
    temperature: float = 0.0  # 0 => greedy
    stop_token: int = -1  # -1 => never stop early
    seed: int = 0
    # Scheduler pass-through: paged KV pool + bucketed prefill + unified
    # token-budget step (the static reference path ignores these — it
    # always runs contiguous rows with whole-prompt prefill).
    paged: bool = True
    page_size: int = 16
    prefill_buckets: bool = True
    n_pages: int | None = None
    chunk_budget: int | None = None  # None -> whole-prompt prefill
    min_chunk: int = 16
    preemption: str = "off"  # "off" | "swap" | "recompute"
    prefix_sharing: bool = True  # adopt indexed prompt-prefix pages
    speculative: bool = False  # drafted multi-token steps (greedy slots)
    draft_k: int = 4  # max draft tokens per verify call
    drafter: Any = None  # Drafter instance; None -> NgramDrafter
    # Sharded stepping: (data, model) test-mesh shape the scheduler builds
    # when the engine's own ShardingCtx has no mesh (None keeps it as-is).
    mesh_shape: tuple[int, int] | None = None
    sharding_profile: str = "decode_default"


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, <=max_new_tokens)
    steps: int
    prefill_logits: np.ndarray


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, serve: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.sctx = sctx
        self.serve = serve
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, cfg, b, sctx))
        self._decode = jax.jit(lambda p, s, t: lm.decode_step(p, cfg, s, t, sctx))
        self._schedulers: dict[int, Scheduler] = {}  # keyed by n_slots

    # -- state surgery -------------------------------------------------------
    def _grow_states(self, states: dict[str, Any], prompt_len: int, batch: int) -> dict[str, Any]:
        """Move prefill caches (length S) into serving caches (cache_len)."""
        target = init_decode_state(self.cfg, batch, self.serve.cache_len, start_pos=prompt_len)
        layouts = blk.stack_layouts(self.cfg, self.serve.cache_len, paged=False)
        grafted = graft_states(
            target["layers"], states["layers"], prompt_len, layouts=layouts
        )
        return {"layers": grafted, "pos": jnp.asarray(prompt_len, jnp.int32)}

    # -- generation (continuous-batching path) ------------------------------
    def _sched_for(self, n_slots: int) -> Scheduler:
        # One scheduler per batch size, kept alive so alternating batch
        # shapes reuse their compiled decode/prefill/admit programs.
        if n_slots not in self._schedulers:
            self._schedulers[n_slots] = Scheduler(
                self.cfg, self.params, self.sctx,
                SchedulerConfig(
                    n_slots=n_slots, cache_len=self.serve.cache_len,
                    seed=self.serve.seed, paged=self.serve.paged,
                    page_size=self.serve.page_size,
                    n_pages=self.serve.n_pages,
                    prefill_buckets=self.serve.prefill_buckets,
                    chunk_budget=self.serve.chunk_budget,
                    min_chunk=self.serve.min_chunk,
                    preemption=self.serve.preemption,
                    prefix_sharing=self.serve.prefix_sharing,
                    speculative=self.serve.speculative,
                    draft_k=self.serve.draft_k,
                    drafter=self.serve.drafter,
                    mesh_shape=self.serve.mesh_shape,
                    sharding_profile=self.serve.sharding_profile,
                ),
            )
        return self._schedulers[n_slots]

    def generate(self, batch: dict[str, Any]) -> GenerationResult:
        cfg, serve = self.cfg, self.serve
        B = batch["tokens"].shape[0]
        prompt_len = batch["tokens"].shape[1] + (cfg.prefix_len or 0)
        assert prompt_len + serve.max_new_tokens <= serve.cache_len or cfg.supports_long_context or cfg.window_size, (
            f"cache_len {serve.cache_len} too small for {prompt_len}+{serve.max_new_tokens}"
        )
        sched = self._sched_for(B)
        sched.reset_rng(serve.seed)
        steps_before = sched.total_decode_steps
        tokens = np.asarray(batch["tokens"])
        extras = {k: np.asarray(v) for k, v in batch.items() if k != "tokens"}
        for i in range(B):
            sched.submit(
                Request(
                    prompt=tokens[i],
                    max_new_tokens=serve.max_new_tokens,
                    stop_token=serve.stop_token,
                    temperature=serve.temperature,
                    extras={k: v[i : i + 1] for k, v in extras.items()},
                )
            )
        finished = sched.run()

        steps = max(len(rs.tokens) for rs in finished)
        out = np.zeros((B, steps), np.int32)
        for i, rs in enumerate(finished):
            row = rs.tokens
            # Early-retired rows pad with their final token so the result
            # stays rectangular; the static path kept decoding instead.
            out[i] = row + [row[-1]] * (steps - len(row))
        if sched.total_decode_steps > steps_before:
            logits = np.asarray(sched.last_decode_logits)
        else:
            # Zero decode steps this call (max_new_tokens == 1 / instant
            # stops): report this batch's prefill logits, like the static
            # path, rather than a stale array from a previous call.
            logits = np.concatenate([rs.prefill_logits for rs in finished], axis=0)
        return GenerationResult(tokens=out, steps=steps, prefill_logits=logits)

    # -- generation (static-batch reference) --------------------------------
    def generate_static(self, batch: dict[str, Any]) -> GenerationResult:
        """The pre-scheduler static loop: one shared position counter, the
        whole batch decodes until its slowest member finishes."""
        cfg, serve = self.cfg, self.serve
        B = batch["tokens"].shape[0]
        prompt_len = batch["tokens"].shape[1] + (cfg.prefix_len or 0)
        assert prompt_len + serve.max_new_tokens <= serve.cache_len or cfg.supports_long_context or cfg.window_size, (
            f"cache_len {serve.cache_len} too small for {prompt_len}+{serve.max_new_tokens}"
        )
        logits, states = self._prefill(self.params, batch)
        states = self._grow_states(states, prompt_len, B)

        key = jax.random.PRNGKey(serve.seed)
        tok = self._sample(logits[:, -1], key)
        out = [np.asarray(tok)[:, 0]]
        done = np.zeros(B, bool)
        steps = 1
        for i in range(serve.max_new_tokens - 1):
            logits, states = self._decode(self.params, states, tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1], sub)
            col = np.asarray(tok)[:, 0]
            out.append(col)
            steps += 1
            if serve.stop_token >= 0:
                done |= col == serve.stop_token
                if done.all():
                    break
        return GenerationResult(
            tokens=np.stack(out, axis=1), steps=steps, prefill_logits=np.asarray(logits)
        )

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        logits = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        if self.serve.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / self.serve.temperature, axis=-1)[
            :, None
        ].astype(jnp.int32)
