"""Admission executor: queue → slot transitions over the layered core.

Free functions over a :class:`~repro.serve.scheduler.Scheduler`. Ordering
(stride-fair tenant picks) and capacity backpressure are plan-layer
decisions; page commitments go through the memory layer; slot resets,
prefill grafts, and swap-ins run through the program registry. The
scheduler calls only :func:`admit_pending` once per step.

Capacity checks peek the free-slot heap's minimum — the slot the
subsequent pop returns — so with a data-partitioned pool the check runs
against the shard that would actually back the admission (identical
behavior on a single shard).
"""
from __future__ import annotations

import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import plan as planlib
from repro.serve.request import RequestState, RequestStatus


def admit_pending(s) -> None:
    # Preempted requests resume first; a *deferred* resume blocks fresh
    # admissions too — otherwise younger requests would keep taking the
    # pages the preempted request is waiting for and starve it.
    while s._free_slots and s._preempted:
        if not try_resume(s, s._preempted[0]):
            return
        s._preempted.popleft()
    sc = s.sched
    if sc.tenant_quota is None and not sc.tenant_weights:
        # Single-tenant: exact FIFO (the historical admission order).
        while s._free_slots and s._queue:
            rs = s._queue[0]
            if not admit(s, rs):
                break
            s._queue.popleft()
        return
    # Multi-tenant: weighted-fair ordering with per-tenant page quotas. A
    # quota-blocked tenant is skipped (its requests keep FIFO order within
    # the tenant) while others continue to admit; pool backpressure blocks
    # everyone (FIFO fairness of the pool itself).
    blocked: set[str] = set()
    while s._free_slots and s._queue:
        rs = s._pick_next(blocked)
        if rs is None:
            break
        tenant = rs.request.tenant
        if s._paged and sc.tenant_quota is not None:
            n_worst = s._worst_pages(rs)
            if n_worst > sc.tenant_quota:
                raise RuntimeError(
                    f"request {rs.rid} needs {n_worst} pages worst-case, "
                    f"more than tenant {tenant!r}'s whole quota "
                    f"({sc.tenant_quota}); raise tenant_quota or lower "
                    "max_new_tokens"
                )
            if s._tenant_pages(tenant) + n_worst > sc.tenant_quota:
                blocked.add(tenant)
                s.quota_deferrals += 1
                continue
        if not admit(s, rs):
            break
        # identity, not ==: Request's dataclass __eq__ compares prompt
        # arrays elementwise
        for i, q in enumerate(s._queue):
            if q is rs:
                del s._queue[i]
                break
        s._charge_tenant(rs)


def admit(s, rs: RequestState) -> bool:
    if s._stream_capable and not rs.request.extras:
        return admit_streaming(s, rs)
    return admit_prefill(s, rs)


def check_fits(s, rs: RequestState, prompt_len: int) -> int:
    """Shared admission validation; returns the worst-case page count."""
    req = rs.request
    assert (
        prompt_len + req.max_new_tokens <= s.sched.cache_len
        or s.cfg.supports_long_context
        or s.cfg.window_size
    ), (
        f"cache_len {s.sched.cache_len} too small for "
        f"{prompt_len}+{req.max_new_tokens}"
    )
    if not s._paged:
        return 0
    n_worst = s.mem.pages_for_len(prompt_len + req.max_new_tokens)
    if n_worst > s.mem.n_pages // s.mem.data_shards:
        # Never admissible even into an empty (shard of the) pool: fail
        # fast instead of deferring forever (run() would spin).
        raise RuntimeError(
            f"request {rs.rid} needs {n_worst} pages worst-case "
            f"({prompt_len}+{req.max_new_tokens} tokens @ "
            f"{s.mem.page_size}/page) but the pool has only "
            f"{s.mem.n_pages // s.mem.data_shards} per shard; raise "
            "n_pages or lower max_new_tokens"
        )
    return n_worst


def admit_streaming(s, rs: RequestState) -> bool:
    """Assign a slot and start streaming the prompt in chunks, adopting any
    indexed prefix pages first (their tokens are skipped, not recomputed).
    Under worst-case reservations this is where OOM backpressure defers;
    reservation-free admission always proceeds (chunks reserve as they
    stream, preempting on demand)."""
    req = rs.request
    prompt_len = req.prompt.shape[0]
    n_worst = check_fits(s, rs, prompt_len)
    slot = s._free_slots[0]  # heap min == the slot the pop below returns
    if s._paged and not s._plan(
        planlib.can_admit_streaming, s.mem, slot, n_worst,
        reservation_free=s.sched.preemption != "off",
    ):
        s.deferred_admissions += 1
        return False
    heapq.heappop(s._free_slots)
    start = 0
    if s._paged:
        s.mem.reserve(slot, 0)
        if s._sharing:
            src_len = (
                len(rs.replay_tokens)
                if rs.replay_tokens is not None
                else prompt_len
            )
            # Adoption is capped below the streamed source so at least one
            # token still streams: the final chunk's logits seed the first
            # sampled token.
            start = s.mem.adopt(slot, req.prompt, src_len)
            if start:
                s.prefix_hits += 1
                s.prefix_hit_tokens += start
        if s.sched.preemption == "off" and not s.mem.extend_to(slot, n_worst):
            # Adoption revives cached pages (no longer evictable), but it
            # adopts at least as many pages as it revives, so the
            # pre-checked headroom still covers the remainder; this
            # rollback is defensive.
            s.mem.release(slot)
            heapq.heappush(s._free_slots, slot)
            s.deferred_admissions += 1
            return False
        s._slot_worst[slot] = (req.tenant, n_worst)
    layers, pos = s.programs.reset(
        s._states["layers"], s._states["pos"], jnp.asarray(slot, jnp.int32),
        jnp.asarray(start, jnp.int32),
    )
    s._states["layers"] = layers
    s._states["pos"] = pos
    s._pos_host[slot] = start
    rs.slot = slot
    rs.prompt_len = prompt_len
    rs.chunk_pos = start
    rs.adopted_tokens = start
    rs.status = RequestStatus.PREFILLING
    rs.t_admit = time.perf_counter()
    s._active[slot] = rs
    s._ev["admits"].append(
        planlib.AdmitPlan(
            rs.rid, "streaming", slot,
            n_worst if s.sched.preemption == "off" else 0,
        )
    )
    return True


def try_resume(s, rs: RequestState) -> bool:
    """Re-admit a preempted request: swap its snapshot back in, or restart
    streaming (recompute). False defers (not enough pages)."""
    if rs.swap is None:
        # recompute: restart chunk streaming over prompt + generated tokens
        return admit_streaming(s, rs)
    snap, pos_v = rs.swap
    need = s.mem.pages_for_len(pos_v)
    slot = s._free_slots[0]  # heap min == the slot the pop below returns
    if not s._plan(planlib.can_resume_swap, s.mem, slot, need):
        s.deferred_admissions += 1
        return False
    heapq.heappop(s._free_slots)
    s.mem.reserve(slot, 0)
    if not s.mem.extend_to(slot, need):  # pragma: no cover - race-free
        raise RuntimeError("pool accounting violated availability check")
    s.mem.grow(slot, need)
    layers, pos = s.programs.swap_in(
        s._states["layers"], s._states["pos"], jax.tree.map(s._put, snap),
        s._put(s.mem.pt[slot]), jnp.asarray(slot, jnp.int32),
        jnp.asarray(pos_v, jnp.int32),
    )
    s._states["layers"] = layers
    s._states["pos"] = pos
    s._pos_host[slot] = pos_v
    rs.swap = None
    rs.slot = slot
    s._slot_worst[slot] = (rs.request.tenant, s._worst_pages(rs))
    rs.status = RequestStatus.ACTIVE
    rs.t_admit = time.perf_counter()
    s._tokens[slot, 0] = rs.tokens[-1]
    s._temps[slot] = rs.request.temperature
    s._active_mask[slot] = True
    s._active[slot] = rs
    s._ev["admits"].append(planlib.AdmitPlan(rs.rid, "resume_swap", slot, need))
    return True


def admit_prefill(s, rs: RequestState) -> bool:
    """Whole-prompt prefill + graft at admission (also the fallback for
    modality-prefix / enc-dec requests when chunked streaming is on).
    Returns False to defer on pool backpressure."""
    req = rs.request
    prompt_len = req.prompt.shape[0] + (s.cfg.prefix_len or 0)
    n_reserve = check_fits(s, rs, prompt_len)
    page_ids_arr = None
    slot = s._free_slots[0]  # heap min == the slot the pop below returns
    if s._paged and not s._plan(planlib.can_admit_prefill, s.mem, slot, n_reserve):
        # OOM backpressure: not enough headroom in the slot's shard for
        # this request's worst case — defer admission (FIFO preserved;
        # live pages are never reclaimed or aliased).
        s.deferred_admissions += 1
        return False
    heapq.heappop(s._free_slots)
    if s._paged:
        s.mem.reserve(slot, n_reserve)
        s._slot_worst[slot] = (req.tenant, n_reserve)
        s.mem.grow(slot, s.mem.pages_for_len(prompt_len))
        page_ids_arr = s._put(s.mem.pt[slot])

    tok_len = req.prompt.shape[0]
    pad_to = s._bucket_len(tok_len)
    toks = np.asarray(req.prompt)
    if pad_to != tok_len:
        toks = np.concatenate([toks, np.zeros(pad_to - tok_len, np.int32)])
    batch = {"tokens": s._put(toks[None, :])}
    for k, v in req.extras.items():
        batch[k] = jnp.asarray(v)
    if s._bucketed:
        batch["logit_pos"] = jnp.asarray(prompt_len - 1, jnp.int32)
    logits, pstates = s.programs.prefill(s.params, batch)

    plen_t = jnp.asarray(prompt_len, jnp.int32)
    slot_t = jnp.asarray(slot, jnp.int32)
    if s._paged:
        layers, pos = s.programs.admit(
            s._states["layers"], s._states["pos"], pstates["layers"],
            slot_t, page_ids_arr, plen_t,
        )
    else:
        layers, pos = s.programs.admit(
            s._states["layers"], s._states["pos"], pstates["layers"],
            slot_t, plen_t,
        )
    s._states["layers"] = layers
    s._states["pos"] = pos
    s._pos_host[slot] = prompt_len

    now = time.perf_counter()
    s._key, sub = jax.random.split(s._key)
    first = int(
        np.asarray(
            s.programs.sample(
                logits[:, -1, :], jnp.full((1,), req.temperature, jnp.float32), sub
            )
        )[0]
    )
    rs.slot = slot
    rs.prompt_len = prompt_len
    rs.status = RequestStatus.ACTIVE
    rs.tokens = [first]
    rs.prefill_logits = np.asarray(logits[:, -1:, :])
    rs.t_admit = now
    rs.t_first_token = now
    rs.t_tokens.append(now)
    s._tokens[slot, 0] = first
    s._temps[slot] = req.temperature
    s._active_mask[slot] = True
    s._active[slot] = rs
    s._ev["admits"].append(planlib.AdmitPlan(rs.rid, "prefill", slot, n_reserve))
    # A 1-token request (or an immediate stop) retires before ever riding
    # the decode step, freeing the slot for this admission loop.
    s._maybe_finish(rs, now)
    return True
