"""Plan layer of the serving core: pure, host-side scheduling decisions.

Every sizing and ordering decision the scheduler makes — chunk buckets,
prefill pad lengths, page-count buckets, preemption victims, weighted-fair
admission order, admission backpressure — lives here as a pure function of
plain values plus read-only :class:`~repro.serve.memory.MemoryManager`
capacity queries. Nothing in this module imports JAX or touches device
state, so every policy is unit-testable (and property-testable, see
tests/test_plan_props.py) without compiling a single program.

The executor (`serve/scheduler.py`) interleaves planning and execution at
decision granularity — an admission can retire instantly and free its slot
for the next admission within the same step, so a single frozen whole-step
plan could not reproduce the historical (test-pinned) schedule. What the
scheduler *does* freeze is the record: every decision taken during one
``step()`` is accumulated into an immutable :class:`BatchPlan`
(``Scheduler.last_plan``) and the time spent inside plan functions into
``Scheduler.plan_time_s`` (the B16 planner-overhead metric).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, Sequence


def pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# -- immutable decision records ---------------------------------------------
@dataclass(frozen=True)
class SlotView:
    """What the planner may know about an occupied slot."""

    slot: int
    rid: int
    status: str  # "active" | "prefilling"
    t_admit: float
    preemptable: bool
    shard: int = 0  # data shard owning the slot's pool slice


@dataclass(frozen=True)
class ChunkPlan:
    """One prefill chunk: bucketed token count + page backing to secure."""

    slot: int
    rid: int
    start: int  # tokens already cached (chunk writes begin here)
    bucket: int  # padded chunk shape (fixed power-of-two set)
    n_real: int  # real tokens in the chunk
    need_pages: int  # total pages the slot must hold after the chunk
    n_lp: int  # page-table bucket passed to the chunk program


@dataclass(frozen=True)
class VerifyPlan:
    """One speculative verify call: pending token + draft, bucketed."""

    slot: int
    rid: int
    start: int
    k: int  # draft tokens proposed
    n_real: int  # k + 1 (pending token rides along)
    bucket: int  # padded verify shape
    need_pages: int
    n_lp: int


@dataclass(frozen=True)
class AdmitPlan:
    """One admission/resume decision (recorded whether or not it ran)."""

    rid: int
    kind: str  # "streaming" | "prefill" | "resume_swap" | "resume_recompute"
    slot: int | None  # None when deferred
    n_reserve: int  # worst-case pages (0 reservation-free / unpaged)


@dataclass(frozen=True)
class BatchPlan:
    """Everything one ``step()`` decided, in decision order."""

    admitted: tuple[AdmitPlan, ...] = ()
    chunk: ChunkPlan | None = None
    verifies: tuple[VerifyPlan, ...] = ()
    decode_rows: tuple[int, ...] = ()
    preempted: tuple[int, ...] = ()  # victim rids, in eviction order


# -- sizing ------------------------------------------------------------------
def bucket_len(
    token_len: int,
    *,
    bucketed: bool,
    min_bucket: int,
    cache_len: int,
    prefix_len: int,
    long_ok: bool,
) -> int:
    """Power-of-two padded prompt length (identity when bucketing is off).

    Dense prompts never exceed ``cache_len`` (asserted at admission), so
    buckets cap there to keep the padded prompt in one row. Prompts
    legitimately *past* the cap (windowed / long-context models,
    ``long_ok``) stay on uncapped power-of-two buckets: at most
    log2(longest prompt) distinct shapes, never the raw length."""
    if not bucketed:
        return token_len
    b = max(min_bucket, 1)
    while b < token_len:
        b *= 2
    cap = cache_len - prefix_len
    if token_len > cap:
        if long_ok:
            return b
        raise RuntimeError(
            f"prompt of {token_len} tokens exceeds the dense prefill cap "
            f"{cap} (cache_len {cache_len}); admission validation should "
            "have rejected this request"
        )
    return min(b, cap)


def chunk_bucket(remaining: int, *, chunk_budget: int, min_chunk: int) -> tuple[int, int]:
    """(bucket, n_real) for the next prefill chunk. Chunk shapes come from
    a *fixed* power-of-two set — ``min_chunk`` up to
    ``pow2_floor(chunk_budget)`` — independent of decode load, so the busy
    system never meets a shape the idle warmup didn't compile."""
    max_b = pow2_floor(chunk_budget)
    bucket = min(max(pow2_ceil(min(remaining, max_b)), min_chunk), max_b)
    return bucket, min(bucket, remaining)


def page_bucket(need: int, max_pages: int) -> int:
    """Power-of-two page-count bucket for a program's table argument: the
    gather/kernel cost tracks the live prefix, not the table width."""
    return min(pow2_ceil(max(need, 1)), max_pages)


def plan_chunk(
    slot: int, rid: int, start: int, remaining: int, *,
    chunk_budget: int, min_chunk: int, mem: Any = None,
) -> ChunkPlan:
    """Size the next chunk of a streaming prompt and the pages backing it.
    ``mem`` (a MemoryManager, or None/unpaged) supplies page geometry via
    capacity queries only — the plan commits nothing."""
    bucket, n_real = chunk_bucket(
        remaining, chunk_budget=chunk_budget, min_chunk=min_chunk
    )
    need = n_lp = 0
    if mem is not None and mem.paged:
        need = mem.pages_for_len(start + n_real)
        n_lp = page_bucket(need, mem.max_pages)
    return ChunkPlan(slot, rid, start, bucket, n_real, need, n_lp)


def plan_verify(
    slot: int, rid: int, start: int, k: int, *, draft_k: int, mem: Any = None
) -> VerifyPlan:
    """Size one speculative verify: pending token + k draft tokens, padded
    to the fixed (k-bucket, page-bucket) set."""
    n_real = k + 1
    bucket = min(pow2_ceil(n_real), pow2_ceil(draft_k + 1))
    need = n_lp = 0
    if mem is not None and mem.paged:
        need = mem.pages_for_len(start + n_real)
        n_lp = page_bucket(need, mem.max_pages)
    return VerifyPlan(slot, rid, start, k, n_real, bucket, need, n_lp)


def spec_budget(max_new_tokens: int, emitted: int) -> int:
    """Draft budget beyond this step's guaranteed emission."""
    return max_new_tokens - emitted - 1


def decode_rows(active_mask: Sequence[bool], handled: Iterable[int] = ()) -> tuple[int, ...]:
    """Slots riding this step's decode: active and not already emitted via
    verify. Frozen slots (free, PREFILLING, spec-handled) never appear."""
    skip = set(handled)
    return tuple(i for i, a in enumerate(active_mask) if a and i not in skip)


# -- admission capacity (backpressure is a plan, not a side effect) ----------
def can_admit_streaming(mem: Any, slot: int, n_worst: int, *, reservation_free: bool) -> bool:
    """Streaming admission proceeds reservation-free (chunks reserve as
    they stream, preempting on demand); under worst-case reservations the
    whole footprint must fit the slot's shard now."""
    if mem is None or not mem.paged or reservation_free:
        return True
    return mem.can_reserve_for(slot, n_worst)


def can_admit_prefill(mem: Any, slot: int, n_reserve: int) -> bool:
    """Whole-prompt prefill always reserves the worst case up front."""
    if mem is None or not mem.paged:
        return True
    return mem.can_reserve_for(slot, n_reserve)


def can_resume_swap(mem: Any, slot: int, need: int) -> bool:
    """A swapped-out request resumes only when its full snapshot fits —
    a deferred resume blocks fresh admissions (starvation guard)."""
    return need <= mem.available_for(slot)


# -- ordering ----------------------------------------------------------------
def pick_victim(
    views: Iterable[SlotView],
    *,
    protect: int,
    requester_rid: int | None = None,
    shard: int | None = None,
) -> int | None:
    """LRU preemption victim: the least-recently-(re)admitted preemptable
    ACTIVE slot; when none exists, a *younger* PREFILLING streamer
    (rid > requester — restarting the youngest guarantees the oldest
    in-flight request always wins its pages). ``shard`` restricts victims
    to one data shard (freeing pages elsewhere cannot back the
    requester's growth); None matches the classic single-pool rule."""
    views = [
        v for v in views
        if v.slot != protect and (shard is None or v.shard == shard)
    ]
    victims = [v for v in views if v.status == "active" and v.preemptable]
    if victims:
        return min(victims, key=lambda v: v.t_admit).slot
    if requester_rid is None:
        return None
    streamers = [
        v for v in views if v.status == "prefilling" and v.rid > requester_rid
    ]
    if not streamers:
        return None
    return max(streamers, key=lambda v: v.rid).slot


@dataclass(frozen=True)
class QueueView:
    """Head-of-line candidate for weighted-fair admission."""

    rid: int
    tenant: str


def pick_next(
    queue: Iterable[QueueView],
    blocked: frozenset[str] | set[str],
    tenant_pass: dict[str, float],
) -> int | None:
    """Stride-scheduling pick: among each unblocked tenant's head-of-line
    request, the one whose tenant has the lowest virtual pass (ties by
    rid). Tenants first seen mid-flight join at the current minimum pass.
    Returns the chosen rid, or None."""
    heads: dict[str, QueueView] = {}
    for v in queue:
        if v.tenant in blocked or v.tenant in heads:
            continue
        heads[v.tenant] = v
    if not heads:
        return None
    floor = min(tenant_pass.values(), default=0.0)

    def pass_of(t: str) -> float:
        return tenant_pass.get(t, floor)

    return min(heads.values(), key=lambda v: (pass_of(v.tenant), v.rid)).rid


def charge_tenant(
    tenant_pass: dict[str, float], tenant: str, tokens: int, weight: float
) -> dict[str, float]:
    """Advance ``tenant``'s stride pass by ``tokens / weight`` (new tenants
    start from the current floor). Returns a new dict — pure."""
    floor = min(tenant_pass.values(), default=0.0)
    out = dict(tenant_pass)
    out[tenant] = out.get(tenant, floor) + tokens / weight
    return out
