"""Chunked-prefill executor: stream one prompt chunk per unified step.

Free functions over a :class:`~repro.serve.scheduler.Scheduler`. Chunk
sizing comes from the plan layer (:func:`repro.serve.plan.plan_chunk`),
page backing from the memory layer, and the chunk program from the
registry.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import plan as planlib
from repro.serve.request import RequestState, RequestStatus


def prefill_chunk_step(s) -> bool:
    """Stream one prompt chunk for the oldest PREFILLING slot. Chunk sizes
    come from the fixed power-of-two bucket set (plan layer), so the
    loaded system never meets a shape the idle warmup didn't compile;
    per-step work stays bounded by chunk_budget + n_slots. Returns True
    if a chunk program ran."""
    prefilling = sorted(
        (rs for rs in s._active.values()
         if rs.status is RequestStatus.PREFILLING),
        key=lambda r: r.rid,
    )
    if not prefilling:
        return False
    sc = s.sched
    rs = prefilling[0]
    slot = rs.slot
    src = (
        rs.replay_tokens
        if rs.replay_tokens is not None
        else np.asarray(rs.request.prompt)
    )
    cp = s._plan(
        planlib.plan_chunk, slot, rs.rid, rs.chunk_pos, len(src) - rs.chunk_pos,
        chunk_budget=sc.chunk_budget, min_chunk=sc.min_chunk,
        mem=s.mem if s._paged else None,
    )
    start, n_real = cp.start, cp.n_real

    page_ids = None
    if s._paged:
        if not s._ensure_pages(slot, cp.need_pages, rid=rs.rid):
            s.deferred_admissions += 1
            return False
        s.mem.grow(slot, cp.need_pages)
        if s._sharing:
            # Fork any shared page in the chunk's write range before the
            # chunk program touches it (steady-state no-op: chunks only
            # write at or past the first unadopted position).
            s._apply_cow(s.mem.prepare_write(slot, start, start + n_real))
        # The chunk only attends to pages covering [0, start + n_real);
        # the power-of-two page bucket keeps the gather/kernel cost
        # tracking the live prefix, not the table width.
        page_ids = s._put(s.mem.pt[slot, : cp.n_lp])

    toks = src[start : start + n_real].astype(np.int32)
    if n_real < cp.bucket:
        toks = np.concatenate([toks, np.zeros(cp.bucket - n_real, np.int32)])
    args = [
        s._states["layers"], s._states["pos"], s._put(toks[None, :]),
        jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32),
        jnp.asarray(n_real, jnp.int32),
    ]
    if s._paged:
        args.append(page_ids)
    logits, layers, pos = s.programs.chunk(*args)
    s._states["layers"] = layers
    s._states["pos"] = pos
    rs.chunk_pos += n_real
    s._pos_host[slot] = rs.chunk_pos
    s.total_chunk_steps += 1
    s._ev["chunk"] = cp
    if s._sharing and slot in s.mem.slot_keys:
        # Register newly-completed full prompt pages in the prefix index
        # (first writer wins; adopted pages are already indexed).
        s.mem.register_progress(slot, rs.chunk_pos)
    if rs.chunk_pos == len(src):
        finish_prefill(s, rs, logits)
    return True


def finish_prefill(s, rs: RequestState, logits: jax.Array) -> None:
    """The prompt is fully streamed: join the decode batch."""
    slot = rs.slot
    now = time.perf_counter()
    req = rs.request
    if rs.replay_tokens is not None:
        # Recompute resume: the last generated token was never fed back; it
        # is the next decode input, not a fresh sample.
        rs.replay_tokens = None
        s._tokens[slot, 0] = rs.tokens[-1]
    else:
        s._key, sub = jax.random.split(s._key)
        first = int(
            np.asarray(
                s.programs.sample(
                    logits[:, -1, :],
                    jnp.full((1,), req.temperature, jnp.float32),
                    sub,
                )
            )[0]
        )
        rs.tokens = [first]
        rs.prefill_logits = np.asarray(logits[:, -1:, :])
        rs.t_first_token = now
        rs.t_tokens.append(now)
        s._tokens[slot, 0] = first
    rs.status = RequestStatus.ACTIVE
    s._temps[slot] = req.temperature
    s._active_mask[slot] = True
    s._maybe_finish(rs, now)
