"""Speculative-decoding executor: draft + verify on top of the layered core.

Free functions over a :class:`~repro.serve.scheduler.Scheduler` — sizing
comes from the plan layer (:func:`repro.serve.plan.plan_verify`), page
backing from the memory layer, and the verify/chunk/setpos programs from
the registry. Kept out of scheduler.py so the core loop stays slim;
nothing here owns state.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import plan as planlib
from repro.serve.request import RequestState, RequestStatus


def spec_step(s) -> set[int]:
    """Draft + verify for every eligible ACTIVE slot (greedy only, no
    modality extras, >= 1 token of budget beyond this step's guaranteed
    emission); returns the slots that emitted here (they sit out this
    step's decode). A slot whose draft can't get page backing falls back
    to plain decoding for this step (``spec_fallbacks``)."""
    handled: set[int] = set()
    for slot in sorted(s._active):
        rs = s._active.get(slot)
        if rs is None or rs.status is not RequestStatus.ACTIVE:
            continue  # may have been preempted by an earlier verify
        req = rs.request
        if req.temperature > 0.0 or req.extras:
            continue
        budget = s._plan(planlib.spec_budget, req.max_new_tokens, len(rs.tokens))
        if budget < 1:
            continue
        ctx = np.concatenate(
            [np.asarray(req.prompt, np.int32), np.asarray(rs.tokens, np.int32)]
        )
        k = min(s.sched.draft_k, budget)
        draft = np.asarray(s._drafter.propose(ctx, k), np.int32).reshape(-1)[:k]
        if draft.size == 0:
            continue
        if verify_slot(s, slot, rs, draft):
            handled.add(slot)
    return handled


def verify_slot(s, slot: int, rs: RequestState, draft: np.ndarray) -> bool:
    """Score ``[pending token, draft...]`` in one all-logits chunk call and
    emit the longest greedy-matching run plus the model's own next token —
    between 1 and k+1 tokens, token-identical to plain decoding. Returns
    False (slot decodes plainly this step) only when the draft can't get
    page backing.

    Invariant in and out: the cache holds ``prompt + generated - 1``
    tokens and ``_tokens[slot]`` is the last generated token, not yet
    fed. Greedy logits at chunk index ``i`` answer "what follows token
    i", so index ``accepted`` supplies the bonus/correction token."""
    vp = s._plan(
        planlib.plan_verify, slot, rs.rid, int(s._pos_host[slot]), len(draft),
        draft_k=s.sched.draft_k, mem=s.mem if s._paged else None,
    )
    k, start, n_real = vp.k, vp.start, vp.n_real
    page_ids = None
    if s._paged:
        if vp.need_pages > s.mem.held(slot):
            if not s._ensure_pages(slot, vp.need_pages, rid=rs.rid):
                s.spec_fallbacks += 1
                return False
            s.mem.grow(slot, vp.need_pages)
        if s._sharing:
            # Defensive CoW guard, like the decode step's: the verify range
            # starts past any shared prompt page (steady-state no-op).
            s._apply_cow(s.mem.prepare_write(slot, start, start + n_real))
        page_ids = s._put(s.mem.pt[slot, : vp.n_lp])

    # Pre-verify snapshot for rollback-by-replay (recurrent carries,
    # windowed ring folds). Taken *after* CoW so forked pages are in it;
    # JAX array immutability makes this a free reference.
    snap = s._states["layers"] if s._needs_replay else None

    toks = np.zeros(vp.bucket, np.int32)
    toks[0] = s._tokens[slot, 0]
    toks[1:n_real] = draft
    toks_dev = s._put(toks[None, :])
    slot_t = jnp.asarray(slot, jnp.int32)
    start_t = jnp.asarray(start, jnp.int32)
    args = [
        s._states["layers"], s._states["pos"], toks_dev,
        slot_t, start_t, jnp.asarray(n_real, jnp.int32),
    ]
    if s._paged:
        args.append(page_ids)
    logits, layers, pos = s.programs.verify(*args)
    s._ev["verifies"].append(vp)

    # Greedy acceptance on host, matching the sample program's cast + argmax.
    lg = np.asarray(logits[0, :n_real, : s.cfg.vocab_size]).astype(np.float32)
    greedy = lg.argmax(axis=-1).astype(np.int32)
    accept = 0
    while accept < k and greedy[accept] == draft[accept]:
        accept += 1
    emitted = [int(t) for t in draft[:accept]] + [int(greedy[accept])]
    n_new = accept + 1  # tokens the cache should have gained

    if accept == k:
        # Full acceptance: the verify pass already cached exactly the
        # accepted run and set pos = start + n_real.
        s._states["layers"] = layers
        s._states["pos"] = pos
    else:
        if s._paged:
            # Return the pages grown for rejected positions (always private:
            # sharing only covers the prompt prefix). Under worst-case
            # reservations the backing stays owed to this slot;
            # reservation-free, it returns to the pool.
            keep = s.mem.pages_for_len(start + n_new)
            removed = s.mem.truncate(
                slot, keep, keep_reservation=s.sched.preemption == "off"
            )
            if removed:
                n_lp = planlib.page_bucket(keep, s.mem.max_pages)
                page_ids = s._put(s.mem.pt[slot, :n_lp])
        if s._needs_replay:
            # State advanced through rejected tokens (recurrence) or
            # rejected writes folded onto live ring entries: re-run the
            # accepted run from the snapshot through the chunk program
            # (chunk_len is traced — no fresh compile per accept count).
            rargs = [
                snap, s._states["pos"], toks_dev, slot_t, start_t,
                jnp.asarray(n_new, jnp.int32),
            ]
            if s._paged:
                rargs.append(page_ids)
            _, rlayers, rpos = s.programs.chunk(*rargs)
            s._states["layers"] = rlayers
            s._states["pos"] = rpos
            s.total_spec_replays += 1
        else:
            # Dense/MLA: garbage past the accepted position is inert under
            # positional masks; only the position needs fixing.
            s._states["layers"] = layers
            s._states["pos"] = s.programs.setpos(
                pos, slot_t, jnp.asarray(start + n_new, jnp.int32)
            )

    s._pos_host[slot] = start + n_new
    rs.spec_steps += 1
    rs.drafted += k
    rs.accepted += accept
    s.total_spec_steps += 1
    s.drafted_tokens_total += k
    s.accepted_tokens_total += accept
    now = time.perf_counter()
    for tok in emitted:
        rs.tokens.append(tok)
        rs.t_tokens.append(now)
        s._tokens[slot, 0] = tok
        s._maybe_finish(rs, now)
        if rs.done:
            break  # stop token mid-run: drop the rest, as plain decode would
    return True
