"""Program layer of the serving core: the jitted step programs.

:class:`ProgramRegistry` owns every compiled program the executor runs —
decode, whole-prompt prefill + admit graft, chunk streaming, speculative
verify, slot reset, copy-on-write page forks, swap-out/in, position fixup,
and sampling — together with the two mesh concerns the step path should
never touch: routing host arrays through fully-replicated ``device_put``
(:meth:`put`) and pinning program outputs to the profile-resolved
NamedShardings (:meth:`constrain_layers`).

Programs are ``jax.jit`` callables; jit's shape cache keys each one by its
argument shapes, so a program effectively compiles once per (program,
bucket) pair — prompt buckets for prefill/admit, (chunk, page) buckets for
chunk, (k, page) buckets for verify. The Python bodies run only when jit
(re)traces, which is exactly what the per-program ``*_traces`` counters on
the registry count: tests pin them to prove the bucket sets are closed and
mesh-independent.

Nothing here owns scheduling state. The registry reads model config,
sharding context, and (sharded) params; slots, queues, and pages belong to
the executor and memory layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.cache import (
    _graft_leaf,
    extract_slot_leaf,
    gather_pages_leaf,
    graft_pages_leaf,
    graft_states,
    insert_slot,
    insert_slot_leaf,
    scatter_pages_leaf,
)
from repro.models import blocks as blk
from repro.serve.step import fresh_slot_layers, init_decode_state
from repro.sharding.rules import ShardingCtx


def paged_cache_bytes(
    cfg, cache_len, n_slots, states, layer_shardings, sctx, mem
) -> dict[str, int]:
    """Actual (peak pages in use) vs contiguous-equivalent cache bytes for
    the paged KV leaves. Zeros when the model has no paged layer."""
    if not mem.paged:
        return {
            "bytes_per_page": 0,
            "peak_bytes": 0,
            "contiguous_bytes": 0,
            "bytes_per_page_per_device": 0,
        }
    # Bytes of one page summed across every paged leaf (a physical page id
    # addresses page-sized storage in every paged layer at once). Sharded,
    # each leaf's per-device share divides by the product of mesh axes its
    # resolved PartitionSpec actually uses — a data-sharded page axis
    # divides too: each device's pool slice holds 1/data of the pages.
    per_page = 0
    per_page_dev = 0
    caps = blk.stack_paged_caps(cfg, cache_len)
    cap_leaves = jax.tree.leaves(caps)
    arr_leaves = jax.tree.leaves(states["layers"])
    sh_leaves = (
        jax.tree.leaves(layer_shardings, is_leaf=lambda x: x is None)
        if layer_shardings is not None
        else [None] * len(arr_leaves)
    )
    mesh_axes = dict(sctx.mesh.shape) if sctx.mesh else {}
    for cap, leafarr, sh in zip(cap_leaves, arr_leaves, sh_leaves):
        if not cap:
            continue
        shape = leafarr.shape
        lead = len(shape) - 4  # stacked layer axis
        n_layers = shape[0] if lead else 1
        page_elems = int(np.prod(shape[lead + 1:]))  # page * kv * hd
        leaf_bytes = n_layers * page_elems * jnp.dtype(leafarr.dtype).itemsize
        per_page += leaf_bytes
        div = 1
        if sh is not None:
            for ax in sh.spec:
                for a in ax if isinstance(ax, tuple) else ((ax,) if ax else ()):
                    div *= mesh_axes.get(a, 1)
        per_page_dev += leaf_bytes // div
    peak = mem.peak_in_use * per_page
    contiguous = n_slots * mem.max_pages * per_page
    return {
        "bytes_per_page": int(per_page),
        "peak_bytes": int(peak),
        "contiguous_bytes": int(contiguous),
        "bytes_per_page_per_device": int(per_page_dev),
    }


def _leaf_page_axis_sharded(arr, sharding) -> bool:
    """True when a pool leaf's physical page axis is mesh-sharded (the
    leading axis, behind the stacked layer axis for 5D leaves)."""
    if sharding is None:
        return False
    spec = sharding.spec
    ax = arr.ndim - 4  # 0 for (P, page, kv, hd), 1 behind a layer axis
    entry = spec[ax] if ax < len(spec) else None
    return bool(entry)


class ProgramRegistry:
    """Compiled programs + trace accounting + sharding glue for one
    scheduler instance. Built once at scheduler construction; the
    executor only ever calls the public program attributes."""

    def __init__(
        self,
        cfg: ModelConfig,
        sctx: ShardingCtx,
        params: Any,
        *,
        cache_len: int,
        layouts: Any,
        caps: Any,
        layer_shardings: Any,
        page_size: int = 0,
        paged: bool = False,
    ):
        self.cfg = cfg
        self.sctx = sctx
        self.params = params
        self._cache_len = cache_len
        self._layouts = layouts
        self._caps = caps
        self._layer_shardings = layer_shardings
        self._replicated = sctx.replicated()
        self._paged = paged

        self.decode_traces = 0  # jit trace count of the decode hot path
        self.prefill_traces = 0  # one per prompt bucket
        self.admit_traces = 0  # one per prompt bucket
        self.chunk_traces = 0  # one per (chunk, page) bucket
        self.swap_traces = 0  # swap-out + swap-in programs
        self.cow_traces = 0  # copy-on-write fork programs (per fork count)
        self.verify_traces = 0  # one per (k-bucket, page-bucket) pair

        def _slot_surgery_trees():
            template = init_decode_state(cfg, 1, cache_len)["layers"]
            c = caps if caps is not None else jax.tree.map(lambda _: 0, template)
            return c, template

        def _freeze_inactive(active, new_layers, old_layers):
            # Inactive slots (free, or PREFILLING between chunks) must keep
            # their per-slot states verbatim across other slots' decode
            # steps: positional KV survives by write-before-read, but a
            # recurrence would absorb the masked slot's garbage token.
            # Shared-pool leaves have no batch row to freeze — their
            # garbage writes stay behind the trash page / the positions the
            # next chunk overwrites.
            c, template = _slot_surgery_trees()

            def leaf(cap, new, old, t):
                if cap:
                    return new
                nd, td = jnp.asarray(new), jnp.asarray(t)
                if nd.shape == td.shape:  # n_slots == 1
                    return jnp.where(active[0], nd, old)
                ax = [i for i in range(nd.ndim) if nd.shape[i] != td.shape[i]][0]
                shape = [1] * nd.ndim
                shape[ax] = nd.shape[ax]
                return jnp.where(active.reshape(shape), nd, old)

            return jax.tree.map(leaf, c, new_layers, old_layers, template)

        def _decode_fn(params, states, token, active):
            # Python body runs only when jit (re)traces: counts compilations.
            self.decode_traces += 1
            logits, new_states = lm.decode_step(params, cfg, states, token, sctx)
            # Freeze inactive slots in place (position and per-slot states).
            new_pos = jnp.where(active, new_states["pos"], states["pos"])
            out = {
                "layers": self.constrain_layers(
                    _freeze_inactive(active, new_states["layers"], states["layers"])
                ),
                "pos": new_pos,
            }
            if "page_table" in new_states:
                out["page_table"] = new_states["page_table"]
            return logits, out

        self.decode = jax.jit(_decode_fn)

        def _prefill_fn(p, b):
            self.prefill_traces += 1
            return lm.prefill(p, cfg, b, sctx)

        self.prefill = jax.jit(_prefill_fn)

        if paged:

            def _admit_fn(layers, pos, prefill_layers, slot, page_ids, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(cfg, 1, cache_len)["layers"]

                def leaf(lay, full, tgt, src):
                    if lay.kind == "paged":  # shared-pool KV leaf: scatter pages
                        return graft_pages_leaf(
                            full, src, page_ids, prompt_len, lay.cap, page_size
                        )
                    return insert_slot_leaf(
                        full, _graft_leaf(tgt, src, prompt_len, lay), slot, lay
                    )

                new_layers = self.constrain_layers(
                    jax.tree.map(leaf, layouts, layers, target, prefill_layers)
                )
                return new_layers, pos.at[slot].set(prompt_len)

        else:

            def _admit_fn(layers, pos, prefill_layers, slot, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(cfg, 1, cache_len)
                slot_layers = graft_states(
                    target["layers"], prefill_layers, prompt_len, layouts=layouts
                )
                new_layers = self.constrain_layers(
                    insert_slot(layers, slot_layers, slot, layouts=layouts)
                )
                return new_layers, pos.at[slot].set(prompt_len)

        # slot and prompt_len are traced, so admission compiles once per
        # prefill *shape* — with bucketing, once per bucket.
        self.admit = jax.jit(_admit_fn)

        # -- unified-step programs (chunk streaming, slot reset, swap) -------
        def _chunk_body(layers, pos, tokens, slot, start, chunk_len, page_ids,
                        all_logits=False):
            c, template = _slot_surgery_trees()
            slot_layers = jax.tree.map(
                lambda lay, cap, full, t: (
                    full if cap else extract_slot_leaf(full, t, slot, lay)
                ),
                layouts, c, layers, template,
            )
            states: dict[str, Any] = {"layers": slot_layers, "pos": start}
            if page_ids is not None:
                states["page_table"] = page_ids[None, :]
            logits, new = lm.chunk_step(
                self.params, cfg, states, tokens, chunk_len, sctx,
                all_logits=all_logits,
            )
            new_layers = self.constrain_layers(
                jax.tree.map(
                    lambda lay, cap, full, s: (
                        s if cap else insert_slot_leaf(full, s, slot, lay)
                    ),
                    layouts, c, layers, new["layers"],
                )
            )
            return logits, new_layers, pos.at[slot].set(start + chunk_len)

        if paged:

            def _chunk_fn(layers, pos, tokens, slot, start, chunk_len, page_ids):
                self.chunk_traces += 1
                return _chunk_body(layers, pos, tokens, slot, start, chunk_len, page_ids)

            def _verify_fn(layers, pos, tokens, slot, start, chunk_len, page_ids):
                self.verify_traces += 1
                return _chunk_body(
                    layers, pos, tokens, slot, start, chunk_len, page_ids,
                    all_logits=True,
                )

        else:

            def _chunk_fn(layers, pos, tokens, slot, start, chunk_len):
                self.chunk_traces += 1
                return _chunk_body(layers, pos, tokens, slot, start, chunk_len, None)

            def _verify_fn(layers, pos, tokens, slot, start, chunk_len):
                self.verify_traces += 1
                return _chunk_body(
                    layers, pos, tokens, slot, start, chunk_len, None,
                    all_logits=True,
                )

        self.chunk = jax.jit(_chunk_fn)
        # Verify program for speculative decoding: the chunk body with
        # logits at *every* position, so one call scores a whole draft.
        self.verify = jax.jit(_verify_fn)
        # Position-only fixup for partial acceptance on archs whose caches
        # tolerate garbage past the accepted position (dense / MLA).
        self.setpos = jax.jit(lambda pos, slot, val: pos.at[slot].set(val))

        def _reset_fn(layers, pos, slot, pos_val):
            # Reset the slot's per-slot leaves to the empty-recurrence state
            # so a chunked prefill starts from what a from-scratch prefill
            # would derive. Pool leaves stay: the trash-pointed table row
            # isolates them. ``pos_val`` is the adopted-prefix length (0
            # without sharing): the slot's frozen decode position must sit
            # at the first *unadopted* logical page, or the inactive slot's
            # garbage decode writes would land inside a shared page.
            c, _ = _slot_surgery_trees()
            fresh = fresh_slot_layers(cfg, cache_len)
            new_layers = self.constrain_layers(
                jax.tree.map(
                    lambda lay, cap, full, t: (
                        full if cap else insert_slot_leaf(full, t, slot, lay)
                    ),
                    layouts, c, layers, fresh,
                )
            )
            return new_layers, pos.at[slot].set(pos_val)

        self.reset = jax.jit(_reset_fn)

        if paged:

            def _copy_pages(full, src_ids, dst_ids):
                if full.ndim == 5:  # stacked groups: leading layer axis
                    return full.at[:, dst_ids].set(full[:, src_ids])
                return full.at[dst_ids].set(full[src_ids])

            def _cow_fn(layers, src_ids, dst_ids):
                # Fork shared pages: copy page contents src -> dst in every
                # pool leaf (one program per fork count; essentially never
                # runs — the scheduler's write pattern stays past adopted
                # spans — but keeps CoW safety local to the pool). Sharded,
                # the copy runs under shard_map per pool leaf when the page
                # axis is *replicated*: every device owns its
                # kv_heads/head_dim slice of both pages and forks them
                # locally, no cross-device traffic. A page axis sharded
                # over "data" means the global ids index blocks that live
                # on different devices, so those leaves copy under plain
                # jit and let GSPMD lower the gather/scatter (forks stay
                # within one shard's block, so the copy is still local in
                # practice — XLA just has to prove it).
                self.cow_traces += 1
                if self._layer_shardings is None:
                    return jax.tree.map(
                        lambda cap, full: (
                            _copy_pages(full, src_ids, dst_ids) if cap else full
                        ),
                        caps, layers,
                    )

                def leaf(cap, full, sh):
                    if not cap:
                        return full
                    if _leaf_page_axis_sharded(full, sh):
                        return _copy_pages(full, src_ids, dst_ids)
                    spec = sh.spec
                    return shard_map(
                        _copy_pages,
                        mesh=sctx.mesh,
                        in_specs=(spec, P(), P()),
                        out_specs=spec,
                        check=False,
                    )(full, src_ids, dst_ids)

                return jax.tree.map(leaf, caps, layers, self._layer_shardings)

            self.cow = jax.jit(_cow_fn)

            def _swap_out_fn(layers, page_ids, slot):
                self.swap_traces += 1
                c, template = _slot_surgery_trees()
                return jax.tree.map(
                    lambda lay, cap, full, t: (
                        gather_pages_leaf(full, page_ids)
                        if cap
                        else extract_slot_leaf(full, t, slot, lay)
                    ),
                    layouts, c, layers, template,
                )

            def _swap_in_fn(layers, pos, snap, page_ids, slot, pos_val):
                self.swap_traces += 1
                c, _ = _slot_surgery_trees()
                new_layers = self.constrain_layers(
                    jax.tree.map(
                        lambda lay, cap, full, s: (
                            scatter_pages_leaf(full, s, page_ids)
                            if cap
                            else insert_slot_leaf(full, s, slot, lay)
                        ),
                        layouts, c, layers, snap,
                    )
                )
                return new_layers, pos.at[slot].set(pos_val)

            self.swap_out = jax.jit(_swap_out_fn)
            self.swap_in = jax.jit(_swap_in_fn)

        def _sample_fn(logits, temps, key):
            lg = logits[:, : cfg.vocab_size].astype(jnp.float32)
            greedy = jnp.argmax(lg, axis=-1)
            scaled = lg / jnp.maximum(temps, 1e-6)[:, None]
            sampled = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)

        self.sample = jax.jit(_sample_fn)

    # -- sharding glue --------------------------------------------------------
    def put(self, x):
        """Host array -> device; fully replicated over the mesh when sharded
        so every jit program sees one stable input layout per bucket."""
        if self._replicated is None:
            return jnp.asarray(x)
        return jax.device_put(np.asarray(x), self._replicated)

    def constrain_layers(self, layers):
        """Pin a step program's output layer tree to the profile-resolved
        NamedShardings (identity without a mesh) — state placement can
        never drift between steps, whatever XLA would have inferred."""
        if self._layer_shardings is None:
            return layers
        return jax.tree.map(
            jax.lax.with_sharding_constraint, layers, self._layer_shardings
        )
