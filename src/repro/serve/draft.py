"""Draft-token proposers for speculative decoding.

A :class:`Drafter` guesses the next ``k`` tokens of a sequence from its
context alone; the scheduler then *verifies* the whole guess in one
``chunk_step`` call (all-position logits) and keeps the longest greedy-
matching run — emitting ``accepted + 1`` tokens per model step instead of
one. The interface is deliberately model-free (``propose(context, k)``):
the built-in drafters are self-speculative (no second model), and a
learned draft model slots in behind the same two methods.

Drafters are *advisory*: a wrong proposal costs one rejected verify
position, never a wrong token — greedy acceptance keeps outputs
token-identical to plain decoding by construction.
"""
from __future__ import annotations

import abc

import numpy as np


class Drafter(abc.ABC):
    """Proposes up to ``k`` draft tokens continuing ``context``.

    ``context`` is the full known token sequence (prompt ++ generated so
    far, including the token about to be fed to the model). Return a
    ``(<=k,)`` int array — empty means "no guess" and the scheduler runs a
    plain decode step for that slot. Must be deterministic for a given
    context (greedy identity tests replay workloads)."""

    @abc.abstractmethod
    def propose(self, context: np.ndarray, k: int) -> np.ndarray: ...

    def reset(self) -> None:  # pragma: no cover - optional hook
        """Forget any cross-request state (called between workloads)."""


class NgramDrafter(Drafter):
    """Self-speculative prompt-lookup drafting (no draft model).

    Finds the most recent *earlier* occurrence of the context's trailing
    n-gram and proposes the tokens that followed it — longest n first, so
    a more specific match wins. Catches the repetition structure real
    prompts are full of (copied spans, code idioms, "assistant echoes the
    question") at zero model cost; on contexts with no self-overlap it
    proposes nothing and the slot falls back to plain decoding.
    """

    def __init__(self, max_ngram: int = 4, min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got {min_ngram}..{max_ngram}")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(context, np.int32).reshape(-1)
        L = len(ctx)
        if k < 1 or L < self.min_ngram + 1:
            return np.zeros(0, np.int32)
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            suffix = ctx[L - n :]
            # Earlier occurrences of the trailing n-gram: windows over
            # ctx[:-1] start at i <= L-1-n, so the suffix's own occurrence
            # (start L-n) is excluded and every match leaves at least one
            # continuation token. The continuation may overlap the suffix —
            # that is the periodic case drafting exists for.
            windows = np.lib.stride_tricks.sliding_window_view(ctx[:-1], n)
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            if hits.size == 0:
                continue
            start = int(hits[-1]) + n
            cont = ctx[start : start + k]
            if cont.size:
                return cont.astype(np.int32)
        return np.zeros(0, np.int32)


class ReplayDrafter(Drafter):
    """Oracle-style drafter that replays known full sequences.

    Holds complete token sequences (prompt ++ continuation); when a
    context is a strict prefix of one of them, proposes the next ``k``
    tokens of that sequence. Stands in for a perfect draft model: the
    benchmark's high-acceptance upper bound, and the deterministic
    acceptance path property tests drive."""

    def __init__(self, sequences):
        self._seqs = [np.asarray(s, np.int32).reshape(-1) for s in sequences]

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(context, np.int32).reshape(-1)
        L = len(ctx)
        for seq in self._seqs:
            if len(seq) > L and np.array_equal(seq[:L], ctx):
                return seq[L : L + k].copy()
        return np.zeros(0, np.int32)


class ScriptDrafter(Drafter):
    """Proposes from a fixed script of drafts (test harness).

    Each ``propose`` call pops the next entry — an int array proposed
    verbatim (truncated to ``k``) — and returns empty once the script is
    exhausted. Lets tests force exact acceptance/rejection patterns."""

    def __init__(self, drafts):
        self._drafts = [np.asarray(d, np.int32).reshape(-1) for d in drafts]
        self.calls = 0

    def propose(self, context: np.ndarray, k: int) -> np.ndarray:
        self.calls += 1
        if not self._drafts:
            return np.zeros(0, np.int32)
        return self._drafts.pop(0)[:k]
