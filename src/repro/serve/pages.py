"""Paged KV block pool for serving.

Instead of one contiguous ``cache_len`` KV row per slot, every paged
attention layer stores its cache as a pool of fixed-size pages
``(n_pages + 1, page_size, n_kv, head_dim)`` shared across all slots; a
per-slot page table (fixed-shape ``(n_slots, max_pages)`` int32, values
change but never the shape) maps a slot's logical page ``j`` — token
positions ``[j * page_size, (j + 1) * page_size)`` after ring folding —
to a physical page id. The same page id addresses page-sized storage in
every paged layer's pool simultaneously (one table, many pools), so the
table is allocated once per slot, not per layer.

The extra physical page (index ``n_pages``) is the **trash page**: every
unused page-table entry points at it. Retired slots keep riding the
fixed-shape decode step with a frozen position, and with a shared pool
their garbage writes could corrupt a new tenant — pointing their whole
table row at the trash page confines those writes to storage nobody
reads (positional validity masks it everywhere else).

``PagePool`` is the host-side allocator. Admission **reserves** a
request's worst-case page count (prompt + max_new_tokens, ring-folded)
so that mid-decode growth can never fail — the OOM-backpressure path is
purely at admission time: if the pool cannot cover the reservation the
request stays queued (deferred, never a corrupted live page). Pages are
physically allocated lazily: the prompt's pages at admit, one more
whenever decode crosses a page boundary, all returned at retirement.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.models.blocks import paged_kv_kinds


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclass(frozen=True)
class PageLayout:
    """Static geometry of a paged serving cache."""

    page_size: int  # tokens per page
    n_pages: int  # physical pages in the pool (excluding the trash page)
    span: int  # logical token capacity a single slot can address

    @property
    def max_pages(self) -> int:
        """Page-table width: logical pages per slot."""
        return cdiv(self.span, self.page_size)

    @property
    def total_pages(self) -> int:
        """Physical pool length including the trash page."""
        return self.n_pages + 1

    @property
    def trash(self) -> int:
        """Physical id of the trash page (see module docstring)."""
        return self.n_pages

    def pages_for_len(self, length: int) -> int:
        """Pages covering logical positions written by ``length`` tokens
        (ring folding caps the footprint at ``span``)."""
        if length <= 0 or self.span == 0:
            return 0
        return cdiv(min(length, self.span), self.page_size)


def model_page_span(cfg: ModelConfig, cache_len: int) -> int:
    """Logical token capacity that needs page backing for ``cfg``.

    Dense KV layers address ``cache_len`` logical slots; windowed layers
    ring-fold into ``window_size`` slots (they reuse the leading
    ``ceil(window / page)`` entries of the same table). Models with no
    paged layer kind (pure recurrent, MLA, enc-dec) need zero pages and
    run the per-slot contiguous layout unchanged.
    """
    kinds = paged_kv_kinds(cfg)
    span = 0
    if kinds & {"attn_mlp", "attn_moe"}:
        span = cache_len
    if "local_attn" in kinds:
        span = max(span, cfg.window_size)
    return span


class PagePool:
    """Host-side page allocator with worst-case reservations.

    Invariants (property-tested in ``tests/test_serve_pages.py``):
      * a physical page is held by at most one slot (no aliasing),
      * ``len(free) + sum(allocated)`` is constant (no leaks),
      * ``sum(reserved - allocated) <= len(free)`` — growth up to each
        slot's reservation can never fail.
    """

    def __init__(self, layout: PageLayout):
        self.layout = layout
        self._free: list[int] = list(range(layout.n_pages - 1, -1, -1))
        self._allocated: dict[int, list[int]] = {}  # slot -> page ids
        self._reserved: dict[int, int] = {}  # slot -> reserved page count
        self.peak_in_use = 0
        self.peak_reserved = 0

    # -- accounting ---------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return sum(len(p) for p in self._allocated.values())

    @property
    def reserved(self) -> int:
        return sum(self._reserved.values())

    def available(self) -> int:
        """Pages admissible to a *new* reservation: free pages minus the
        backing still owed to existing reservations."""
        owed = sum(
            self._reserved[s] - len(self._allocated.get(s, ()))
            for s in self._reserved
        )
        return len(self._free) - owed

    def allocated(self, slot: int) -> list[int]:
        return self._allocated.get(slot, [])

    def can_reserve(self, n: int) -> bool:
        return n <= self.available()

    # -- lifecycle ----------------------------------------------------------
    def reserve(self, slot: int, n: int) -> None:
        if slot in self._reserved:
            raise ValueError(f"slot {slot} already holds a reservation")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"pool overcommit: reserve({n}) with only {self.available()} "
                f"available of {self.layout.n_pages}"
            )
        self._reserved[slot] = n
        self._allocated[slot] = []
        self.peak_reserved = max(self.peak_reserved, self.reserved)

    def grow_to(self, slot: int, n_total: int) -> list[int]:
        """Allocate pages until ``slot`` holds ``n_total``; returns the new
        page ids. Never fails within the slot's reservation."""
        held = self._allocated[slot]
        if n_total > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: grow to {n_total} exceeds reservation "
                f"{self._reserved[slot]}"
            )
        new = []
        while len(held) < n_total:
            new.append(self._free.pop())
            held.append(new[-1])
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return new

    def extend_to(self, slot: int, n_total: int) -> bool:
        """Raise ``slot``'s reservation to ``n_total`` pages if the pool can
        back it; returns False (reservation unchanged) on OOM.

        This is the *reservation-free admission* primitive: instead of
        reserving a request's worst case up front, the scheduler reserves
        pages incrementally — per prefill chunk and per decode page-boundary
        crossing — and reacts to a False return by preempting a victim
        (swap/recompute) or deferring. ``reserve(slot, 0)`` registers the
        slot first.
        """
        cur = self._reserved.get(slot)
        if cur is None:
            raise ValueError(f"slot {slot} holds no reservation to extend")
        if n_total <= cur:
            return True
        if n_total - cur > self.available():
            return False
        self._reserved[slot] = n_total
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        return True

    def reset_peaks(self) -> None:
        """Restart peak tracking (e.g. after a warmup phase) from the
        current occupancy."""
        self.peak_in_use = self.in_use
        self.peak_reserved = self.reserved

    def release(self, slot: int) -> None:
        """Free every page the slot holds and drop its reservation."""
        for pid in self._allocated.pop(slot, []):
            self._free.append(pid)
        self._reserved.pop(slot, None)

    def stats(self) -> dict[str, int]:
        return {
            "n_pages": self.layout.n_pages,
            "page_size": self.layout.page_size,
            "pages_in_use": self.in_use,
            "pages_reserved": self.reserved,
            "pages_free": self.n_free,
            "peak_pages_in_use": self.peak_in_use,
            "peak_pages_reserved": self.peak_reserved,
        }
