"""Paged KV block pool for serving: refcounted pages with copy-on-write
prefix sharing.

Instead of one contiguous ``cache_len`` KV row per slot, every paged
attention layer stores its cache as a pool of fixed-size pages
``(n_pages + 1, page_size, n_kv, head_dim)`` shared across all slots; a
per-slot page table (fixed-shape ``(n_slots, max_pages)`` int32, values
change but never the shape) maps a slot's logical page ``j`` — token
positions ``[j * page_size, (j + 1) * page_size)`` after ring folding —
to a physical page id. The same page id addresses page-sized storage in
every paged layer's pool simultaneously (one table, many pools), so the
table is allocated once per slot, not per layer.

The extra physical page (index ``n_pages``) is the **trash page**: every
unused page-table entry points at it. Retired slots keep riding the
fixed-shape decode step with a frozen position, and with a shared pool
their garbage writes could corrupt a new tenant — pointing their whole
table row at the trash page confines those writes to storage nobody
reads (positional validity masks it everywhere else).

**Refcounted sharing.** A physical page may appear in several slots'
tables at once: each page carries a refcount (the number of slots whose
table maps it) and is freed only when that count reaches zero. Full
prompt-prefix pages are content-addressed through a **prefix index** —
``prefix_page_keys`` hashes a prompt at page granularity into a chain of
keys, a completed page is registered under its key, and a later request
whose prompt starts with the same tokens *adopts* the existing physical
pages instead of recomputing them (``adopt_prefix``): N requests sharing
a system prompt pay one set of pages and near-zero warm-prefix TTFT.
Pages whose refcount drops to zero while still indexed are parked in an
LRU *cached* list — immediately reusable by the next adopter, reclaimed
(and unindexed) only when the free list runs dry.

**Copy-on-write.** Shared pages are immutable by construction — only
*full* prompt pages are indexed, adoption is page-aligned, and both the
chunked-prefill and the decode write paths only ever write at or past
the first unadopted position. ``prepare_write`` enforces that invariant
locally anyway: before a slot writes token range ``[start, stop)`` the
scheduler calls it, and any page in that range with refcount > 1 is
forked to a private copy (the caller re-points its table entry and
copies the device page), while a refcount-1 page that is still indexed
is simply unindexed (its content is about to diverge from its key).

``PagePool`` is the host-side allocator. Admission **reserves** page
counts (worst-case under ``preemption="off"``, incrementally otherwise)
so that growth within a reservation can never fail; adopted pages raise
a slot's reservation and allocation together, so sharing never consumes
the backing owed to other slots. The owed backing — the gap between
reservations and allocations that ``available()`` must protect — is
maintained incrementally (``_owed``), not recomputed per call: the
scheduler asks on every prefill chunk and decode page-boundary crossing.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.blocks import paged_kv_kinds


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def prefix_page_keys(
    tokens: np.ndarray, page_size: int, n_pages: int | None = None
) -> list[bytes]:
    """Hash a token vector into its chain of full-page prefix keys.

    ``keys[j]`` digests tokens ``[0, (j + 1) * page_size)`` — each key
    extends the previous one, so two prompts share ``keys[:k]`` iff they
    share their first ``k * page_size`` tokens. Only *full* pages get a
    key: a partial trailing page is never indexed (it is still written).
    """
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    n = len(toks) // page_size if n_pages is None else n_pages
    keys: list[bytes] = []
    h = b""
    for j in range(n):
        h = hashlib.blake2b(
            h + toks[j * page_size : (j + 1) * page_size].tobytes(), digest_size=16
        ).digest()
        keys.append(h)
    return keys


@dataclass(frozen=True)
class PageLayout:
    """Static geometry of a paged serving cache."""

    page_size: int  # tokens per page
    n_pages: int  # physical pages in the pool (excluding trash pages)
    span: int  # logical token capacity a single slot can address
    # Data-parallel pool partitioning (serve/memory.py): the allocatable
    # pages split into `data_shards` equal blocks, each carrying its own
    # trash row as the block's last physical page so a shard's garbage
    # writes stay on the devices that own its slice. 1 = the classic
    # single-pool layout with one trailing trash page.
    data_shards: int = 1

    @property
    def max_pages(self) -> int:
        """Page-table width: logical pages per slot."""
        return cdiv(self.span, self.page_size)

    @property
    def total_pages(self) -> int:
        """Physical pool length including the trash page(s)."""
        return self.n_pages + self.data_shards

    @property
    def trash(self) -> int:
        """Physical id of the default trash page (the global last row —
        model code uses it as the write sink for pad tokens; per-slot
        rows use their own shard's trash, see ``MemoryManager.trash_of``)."""
        return self.total_pages - 1

    def pages_for_len(self, length: int) -> int:
        """Pages covering logical positions written by ``length`` tokens
        (ring folding caps the footprint at ``span``)."""
        if length <= 0 or self.span == 0:
            return 0
        return cdiv(min(length, self.span), self.page_size)


def model_page_span(cfg: ModelConfig, cache_len: int) -> int:
    """Logical token capacity that needs page backing for ``cfg``.

    Dense KV layers address ``cache_len`` logical slots; windowed layers
    ring-fold into ``window_size`` slots (they reuse the leading
    ``ceil(window / page)`` entries of the same table). Models with no
    paged layer kind (pure recurrent, MLA, enc-dec) need zero pages and
    run the per-slot contiguous layout unchanged.
    """
    kinds = paged_kv_kinds(cfg)
    span = 0
    if kinds & {"attn_mlp", "attn_moe"}:
        span = cache_len
    if "local_attn" in kinds:
        span = max(span, cfg.window_size)
    return span


class PagePool:
    """Host-side refcounted page allocator with prefix sharing + CoW.

    Invariants (property-tested in ``tests/test_serve_pages.py``):
      * refcounts are never negative; a page is freed (or cached) exactly
        when its refcount reaches zero,
      * ``free + cached + in_use`` partitions the pool (conservation —
        a page shared by k slots is *one* in-use page, not k),
      * ``_owed`` always equals ``sum(reserved - allocated)`` recomputed,
      * ``sum(reserved - allocated) <= free + cached`` — growth up to each
        slot's reservation can never fail,
      * after ``prepare_write`` over a range, every page in that range is
        exclusively owned (refcount 1) and unindexed.
    """

    def __init__(self, layout: PageLayout):
        self.layout = layout
        self._free: list[int] = list(range(layout.n_pages - 1, -1, -1))
        self._allocated: dict[int, list[int]] = {}  # slot -> page ids (logical order)
        self._reserved: dict[int, int] = {}  # slot -> reserved page count
        self._ref: dict[int, int] = {}  # pid -> #slots mapping it (absent == 0)
        self._index: dict[bytes, int] = {}  # prefix key -> pid
        self._key_of: dict[int, bytes] = {}  # pid -> its index key
        # ref-0 pages still holding indexed prefix content, LRU order
        # (oldest first; dict preserves insertion order).
        self._cached: dict[int, None] = {}
        self._owed = 0  # sum(reserved - allocated), maintained incrementally
        self.peak_in_use = 0
        self.peak_reserved = 0
        self.cow_forks = 0
        self.adopted_total = 0
        self.cache_evictions = 0

    # -- accounting ---------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    @property
    def in_use(self) -> int:
        """Distinct physical pages mapped by at least one slot."""
        return len(self._ref)

    @property
    def reserved(self) -> int:
        return sum(self._reserved.values())

    def available(self) -> int:
        """Pages admissible to a *new* reservation: free + evictable
        cached pages minus the backing still owed to existing
        reservations. O(1) — ``_owed`` is maintained incrementally."""
        return len(self._free) + len(self._cached) - self._owed

    def owed_recomputed(self) -> int:
        """The owed backing recomputed from scratch (test oracle for the
        incremental ``_owed`` counter)."""
        return sum(
            self._reserved[s] - len(self._allocated.get(s, ()))
            for s in self._reserved
        )

    def allocated(self, slot: int) -> list[int]:
        return self._allocated.get(slot, [])

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def can_reserve(self, n: int) -> bool:
        return n <= self.available()

    # -- internal -----------------------------------------------------------
    def _drop_index(self, pid: int) -> None:
        key = self._key_of.pop(pid, None)
        if key is not None and self._index.get(key) == pid:
            del self._index[key]

    def _take_free(self) -> int:
        """A writable physical page: the free list first, then evict the
        least-recently-released cached prefix page (unindexing it)."""
        if self._free:
            return self._free.pop()
        if self._cached:
            pid = next(iter(self._cached))
            del self._cached[pid]
            self._drop_index(pid)
            self.cache_evictions += 1
            return pid
        raise RuntimeError(
            "page pool exhausted: no free or cached page to take "
            "(accounting bug, or a CoW fork beyond the pool's backing)"
        )

    # -- lifecycle ----------------------------------------------------------
    def reserve(self, slot: int, n: int) -> None:
        if slot in self._reserved:
            raise ValueError(f"slot {slot} already holds a reservation")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"pool overcommit: reserve({n}) with only {self.available()} "
                f"available of {self.layout.n_pages}"
            )
        self._reserved[slot] = n
        self._allocated[slot] = []
        self._owed += n
        self.peak_reserved = max(self.peak_reserved, self.reserved)

    def grow_to(self, slot: int, n_total: int) -> list[int]:
        """Allocate fresh private pages until ``slot`` holds ``n_total``;
        returns the new page ids. Never fails within the reservation."""
        held = self._allocated[slot]
        if n_total > self._reserved[slot]:
            raise RuntimeError(
                f"slot {slot}: grow to {n_total} exceeds reservation "
                f"{self._reserved[slot]}"
            )
        new = []
        while len(held) < n_total:
            pid = self._take_free()
            self._ref[pid] = 1
            new.append(pid)
            held.append(pid)
            self._owed -= 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return new

    def extend_to(self, slot: int, n_total: int) -> bool:
        """Raise ``slot``'s reservation to ``n_total`` pages if the pool can
        back it; returns False (reservation unchanged) on OOM.

        This is the *reservation-free admission* primitive: instead of
        reserving a request's worst case up front, the scheduler reserves
        pages incrementally — per prefill chunk and per decode page-boundary
        crossing — and reacts to a False return by preempting a victim
        (swap/recompute) or deferring. ``reserve(slot, 0)`` registers the
        slot first.
        """
        cur = self._reserved.get(slot)
        if cur is None:
            raise ValueError(f"slot {slot} holds no reservation to extend")
        if n_total <= cur:
            return True
        if n_total - cur > self.available():
            return False
        self._reserved[slot] = n_total
        self._owed += n_total - cur
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        return True

    # -- prefix sharing -----------------------------------------------------
    def adopt_prefix(self, slot: int, keys: list[bytes]) -> int:
        """Map the longest indexed run of ``keys`` into ``slot``'s table.

        Each hit bumps the page's refcount (reviving it from the cached
        list if idle) and raises the slot's reservation in step with its
        allocation, so adoption consumes no free-list backing and can
        never fail. Must run right after ``reserve`` (before any growth):
        adopted pages are the slot's logical pages ``0..n-1``. Returns
        the number of pages adopted; ``allocated(slot)`` gives their ids.
        """
        if slot not in self._reserved:
            raise ValueError(f"slot {slot} holds no reservation to adopt into")
        held = self._allocated[slot]
        if held:
            raise ValueError("adopt_prefix must precede page growth")
        n = 0
        for key in keys:
            pid = self._index.get(key)
            if pid is None:
                break
            if pid in self._cached:
                del self._cached[pid]
            self._ref[pid] = self._ref.get(pid, 0) + 1
            held.append(pid)
            self._reserved[slot] += 1  # reservation and allocation move together
            n += 1
        if n:
            self.adopted_total += n
            self.peak_in_use = max(self.peak_in_use, self.in_use)
            self.peak_reserved = max(self.peak_reserved, self.reserved)
        return n

    def register_page(self, slot: int, logical: int, key: bytes) -> bool:
        """Index ``slot``'s logical page under its prefix key once its
        content is complete (every position written). First writer wins:
        if the key is already indexed (a concurrent identical prompt) the
        later page stays private. Idempotent."""
        pid = self._allocated[slot][logical]
        if pid in self._key_of or key in self._index:
            return False
        self._index[key] = pid
        self._key_of[pid] = key
        return True

    def lookup_prefix(self, keys: list[bytes]) -> int:
        """Length of the longest indexed run of ``keys`` (no side effects)."""
        n = 0
        for key in keys:
            if key not in self._index:
                break
            n += 1
        return n

    def prepare_write(self, slot: int, start: int, stop: int) -> list[tuple[int, int, int]]:
        """Make token range ``[start, stop)`` of ``slot`` exclusively
        writable. Pages in the range with refcount > 1 are forked to a
        private copy — the table entry is re-pointed here and the caller
        must copy device contents ``old -> new`` and update its mirrors —
        and refcount-1 pages still indexed are unindexed (their content is
        about to diverge from their key). Returns ``(logical, old, new)``
        fork triples (empty in the steady state: the scheduler only ever
        writes at or past the first unadopted position)."""
        held = self._allocated.get(slot)
        if not held or stop <= start:
            return []
        P, span = self.layout.page_size, self.layout.span
        fold = (lambda t: (t % span) // P) if span else (lambda t: t // P)
        if span and stop - start >= span:
            js: list[int] = list(range(len(held)))
        else:
            js = sorted({fold(t) for t in [*range(start, stop, P), stop - 1]})
        forks: list[tuple[int, int, int]] = []
        for j in js:
            if j >= len(held):
                continue
            pid = held[j]
            r = self._ref[pid]
            if r > 1:
                new = self._take_free()
                self._ref[pid] = r - 1
                self._ref[new] = 1
                held[j] = new
                forks.append((j, pid, new))
                self.cow_forks += 1
            elif pid in self._key_of:
                self._drop_index(pid)
        if forks:
            self.peak_in_use = max(self.peak_in_use, self.in_use)
        return forks

    def truncate_to(
        self, slot: int, n_total: int, keep_reservation: bool = False
    ) -> list[int]:
        """Drop ``slot``'s trailing pages until it holds ``n_total``
        (speculative-decoding rollback: pages grown for rejected draft
        tokens are returned). Popped pages are recycled exactly as in
        ``release`` — though in speculative use they are always private
        refcount-1 pages (CoW forking and page-aligned adoption mean
        sharing only ever covers the prompt prefix, and drafts extend past
        it). With ``keep_reservation`` the reservation stays (the freed
        backing becomes owed again — ``preemption="off"`` mode, where the
        worst case was reserved up front); otherwise the reservation
        shrinks with the allocation. Returns the popped page ids (newest
        first) so the caller can re-point table entries at the trash page.
        """
        held = self._allocated.get(slot)
        if held is None:
            raise ValueError(f"slot {slot} holds no allocation to truncate")
        if n_total < 0 or n_total > len(held):
            raise ValueError(
                f"slot {slot}: truncate to {n_total} of {len(held)} pages"
            )
        removed: list[int] = []
        while len(held) > n_total:
            pid = held.pop()
            removed.append(pid)
            r = self._ref[pid] - 1
            if r > 0:
                self._ref[pid] = r
                continue
            del self._ref[pid]
            if pid in self._key_of:
                self._cached[pid] = None
            else:
                self._free.append(pid)
        if removed:
            if keep_reservation:
                self._owed += len(removed)
            else:
                self._reserved[slot] -= len(removed)
        return removed

    # -- retirement ---------------------------------------------------------
    def release(self, slot: int) -> None:
        """Unmap every page the slot holds and drop its reservation. A
        page's storage is recycled only at refcount zero: indexed pages
        park in the cached LRU (future adopters revive them), anonymous
        pages return to the free list."""
        held = self._allocated.pop(slot, [])
        reserved = self._reserved.pop(slot, 0)
        self._owed -= reserved - len(held)
        for pid in held:
            r = self._ref[pid] - 1
            if r > 0:
                self._ref[pid] = r
                continue
            del self._ref[pid]
            if pid in self._key_of:
                self._cached[pid] = None
            else:
                self._free.append(pid)

    def reset_peaks(self) -> None:
        """Restart peak tracking (e.g. after a warmup phase) from the
        current occupancy."""
        self.peak_in_use = self.in_use
        self.peak_reserved = self.reserved

    def stats(self) -> dict[str, int]:
        return {
            "n_pages": self.layout.n_pages,
            "page_size": self.layout.page_size,
            "pages_in_use": self.in_use,
            "pages_reserved": self.reserved,
            "pages_free": self.n_free,
            "pages_cached": self.n_cached,
            "pages_shared": sum(1 for r in self._ref.values() if r > 1),
            "pages_indexed": len(self._index),
            "peak_pages_in_use": self.peak_in_use,
            "peak_pages_reserved": self.peak_reserved,
            "adopted_pages": self.adopted_total,
            "cow_forks": self.cow_forks,
            "cache_evictions": self.cache_evictions,
        }
