"""Request lifecycle types for the continuous-batching scheduler.

A ``Request`` is what a client submits: a prompt plus per-request decoding
policy (max_new_tokens, stop token, temperature). ``RequestState`` is the
scheduler's record of one request as it moves queued -> active -> finished,
including its generated tokens and timing/throughput stats.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any

import numpy as np


class RequestStatus(enum.Enum):
    QUEUED = "queued"  # in the admission queue, no slot yet
    PREFILLING = "prefilling"  # holds a slot, prompt streaming in by chunks
    ACTIVE = "active"  # prefilled into a slot, decoding
    PREEMPTED = "preempted"  # pages reclaimed mid-decode, awaiting resume
    FINISHED = "finished"  # retired (stop token or length)


@dataclass
class Request:
    """One generation request. ``prompt`` is a (P,) int32 token vector;
    ``extras`` carries per-request modality inputs (``prefix_embeds`` /
    ``enc_embeds``) with a leading batch-1 axis. ``tenant`` names the
    submitting tenant for page quotas / weighted-fair admission (every
    request shares one tenant by default, which disables both)."""

    prompt: np.ndarray
    max_new_tokens: int = 32
    stop_token: int = -1  # -1 => never stop early
    temperature: float = 0.0  # 0 => greedy
    extras: dict[str, Any] = field(default_factory=dict)
    tenant: str = "default"

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32)
        if self.prompt.ndim != 1:
            raise ValueError(f"prompt must be (P,), got {self.prompt.shape}")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclass
class RequestState:
    """Scheduler-side state of one request."""

    request: Request
    rid: int
    status: RequestStatus = RequestStatus.QUEUED
    slot: int | None = None
    prompt_len: int = 0  # tokens + modality prefix, set at admission
    tokens: list[int] = field(default_factory=list)
    finish_reason: str | None = None  # "stop" | "length"
    prefill_logits: np.ndarray | None = None  # (1, 1, V) last-position logits
    decode_steps: int = 0  # decode iterations this request rode in
    # Chunked-prefill cursor: prompt tokens already streamed into the cache
    # (counts teacher-forced replay tokens after a recompute resume).
    chunk_pos: int = 0
    replay_tokens: np.ndarray | None = None  # prompt ++ generated, for resume
    preemptions: int = 0
    # Prompt tokens satisfied from the shared prefix index at admission
    # (their pages were adopted, not recomputed — the warm-prefix win).
    adopted_tokens: int = 0
    # Speculative decoding: verify steps this request rode, draft tokens
    # proposed for it, and how many of those the verify pass accepted
    # (each spec step also emits one non-draft bonus token on top).
    spec_steps: int = 0
    drafted: int = 0
    accepted: int = 0
    swap: Any = None  # host-side page/state snapshot while PREEMPTED (swap)
    # Wall-clock stamps (time.perf_counter seconds).
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_finish: float = 0.0
    t_tokens: list[float] = field(default_factory=list)  # per-token stamps

    @property
    def done(self) -> bool:
        return self.status is RequestStatus.FINISHED

    @property
    def ttft_s(self) -> float:
        """Submit -> first token (includes queueing + prefill)."""
        return max(self.t_first_token - self.t_submit, 0.0)

    @property
    def latency_s(self) -> float:
        """Submit -> last token."""
        return max(self.t_finish - self.t_submit, 0.0)

    @property
    def decode_tokens_per_s(self) -> float:
        dt = self.t_finish - self.t_admit
        return len(self.tokens) / dt if dt > 0 else float("inf")

    def inter_token_s(self) -> list[float]:
        """Gaps between consecutive token emissions (the latency a streaming
        client feels mid-generation; long un-chunked prefills of *other*
        requests show up here as spikes)."""
        return [
            b - a for a, b in zip(self.t_tokens, self.t_tokens[1:])
        ]
