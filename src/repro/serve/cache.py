"""Slot-cache surgery for serving.

Two layers of state rewriting, both shape-driven so they work for every
state kind in the model zoo (dense KV, windowed ring KV, MLA compressed,
recurrent h/conv, cross-attention encoder KV) and for scan-stacked group
states with a leading layer axis:

  * ``graft_states`` — move prefill caches (allocated at prompt length S)
    into serving-length caches (cache_len): dense caches left-align, window
    ring buffers place position p at slot ``p % W`` for the last W prompt
    positions, recurrent/equal-shape states copy through. The single axis
    whose size differs between source and target is the cache-sequence axis.
  * ``insert_slot`` — write a single-slot (batch=1) serving-length state
    into slot ``s`` of the batched scheduler state. Here the single
    differing axis is the batch axis; equal shapes mean n_slots == 1.

Both preserve the destination dtype (bf16 caches stay bf16 even when the
prefill ran in fp32).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _graft_leaf(dst: jax.Array, src: jax.Array, prompt_len: int) -> jax.Array:
    d, s = jnp.asarray(dst), jnp.asarray(src)
    if d.shape == s.shape:
        return s.astype(d.dtype)
    if d.ndim != s.ndim:
        raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
    diff = [i for i in range(d.ndim) if d.shape[i] != s.shape[i]]
    if len(diff) != 1:
        raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
    ax = diff[0]  # the cache-sequence axis (works for stacked groups too)
    dm = jnp.moveaxis(d, ax, 0)
    sm = jnp.moveaxis(s, ax, 0)
    W = dm.shape[0]
    if sm.shape[0] >= W:
        # ring buffer: the last W prompt positions land at slot p % W
        tail = sm[-W:]
        pos = jnp.arange(prompt_len - W, prompt_len) % W
        dm = dm.at[pos].set(tail.astype(dm.dtype))
    else:
        # dense cache longer than the prompt: left-aligned
        dm = dm.at[: sm.shape[0]].set(sm.astype(dm.dtype))
    return jnp.moveaxis(dm, 0, ax)


def graft_states(
    target_layers: Any, prefill_layers: Any, prompt_len: int
) -> Any:
    """Graft prefill-length layer states into serving-length layer states.

    ``prompt_len`` must be a Python int (the ring placement is computed
    statically), so jitted callers take it as a static argument.
    """
    return jax.tree.map(
        lambda d, s: _graft_leaf(d, s, prompt_len), target_layers, prefill_layers
    )


def insert_slot(full_layers: Any, slot_layers: Any, slot: jax.Array | int) -> Any:
    """Insert a batch-1 serving-length state pytree at batch index ``slot``.

    ``slot`` may be a traced scalar: admission re-uses one compiled program
    for every slot index.
    """

    def ins(dst: jax.Array, src: jax.Array) -> jax.Array:
        d, s = jnp.asarray(dst), jnp.asarray(src)
        if d.shape == s.shape:  # n_slots == 1
            return s.astype(d.dtype)
        if d.ndim != s.ndim:
            raise ValueError(f"cannot insert slot state {s.shape} -> {d.shape}")
        diff = [i for i in range(d.ndim) if d.shape[i] != s.shape[i]]
        if len(diff) != 1 or s.shape[diff[0]] != 1:
            raise ValueError(f"cannot insert slot state {s.shape} -> {d.shape}")
        ax = diff[0]  # the batch axis
        start = [0] * d.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), tuple(start))

    return jax.tree.map(ins, full_layers, slot_layers)
