"""Slot-cache surgery for serving.

Shape-driven state rewriting that works for every state kind in the model
zoo (dense KV, windowed ring KV, MLA compressed, recurrent h/conv,
cross-attention encoder KV) and for scan-stacked group states with a
leading layer axis:

  * ``graft_states`` — move prefill caches (allocated at prompt length S)
    into serving-length caches (cache_len): dense caches left-align, window
    ring buffers place position p at slot ``p % W`` for the last W prompt
    positions, recurrent/equal-shape states copy through. The single axis
    whose size differs between source and target is the cache-sequence axis.
  * ``insert_slot`` — write a single-slot (batch=1) serving-length state
    into slot ``s`` of the batched scheduler state. Here the single
    differing axis is the batch axis; equal shapes mean n_slots == 1.
  * ``graft_pages_leaf`` — the paged-serving counterpart of graft+insert
    for one dense/windowed KV leaf: the prefill cache is laid out
    page-by-page and scattered into the shared pool at this slot's
    physical page ids (see serve/pages.py).

``prompt_len`` may be a traced scalar: bucketed prefill pads prompts to a
shared length, so the *shapes* here are per-bucket while the true prompt
length is a runtime value. Ring placement handles that with fixed-shape
index arithmetic (invalid entries are routed to a junk row and sliced
off); padded positions beyond ``prompt_len`` may land in the cache as
garbage, which is safe everywhere a cache is read through positional
validity masking plus the decode write-before-read invariant.

Grafts dispatch on explicit :class:`repro.models.schema.LeafLayout`
metadata when a congruent ``layouts`` pytree is supplied (derived from
the state schema's axis names by ``blocks.stack_layouts``): dense leaves
left-align and *refuse* a source longer than the target, ring leaves
fold, copy leaves require exact shapes. Without layouts the legacy
shape-diff guessing is used — kept for direct callers, but the shape
heuristic cannot tell a ring leaf from a dense leaf whose sizes happen
to coincide, which is exactly the mis-graft the metadata closes off.

All grafts preserve the destination dtype (bf16 caches stay bf16 even
when the prefill ran in fp32).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.schema import LeafLayout


def _ring_fill(
    dm: jax.Array,  # (W, ...) destination, moveaxis'd
    sm: jax.Array,  # (S, ...) source with S >= ring capacity
    prompt_len: jax.Array | int,
    cap: int,  # ring modulus (== W here; < L for paged layouts)
) -> jax.Array:
    """Place source positions ``prompt_len - cap .. prompt_len - 1`` at ring
    slot ``p % cap``. Entries with p < 0 (padded prompts shorter than the
    ring) are scattered into a junk row appended past the end."""
    W = dm.shape[0]
    p = prompt_len - cap + jnp.arange(cap)
    gsrc = jnp.take(sm, jnp.clip(p, 0, sm.shape[0] - 1), axis=0)
    slot = jnp.where(p >= 0, p % cap, W)
    padded = jnp.concatenate([dm, jnp.zeros_like(dm[:1])], axis=0)
    return padded.at[slot].set(gsrc.astype(dm.dtype))[:W]


def _graft_leaf(
    dst: jax.Array,
    src: jax.Array,
    prompt_len: jax.Array | int,
    layout: LeafLayout | None = None,
) -> jax.Array:
    d, s = jnp.asarray(dst), jnp.asarray(src)
    if layout is not None:
        if d.ndim != s.ndim:
            raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
        if layout.kind == "copy":
            if d.shape != s.shape:
                raise ValueError(
                    f"copy-layout leaf requires matching shapes, got {s.shape} -> {d.shape}"
                )
            return s.astype(d.dtype)
        ax = layout.seq_axis
        dm = jnp.moveaxis(d, ax, 0)
        sm = jnp.moveaxis(s, ax, 0)
        rest_d = dm.shape[1:]
        rest_s = sm.shape[1:]
        if rest_d != rest_s:
            raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
        W = dm.shape[0]
        if layout.kind == "ring":
            if sm.shape[0] >= W:
                dm = _ring_fill(dm, sm, prompt_len, W)
            else:  # prefill ran at a bucket shorter than the window
                dm = dm.at[: sm.shape[0]].set(sm.astype(dm.dtype))
        elif layout.kind == "dense":
            if sm.shape[0] > W:
                # Without metadata this case used to silently ring-fold.
                raise ValueError(
                    f"dense cache graft source {s.shape} exceeds target {d.shape} "
                    f"along seq axis {ax}"
                )
            dm = dm.at[: sm.shape[0]].set(sm.astype(dm.dtype))
        else:
            raise ValueError(f"cannot graft layout {layout.kind!r} leaf")
        return jnp.moveaxis(dm, 0, ax)
    # Legacy shape-diff guessing (no layout metadata supplied).
    if d.shape == s.shape:
        return s.astype(d.dtype)
    if d.ndim != s.ndim:
        raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
    diff = [i for i in range(d.ndim) if d.shape[i] != s.shape[i]]
    if len(diff) != 1:
        raise ValueError(f"cannot graft cache {s.shape} -> {d.shape}")
    ax = diff[0]  # the cache-sequence axis (works for stacked groups too)
    dm = jnp.moveaxis(d, ax, 0)
    sm = jnp.moveaxis(s, ax, 0)
    W = dm.shape[0]
    if sm.shape[0] >= W:
        # ring buffer: the last W prompt positions land at slot p % W
        dm = _ring_fill(dm, sm, prompt_len, W)
    else:
        # dense cache longer than the prompt: left-aligned
        dm = dm.at[: sm.shape[0]].set(sm.astype(dm.dtype))
    return jnp.moveaxis(dm, 0, ax)


def graft_states(
    target_layers: Any,
    prefill_layers: Any,
    prompt_len: jax.Array | int,
    layouts: Any = None,
) -> Any:
    """Graft prefill-length layer states into serving-length layer states.

    ``prompt_len`` may be a Python int or a traced scalar (one compiled
    program per prefill *shape*, shared by every true length in a bucket).
    ``layouts`` is an optional congruent pytree of :class:`LeafLayout`
    (from ``blocks.stack_layouts``); when given, each leaf's graft is
    dispatched on explicit metadata instead of shape guessing.
    """
    if layouts is None:
        return jax.tree.map(
            lambda d, s: _graft_leaf(d, s, prompt_len), target_layers, prefill_layers
        )
    return jax.tree.map(
        lambda d, s, lay: _graft_leaf(d, s, prompt_len, lay),
        target_layers,
        prefill_layers,
        layouts,
    )


def _batch_axis(
    d_shape: tuple[int, ...],
    s_shape: tuple[int, ...],
    layout: LeafLayout | None,
    what: str,
) -> int:
    """The batch axis of a per-slot leaf: taken from explicit
    :class:`LeafLayout` metadata when supplied (sharded serving relies on
    this — a leaf whose non-batch dim is mesh-sharded can otherwise alias
    the shape-diff heuristic), else located as the single differing axis."""
    if layout is not None and layout.batch_axis >= 0:
        ax = layout.batch_axis
        if s_shape[ax] != 1 or any(
            d_shape[i] != s_shape[i] for i in range(len(d_shape)) if i != ax
        ):
            raise ValueError(f"cannot {what} slot state {s_shape} -> {d_shape}")
        return ax
    diff = [i for i in range(len(d_shape)) if d_shape[i] != s_shape[i]]
    if len(diff) != 1 or s_shape[diff[0]] != 1:
        raise ValueError(f"cannot {what} slot state {s_shape} -> {d_shape}")
    return diff[0]


def insert_slot_leaf(
    dst: jax.Array,
    src: jax.Array,
    slot: jax.Array | int,
    layout: LeafLayout | None = None,
) -> jax.Array:
    """Insert one batch-1 serving-length leaf at batch index ``slot``."""
    d, s = jnp.asarray(dst), jnp.asarray(src)
    if d.shape == s.shape:  # n_slots == 1
        return s.astype(d.dtype)
    if d.ndim != s.ndim:
        raise ValueError(f"cannot insert slot state {s.shape} -> {d.shape}")
    ax = _batch_axis(d.shape, s.shape, layout, "insert")
    start = [0] * d.ndim
    start[ax] = slot
    return jax.lax.dynamic_update_slice(d, s.astype(d.dtype), tuple(start))


def insert_slot(
    full_layers: Any, slot_layers: Any, slot: jax.Array | int, layouts: Any = None
) -> Any:
    """Insert a batch-1 serving-length state pytree at batch index ``slot``.

    ``slot`` may be a traced scalar: admission re-uses one compiled program
    for every slot index. ``layouts`` (optional, congruent LeafLayout tree)
    makes the batch axis explicit per leaf.
    """
    if layouts is None:
        return jax.tree.map(
            lambda d, s: insert_slot_leaf(d, s, slot), full_layers, slot_layers
        )
    return jax.tree.map(
        lambda d, s, lay: insert_slot_leaf(d, s, slot, lay),
        full_layers, slot_layers, layouts,
    )


def extract_slot_leaf(
    full: jax.Array,
    template: jax.Array,
    slot: jax.Array | int,
    layout: LeafLayout | None = None,
) -> jax.Array:
    """Slice one batch row out of a batched serving leaf — the inverse of
    :func:`insert_slot_leaf`. ``template`` is a batch-1 leaf of the target
    shape; the batch axis comes from ``layout`` when supplied, else is
    located per-leaf by shape, so scan-stacked group states need no
    special casing."""
    f, t = jnp.asarray(full), jnp.asarray(template)
    if f.shape == t.shape:  # n_slots == 1
        return f
    if f.ndim != t.ndim:
        raise ValueError(f"cannot extract slot state {f.shape} -> {t.shape}")
    ax = _batch_axis(f.shape, t.shape, layout, "extract")
    start = [0] * f.ndim
    start[ax] = slot
    return jax.lax.dynamic_slice(f, tuple(start), t.shape)


def extract_slot(
    full_layers: Any,
    template_layers: Any,
    slot: jax.Array | int,
    layouts: Any = None,
) -> Any:
    """Extract a batch-1 state pytree at batch index ``slot`` (traced OK)."""
    if layouts is None:
        return jax.tree.map(
            lambda f, t: extract_slot_leaf(f, t, slot), full_layers, template_layers
        )
    return jax.tree.map(
        lambda f, t, lay: extract_slot_leaf(f, t, slot, lay),
        full_layers, template_layers, layouts,
    )


def gather_pages_leaf(pool: jax.Array, page_ids: jax.Array) -> jax.Array:
    """Snapshot one slot's logical span out of a shared page pool:
    ``(max_pages, page, ...)`` in logical order (trash-backed tail entries
    snapshot trash garbage — harmless, they are restored to trash-padded
    table rows whose reads are positionally masked). Handles an optional
    leading scan-stacked layer axis."""
    pool = jnp.asarray(pool)
    if pool.ndim == 5:  # (L, P+1, page, kv, hd) stacked groups
        return jax.vmap(lambda pl_: gather_pages_leaf(pl_, page_ids))(pool)
    return pool[page_ids]


def scatter_pages_leaf(
    pool: jax.Array, snapshot: jax.Array, page_ids: jax.Array
) -> jax.Array:
    """Write a :func:`gather_pages_leaf` snapshot back at (new) physical page
    ids — the swap-in counterpart. Entries of ``page_ids`` beyond the pages
    the slot holds must point at the trash page."""
    pool = jnp.asarray(pool)
    if pool.ndim == 5:
        return jax.vmap(lambda pl_, s_: scatter_pages_leaf(pl_, s_, page_ids))(
            pool, snapshot
        )
    return pool.at[page_ids].set(snapshot.astype(pool.dtype))


def graft_pages_leaf(
    pool: jax.Array,  # (P+1, page, kv, hd) or (L, P+1, page, kv, hd) stacked
    src: jax.Array,  # (1, S, kv, hd) or (L, 1, S, kv, hd) prefill cache
    page_ids: jax.Array,  # (max_pages,) physical ids, trash-padded
    prompt_len: jax.Array | int,
    cap: int,  # logical token capacity (cache_len dense / window ring)
    page_size: int,
) -> jax.Array:
    """Scatter one prefill KV leaf into the shared page pool.

    The prefill cache is first laid out logically — left-aligned for dense
    leaves, ring-folded modulo ``cap`` for windowed leaves — then reshaped
    into pages and scattered at this slot's physical page ids. Entries of
    ``page_ids`` beyond the pages this leaf spans must point at the trash
    page (writing it is always harmless).
    """
    pool, src = jnp.asarray(pool), jnp.asarray(src)
    if pool.ndim not in (4, 5):  # (P+1, page, kv, hd) + optional layer axis
        raise ValueError(f"unexpected paged KV leaf rank: {pool.shape}")
    lead = pool.ndim - 4
    if lead:  # scan-stacked groups: map the leading layer axis
        return jax.vmap(
            lambda pl_, s_: graft_pages_leaf(
                pl_, s_, page_ids, prompt_len, cap, page_size
            )
        )(pool, src)
    s = src[0]  # (S, kv, hd)
    S = s.shape[0]
    n_lp = min(-(-cap // page_size), page_ids.shape[0])
    L = n_lp * page_size
    tail = s.shape[1:]
    if S >= cap:
        # ring-fold: positions prompt_len-cap..prompt_len-1 at slot p % cap
        # (cap may be < L when the window doesn't divide the page size;
        # slots >= cap stay zero and are masked by the window validity).
        logical = _ring_fill(jnp.zeros((L, *tail), pool.dtype), s, prompt_len, cap)
    else:
        logical = jnp.zeros((L, *tail), pool.dtype).at[:S].set(s.astype(pool.dtype))
    return pool.at[page_ids[:n_lp]].set(logical.reshape(n_lp, page_size, *tail))
