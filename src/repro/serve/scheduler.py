"""Continuous-batching request scheduler over a paged KV block pool.

The scheduler owns ``n_slots`` persistent decode slots backed by one batched
decode state. Dense and windowed attention KV caches live in a shared
**page pool** — ``n_pages`` fixed-size pages multiplexed across all slots
through a per-slot page table (see serve/pages.py) — so a slot's cache
footprint is its live tokens rounded up to pages, not a worst-case
``cache_len`` row. MLA compressed caches, recurrent states, and enc-dec
caches keep their per-slot layout behind the same interface; models with
no paged layer kind run exactly the PR-1 contiguous path.

**Unified token-budget step.** With ``chunk_budget`` set, each ``step()``
composes one bounded batch of work: every decoding slot contributes one
token, plus a prefill *chunk* of the oldest prompt still streaming in
(``RequestStatus.PREFILLING``). Long prompts therefore enter the paged
KV over several steps — decode cadence never stalls behind a 4k-token
prefill. Chunk sizes are drawn from a fixed power-of-two bucket set
(``min_chunk`` .. ``pow2_floor(chunk_budget)``), deliberately independent
of the live decode count so the loaded system never meets a chunk shape
the idle warmup didn't compile; per-step work is bounded by
``chunk_budget + n_slots`` tokens. With ``chunk_budget=None`` the PR-1/2
lifecycle is unchanged: whole-prompt prefill + graft at admission.

**Page-aware preemption.** ``preemption="off"`` keeps worst-case page
reservations at admission (prompt + max_new_tokens; OOM backpressure
defers the queue). ``"swap"`` / ``"recompute"`` admit **reservation-free**:
pages are reserved incrementally per chunk and per decode page-boundary
crossing, and when the pool runs dry the LRU decoding slot is preempted —
its pages (and per-slot states) snapshot to host memory (``swap``) or are
dropped and re-derived by re-streaming prompt + generated tokens
(``recompute``). Preempted requests resume ahead of fresh admissions and
continue token-identically (greedy) from where they left off. Multiple
prompts may stream concurrently: when no ACTIVE victim holds reclaimable
pages, a *younger* PREFILLING streamer is restarted instead (streaming
admissions are token-only, so re-streaming is always valid under either
policy), which guarantees the oldest in-flight request can always reclaim
what it needs — the old single-streamer admission gate is gone.

**Prefix sharing.** With ``prefix_sharing`` (fully-paged streaming-capable
models), prompts are hashed at page granularity on admission and full
prompt pages are content-addressed in the pool's prefix index: a request
whose prompt starts with an already-indexed page chain *adopts* those
physical pages (refcount++) instead of recomputing them, then streams only
the unadopted tail — N requests sharing a system prompt pay one set of
pages and near-zero warm-prefix TTFT. Shared pages are copy-on-write:
before any write into an adopted range the pool forks a private copy
(``cow_traces``; never taken on the scheduler's own write pattern, which
only touches positions past the adopted span).

**Multi-tenant admission.** ``tenant_quota`` caps each tenant's summed
worst-case page footprint (quota-blocked tenants are skipped while others
admit); ``tenant_weights`` orders fresh admissions by stride scheduling —
each admit advances its tenant's virtual pass by ``tokens / weight`` — so
a heavy tenant cannot starve a light one. With both unset the admission
queue stays exact-FIFO.

**Speculative decoding.** With ``speculative``, every greedy ACTIVE slot
gets a chance to emit *several* tokens per step: a :class:`Drafter`
proposes up to ``draft_k`` continuation tokens from the token history
alone (the default n-gram prompt-lookup drafter needs no second model),
and one **verify** call — ``lm.chunk_step`` with ``all_logits`` — scores
the pending input token plus the whole draft at once. The logits at
chunk index ``i`` are exactly what sequential decoding would produce
after consuming token ``i``, so greedy acceptance (keep the longest run
where the model's argmax equals the draft) emits ``accepted + 1`` tokens
that are token-identical to plain decoding by construction. Rejection
rollback rides the existing machinery: page growth for the draft is
truncated back (``PagePool.truncate_to``; refcounts preserved — draft
pages are always private), garbage KV beyond the accepted position is
inert under the positional masks for dense/MLA caches, and archs whose
state genuinely advanced (recurrent carries, windowed ring folds) replay
the accepted tokens from a pre-verify snapshot through the already-
compiled chunk program. Verify shapes come from a fixed bucket set (one
trace per (k-bucket, page-bucket)), and speculation composes with
chunked prefill, preemption, prefix sharing, and tenant admission — a
slot that cannot get pages for its draft simply decodes plainly that
step (``spec_fallbacks``).

The decode hot path is shape-stable by construction: tokens ``(n_slots,
1)``, active mask ``(n_slots,)``, positions ``(n_slots,)``, page table
``(n_slots, max_pages)`` int32 — joins, leaves, chunk streaming, page
growth, and preemption only change array *values*, so the step never
recompiles after its single warmup trace (``decode_traces``;
``prefill_traces``/``admit_traces`` count per-bucket compiles of the
legacy path, ``chunk_traces`` per chunk bucket, ``swap_traces`` the
swap-out/in pair, ``verify_traces`` per verify bucket pair). Inactive
slots keep decoding garbage with a frozen position; their writes land in
the trash page (paged) or their own about-to-be-overwritten row
(contiguous), so no live state is ever visible through the masks.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import lm
from repro.serve.cache import (
    _graft_leaf,
    extract_slot_leaf,
    gather_pages_leaf,
    graft_pages_leaf,
    graft_states,
    insert_slot,
    insert_slot_leaf,
    scatter_pages_leaf,
)
from repro.serve.draft import Drafter, NgramDrafter
from repro.serve.pages import (
    PageLayout,
    PagePool,
    cdiv,
    model_page_span,
    prefix_page_keys,
)
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.step import (
    decode_state_shardings,
    fresh_slot_layers,
    init_decode_state,
    init_paged_decode_state,
)
from repro.sharding.rules import ShardingCtx, get_profile

_RECURRENT_KINDS = {"rglru", "mlstm", "slstm"}


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class SchedulerConfig:
    n_slots: int = 4  # concurrent sequences in the batched decode state
    cache_len: int = 256  # per-slot logical cache slots (>= prompt + new tokens for dense)
    seed: int = 0
    keep_finished: int = 1024  # finished RequestStates retained for result()
    # Paged KV pool (dense/windowed attention caches). n_pages=None sizes the
    # pool at capacity parity with the contiguous layout (n_slots full rows);
    # shrink it to multiplex a smaller pool across mixed-size requests.
    paged: bool = True
    page_size: int = 16  # tokens per page
    n_pages: int | None = None
    # Pad prompts to power-of-two buckets so prefill/admit compile once per
    # bucket (auto-disabled for recurrent models, whose states would absorb
    # the pad tokens).
    prefill_buckets: bool = True
    min_bucket: int = 8
    # Unified token-budget step: bounds per-step work at one token per
    # decoding slot plus a prefill chunk of at most pow2_floor(chunk_budget)
    # tokens (power-of-two buckets >= min_chunk). None -> whole-prompt
    # prefill at admission.
    chunk_budget: int | None = None
    min_chunk: int = 16
    # Page-aware preemption (requires chunk_budget): "off" reserves the
    # worst case at admission; "swap" / "recompute" admit reservation-free
    # and reclaim the LRU decoding slot's pages on OOM.
    preemption: str = "off"
    # Content-address full prompt pages and adopt matching pages at
    # admission (copy-on-write protected). Takes effect only for
    # fully-paged streaming-capable models; a no-op everywhere else.
    prefix_sharing: bool = True
    # Multi-tenant admission: cap each tenant's summed worst-case page
    # footprint (None -> unlimited) and order fresh admissions by stride
    # scheduling over per-tenant weights (None -> exact FIFO).
    tenant_quota: int | None = None
    tenant_weights: dict[str, float] | None = None
    # Speculative decoding: draft up to draft_k tokens per greedy ACTIVE
    # slot and verify them in one all-position chunk call, emitting
    # accepted+1 tokens per step (token-identical to plain greedy).
    # drafter=None installs the self-speculative NgramDrafter; any
    # Drafter instance (oracle, learned draft model wrapper) slots in.
    speculative: bool = False
    draft_k: int = 4
    drafter: Drafter | None = None
    # Sharded multi-device stepping: lay the batched decode state — and the
    # page-pool backing arrays — out over a ("data", "model") mesh built at
    # construction (per-leaf PartitionSpecs resolved from the profile's
    # logical-axis rules, replicated fallback when sizes don't divide).
    # None keeps whatever ShardingCtx the caller passed (usually null); a
    # (data, model) tuple builds a test mesh when the passed ctx has no
    # mesh. Page *tables* and refcounts stay host-side either way.
    mesh_shape: tuple[int, int] | None = None
    sharding_profile: str = "decode_default"


class Scheduler:
    def __init__(
        self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, sched: SchedulerConfig
    ):
        self.cfg = cfg
        self.params = params
        if sched.mesh_shape is not None and sctx.mesh is None:
            d, m = (int(x) for x in sched.mesh_shape)
            if d * m > 1:
                from repro.launch.mesh import make_test_mesh

                sctx = ShardingCtx(
                    make_test_mesh(data=d, model=m),
                    get_profile(sched.sharding_profile),
                )
        self.sctx = sctx
        self.sched = sched
        n = sched.n_slots
        if sched.preemption not in ("off", "swap", "recompute"):
            raise ValueError(f"unknown preemption policy {sched.preemption!r}")
        if sched.preemption != "off" and sched.chunk_budget is None:
            raise ValueError(
                "preemption requires the unified token-budget step "
                "(set chunk_budget)"
            )
        if sched.tenant_quota is not None and sched.tenant_quota < 1:
            raise ValueError(f"tenant_quota must be >= 1, got {sched.tenant_quota}")
        if sched.tenant_weights is not None and any(
            w <= 0 for w in sched.tenant_weights.values()
        ):
            raise ValueError("tenant_weights must be positive")
        self._chunked = sched.chunk_budget is not None
        if self._chunked and sched.chunk_budget < sched.min_chunk:
            raise ValueError(
                f"chunk_budget {sched.chunk_budget} < min_chunk {sched.min_chunk}"
            )
        # Chunked streaming handles token-only requests; modality prefixes
        # and enc-dec cross caches go through whole-prompt prefill.
        self._stream_capable = self._chunked and not cfg.enc_dec and not cfg.prefix_len
        if sched.speculative and sched.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {sched.draft_k}")
        # Speculation rides chunk_step, which (like streaming) handles
        # token-only decoder stacks; enc-dec and modality-prefix models
        # fall back to plain decoding. Per-request gating (greedy only,
        # no extras) happens in _spec_step.
        self._spec = sched.speculative and not cfg.enc_dec and not cfg.prefix_len
        self._drafter: Drafter | None = None
        if self._spec:
            self._drafter = (
                sched.drafter if sched.drafter is not None else NgramDrafter()
            )

        span = model_page_span(cfg, sched.cache_len) if sched.paged else 0
        self._paged = span > 0
        if self._paged:
            n_pages = (
                sched.n_pages
                if sched.n_pages is not None
                else n * cdiv(span, sched.page_size)
            )
            self.pages: PageLayout | None = PageLayout(
                page_size=sched.page_size, n_pages=n_pages, span=span
            )
            self.pool: PagePool | None = PagePool(self.pages)
            state = init_paged_decode_state(cfg, n, sched.cache_len, self.pages, sctx=sctx)
            self._pt = np.full((n, self.pages.max_pages), self.pages.trash, np.int32)
        else:
            self.pages = None
            self.pool = None
            state = init_decode_state(cfg, n, sched.cache_len, sctx=sctx)
            state["pos"] = jnp.zeros((n,), jnp.int32)
        # Sharded stepping: pin every layer leaf (including the pool
        # leaves, whose kv_heads/head_dim shard over "model" — each device
        # owns its slice of every page) to its profile-resolved
        # NamedSharding, place the weights the same way, and route every
        # host-produced array (page table, token column, masks) through
        # fully-replicated device_put so program input/output layouts are
        # identical across steps — one trace per bucket, never per mesh.
        self._layer_shardings = decode_state_shardings(
            cfg, n, sched.cache_len, sctx, pages=self.pages if self._paged else None
        )
        self._replicated = sctx.replicated()
        if self._layer_shardings is not None:
            from repro.models.schema import shard_tree

            self.params = shard_tree(params, lm.model_schema(cfg), sctx)
        if self._paged:
            state["page_table"] = self._put(self._pt)
        self._states: dict[str, Any] = state
        self._tokens = np.zeros((n, 1), np.int32)  # next input token per slot
        self._temps = np.zeros((n,), np.float32)
        self._active_mask = np.zeros((n,), bool)
        self._pos_host = np.zeros((n,), np.int64)  # tokens cached per slot

        kinds = set(cfg.block_pattern) | set(cfg.first_blocks)
        self._bucketed = sched.prefill_buckets and not (kinds & _RECURRENT_KINDS)
        # Rejected draft positions leave inert garbage in dense / MLA
        # caches (positional masks never read past the accepted position),
        # but genuinely corrupt state that *advanced*: recurrent carries
        # consumed the rejected tokens, and windowed ring caches fold
        # rejected writes onto live window entries. Those archs roll back
        # by replaying the accepted run from a pre-verify snapshot.
        self._needs_replay = bool(kinds & _RECURRENT_KINDS) or (
            "local_attn" in kinds
        )
        # Prefix sharing needs every stateful leaf to live behind the page
        # table: windowed ring pages are position-folded (not prefix
        # content-addressable) and per-slot leaves (MLA ckv, recurrent
        # states) would silently carry prefix information sharing can't
        # reconstruct — so only fully dense-paged streaming models share.
        self._sharing = (
            sched.prefix_sharing
            and self._paged
            and self._stream_capable
            and kinds <= {"attn_mlp", "attn_moe"}
            and kinds <= blk.paged_kv_kinds(cfg)
        )
        self._slot_keys: dict[int, list[bytes]] = {}  # slot -> prompt page keys
        self._slot_reg: dict[int, int] = {}  # slot -> leading pages registered
        self._slot_worst: dict[int, tuple[str, int]] = {}  # slot -> (tenant, pages)
        self._tenant_pass: dict[str, float] = {}  # stride-scheduling virtual time

        self._queue: deque[RequestState] = deque()
        self._preempted: deque[RequestState] = deque()  # resume before admits
        self._active: dict[int, RequestState] = {}  # slot -> request
        self._free_slots: list[int] = list(range(n))
        heapq.heapify(self._free_slots)
        self._finished: dict[int, RequestState] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(sched.seed)

        self.decode_traces = 0  # jit trace count of the decode hot path
        self.prefill_traces = 0  # one per prompt bucket
        self.admit_traces = 0  # one per prompt bucket
        self.chunk_traces = 0  # one per chunk bucket
        self.swap_traces = 0  # swap-out + swap-in programs
        self.cow_traces = 0  # copy-on-write fork programs (per fork count)
        self.verify_traces = 0  # one per (k-bucket, page-bucket) pair
        self.total_decode_steps = 0
        self.total_chunk_steps = 0
        self.total_spec_steps = 0  # verify calls (one slot each)
        self.total_spec_replays = 0  # partial-accept rollback replays
        self.spec_fallbacks = 0  # drafts dropped for lack of pages
        self.drafted_tokens_total = 0
        self.accepted_tokens_total = 0
        self.deferred_admissions = 0  # pool-backpressure events
        self.quota_deferrals = 0  # tenant-quota skip events
        self.preemptions_total = 0
        self.prefix_hits = 0  # admissions that adopted >= 1 indexed page
        self.prefix_hit_tokens = 0  # prompt tokens satisfied by adoption
        self.finished_total = 0  # cumulative, survives keep_finished eviction
        self.generated_tokens_total = 0
        self.last_decode_logits: jax.Array | None = None

        # Explicit per-leaf layout metadata (paged pool leaf, dense,
        # ring, copy) — the graft/surgery dispatch; see models/schema.py.
        layouts = blk.stack_layouts(cfg, sched.cache_len, paged=self._paged)
        # Per-leaf logical capacities: >0 marks a shared-pool KV leaf (no
        # batch axis; passed through untouched by per-slot surgery).
        caps = blk.stack_paged_caps(cfg, sched.cache_len) if self._paged else None

        def _slot_surgery_trees():
            template = init_decode_state(self.cfg, 1, self.sched.cache_len)["layers"]
            c = caps if caps is not None else jax.tree.map(lambda _: 0, template)
            return c, template

        self._layouts = layouts

        def _freeze_inactive(active, new_layers, old_layers):
            # Inactive slots (free, or PREFILLING between chunks) must keep
            # their per-slot states verbatim across other slots' decode
            # steps: positional KV survives by write-before-read, but a
            # recurrence would absorb the masked slot's garbage token.
            # Shared-pool leaves have no batch row to freeze — their
            # garbage writes stay behind the trash page / the positions the
            # next chunk overwrites.
            c, template = _slot_surgery_trees()

            def leaf(cap, new, old, t):
                if cap:
                    return new
                nd, td = jnp.asarray(new), jnp.asarray(t)
                if nd.shape == td.shape:  # n_slots == 1
                    return jnp.where(active[0], nd, old)
                ax = [i for i in range(nd.ndim) if nd.shape[i] != td.shape[i]][0]
                shape = [1] * nd.ndim
                shape[ax] = nd.shape[ax]
                return jnp.where(active.reshape(shape), nd, old)

            return jax.tree.map(leaf, c, new_layers, old_layers, template)

        def _decode_fn(params, states, token, active):
            # Python body runs only when jit (re)traces: counts compilations.
            self.decode_traces += 1
            logits, new_states = lm.decode_step(params, self.cfg, states, token, self.sctx)
            # Freeze inactive slots in place (position and per-slot states).
            new_pos = jnp.where(active, new_states["pos"], states["pos"])
            out = {
                "layers": self._constrain_layers(
                    _freeze_inactive(active, new_states["layers"], states["layers"])
                ),
                "pos": new_pos,
            }
            if "page_table" in new_states:
                out["page_table"] = new_states["page_table"]
            return logits, out

        self._decode = jax.jit(_decode_fn)

        def _prefill_fn(p, b):
            self.prefill_traces += 1
            return lm.prefill(p, self.cfg, b, self.sctx)

        self._prefill = jax.jit(_prefill_fn)

        if self._paged:
            page_size = self.pages.page_size

            def _admit_fn(layers, pos, prefill_layers, slot, page_ids, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(self.cfg, 1, self.sched.cache_len)["layers"]

                def leaf(lay, full, tgt, src):
                    if lay.kind == "paged":  # shared-pool KV leaf: scatter pages
                        return graft_pages_leaf(
                            full, src, page_ids, prompt_len, lay.cap, page_size
                        )
                    return insert_slot_leaf(
                        full, _graft_leaf(tgt, src, prompt_len, lay), slot, lay
                    )

                new_layers = self._constrain_layers(
                    jax.tree.map(leaf, layouts, layers, target, prefill_layers)
                )
                return new_layers, pos.at[slot].set(prompt_len)

        else:

            def _admit_fn(layers, pos, prefill_layers, slot, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(self.cfg, 1, self.sched.cache_len)
                slot_layers = graft_states(
                    target["layers"], prefill_layers, prompt_len, layouts=layouts
                )
                new_layers = self._constrain_layers(
                    insert_slot(layers, slot_layers, slot, layouts=layouts)
                )
                return new_layers, pos.at[slot].set(prompt_len)

        # slot and prompt_len are traced, so admission compiles once per
        # prefill *shape* — with bucketing, once per bucket.
        self._admit_jit = jax.jit(_admit_fn)

        # -- unified-step programs (chunk streaming, slot reset, swap) -------
        def _chunk_body(layers, pos, tokens, slot, start, chunk_len, page_ids,
                        all_logits=False):
            c, template = _slot_surgery_trees()
            slot_layers = jax.tree.map(
                lambda lay, cap, full, t: (
                    full if cap else extract_slot_leaf(full, t, slot, lay)
                ),
                layouts, c, layers, template,
            )
            states: dict[str, Any] = {"layers": slot_layers, "pos": start}
            if page_ids is not None:
                states["page_table"] = page_ids[None, :]
            logits, new = lm.chunk_step(
                self.params, self.cfg, states, tokens, chunk_len, self.sctx,
                all_logits=all_logits,
            )
            new_layers = self._constrain_layers(
                jax.tree.map(
                    lambda lay, cap, full, s: (
                        s if cap else insert_slot_leaf(full, s, slot, lay)
                    ),
                    layouts, c, layers, new["layers"],
                )
            )
            return logits, new_layers, pos.at[slot].set(start + chunk_len)

        if self._paged:

            def _chunk_fn(layers, pos, tokens, slot, start, chunk_len, page_ids):
                self.chunk_traces += 1
                return _chunk_body(layers, pos, tokens, slot, start, chunk_len, page_ids)

            def _verify_fn(layers, pos, tokens, slot, start, chunk_len, page_ids):
                self.verify_traces += 1
                return _chunk_body(
                    layers, pos, tokens, slot, start, chunk_len, page_ids,
                    all_logits=True,
                )

        else:

            def _chunk_fn(layers, pos, tokens, slot, start, chunk_len):
                self.chunk_traces += 1
                return _chunk_body(layers, pos, tokens, slot, start, chunk_len, None)

            def _verify_fn(layers, pos, tokens, slot, start, chunk_len):
                self.verify_traces += 1
                return _chunk_body(
                    layers, pos, tokens, slot, start, chunk_len, None,
                    all_logits=True,
                )

        self._chunk_jit = jax.jit(_chunk_fn)
        # Verify program for speculative decoding: the chunk body with
        # logits at *every* position, so one call scores a whole draft.
        self._verify_jit = jax.jit(_verify_fn)
        # Position-only fixup for partial acceptance on archs whose caches
        # tolerate garbage past the accepted position (dense / MLA).
        self._setpos_jit = jax.jit(lambda pos, slot, val: pos.at[slot].set(val))

        def _reset_fn(layers, pos, slot, pos_val):
            # Reset the slot's per-slot leaves to the empty-recurrence state
            # so a chunked prefill starts from what a from-scratch prefill
            # would derive. Pool leaves stay: the trash-pointed table row
            # isolates them. ``pos_val`` is the adopted-prefix length (0
            # without sharing): the slot's frozen decode position must sit
            # at the first *unadopted* logical page, or the inactive slot's
            # garbage decode writes would land inside a shared page.
            c, _ = _slot_surgery_trees()
            fresh = fresh_slot_layers(self.cfg, self.sched.cache_len)
            new_layers = self._constrain_layers(
                jax.tree.map(
                    lambda lay, cap, full, t: (
                        full if cap else insert_slot_leaf(full, t, slot, lay)
                    ),
                    layouts, c, layers, fresh,
                )
            )
            return new_layers, pos.at[slot].set(pos_val)

        self._reset_jit = jax.jit(_reset_fn)

        if self._paged:

            def _copy_pages(full, src_ids, dst_ids):
                if full.ndim == 5:  # stacked groups: leading layer axis
                    return full.at[:, dst_ids].set(full[:, src_ids])
                return full.at[dst_ids].set(full[src_ids])

            def _cow_fn(layers, src_ids, dst_ids):
                # Fork shared pages: copy page contents src -> dst in every
                # pool leaf (one program per fork count; essentially never
                # runs — the scheduler's write pattern stays past adopted
                # spans — but keeps CoW safety local to the pool). Sharded,
                # the copy runs under shard_map per pool leaf: the page axis
                # is never mesh-sharded, so every device owns its
                # kv_heads/head_dim slice of both pages and forks them
                # locally — no cross-device traffic, the device-local-pool
                # property made executable.
                self.cow_traces += 1
                if self._layer_shardings is None:
                    return jax.tree.map(
                        lambda cap, full: (
                            _copy_pages(full, src_ids, dst_ids) if cap else full
                        ),
                        caps, layers,
                    )

                def leaf(cap, full, sh):
                    if not cap:
                        return full
                    spec = sh.spec
                    return shard_map(
                        _copy_pages,
                        mesh=self.sctx.mesh,
                        in_specs=(spec, P(), P()),
                        out_specs=spec,
                        check=False,
                    )(full, src_ids, dst_ids)

                return jax.tree.map(leaf, caps, layers, self._layer_shardings)

            self._cow_jit = jax.jit(_cow_fn)

        if self._paged:

            def _swap_out_fn(layers, page_ids, slot):
                self.swap_traces += 1
                c, template = _slot_surgery_trees()
                return jax.tree.map(
                    lambda lay, cap, full, t: (
                        gather_pages_leaf(full, page_ids)
                        if cap
                        else extract_slot_leaf(full, t, slot, lay)
                    ),
                    layouts, c, layers, template,
                )

            def _swap_in_fn(layers, pos, snap, page_ids, slot, pos_val):
                self.swap_traces += 1
                c, _ = _slot_surgery_trees()
                new_layers = self._constrain_layers(
                    jax.tree.map(
                        lambda lay, cap, full, s: (
                            scatter_pages_leaf(full, s, page_ids)
                            if cap
                            else insert_slot_leaf(full, s, slot, lay)
                        ),
                        layouts, c, layers, snap,
                    )
                )
                return new_layers, pos.at[slot].set(pos_val)

            self._swap_out_jit = jax.jit(_swap_out_fn)
            self._swap_in_jit = jax.jit(_swap_in_fn)

        def _sample_fn(logits, temps, key):
            lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
            greedy = jnp.argmax(lg, axis=-1)
            scaled = lg / jnp.maximum(temps, 1e-6)[:, None]
            sampled = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)

        self._sample = jax.jit(_sample_fn)

    # -- sharded-stepping helpers -------------------------------------------
    def _put(self, x):
        """Host array -> device; fully replicated over the mesh when sharded
        so every jit program sees one stable input layout per bucket."""
        if self._replicated is None:
            return jnp.asarray(x)
        return jax.device_put(np.asarray(x), self._replicated)

    def _constrain_layers(self, layers):
        """Pin a step program's output layer tree to the profile-resolved
        NamedShardings (identity without a mesh) — state placement can
        never drift between steps, whatever XLA would have inferred."""
        if self._layer_shardings is None:
            return layers
        return jax.tree.map(
            jax.lax.with_sharding_constraint, layers, self._layer_shardings
        )

    # -- client API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            RequestState(request=request, rid=rid, t_submit=time.perf_counter())
        )
        return rid

    def reset_rng(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)

    def set_drafter(self, drafter: Drafter) -> None:
        """Swap the draft proposer (e.g. install a workload oracle for
        benchmarking acceptance upper bounds). No-op with speculation off."""
        if self._spec:
            self._drafter = drafter

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._preempted)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def result(self, rid: int) -> RequestState:
        rs = self._finished.get(rid)
        if rs is not None:
            return rs
        in_flight = (
            any(r.rid == rid for r in self._active.values())
            or any(r.rid == rid for r in self._queue)
            or any(r.rid == rid for r in self._preempted)
        )
        if in_flight:
            raise KeyError(f"request {rid} is not finished yet")
        if 0 <= rid < self._next_rid:
            raise KeyError(
                f"request {rid} finished but its result was evicted "
                f"(keep_finished={self.sched.keep_finished}); raise "
                "keep_finished or collect results as requests retire (run())"
            )
        raise KeyError(f"unknown request id {rid}")

    def run(self) -> list[RequestState]:
        """Drive steps until queue and slots drain; returns finished states
        for the requests that were in flight at call time, in submission
        order. Results are collected as requests retire, so they survive
        ``keep_finished`` eviction even when one drain outruns the cap."""
        in_flight = (
            {rs.rid for rs in self._queue}
            | {rs.rid for rs in self._active.values()}
            | {rs.rid for rs in self._preempted}
        )
        results: dict[int, RequestState] = {}
        while self._queue or self._active or self._preempted:
            self.step()
            for rid in list(in_flight):
                rs = self._finished.get(rid)
                if rs is not None:
                    results[rid] = rs
                    in_flight.discard(rid)
        return [results[r] for r in sorted(results)]

    # -- one scheduling iteration ------------------------------------------
    def step(self) -> bool:
        """Admit/resume from the queues, stream at most one prefill chunk
        (fixed power-of-two buckets up to the token budget), run per-slot
        speculative verify steps (when enabled), then one decode step over
        the remaining decoding slots. Returns True if any model program
        ran."""
        self._admit_pending()
        ran = False
        if self._chunked:
            ran = self._prefill_chunk_step()
        handled: set[int] = set()
        if self._spec and self._active_mask.any():
            handled = self._spec_step()
            ran = ran or bool(handled)
        # Slots that already emitted via verify sit out this decode: their
        # cleared mask freezes pos and per-slot states exactly like a
        # PREFILLING slot's, and their garbage writes are confined the
        # same way (trash page / positions the next real write overwrites
        # before any read).
        mask = self._active_mask
        if handled:
            mask = mask.copy()
            mask[list(handled)] = False
        if not mask.any():
            return ran
        if self._paged:
            self._grow_pages(skip=handled)
            if self._sharing:
                # CoW guard: decode writes one token per ACTIVE slot at its
                # current position — fork first if that page is shared (the
                # scheduler's write pattern keeps this a no-op, but the
                # invariant is enforced here, not assumed).
                for slot, rs in list(self._active.items()):
                    if rs.status is RequestStatus.ACTIVE and slot not in handled:
                        p = int(self._pos_host[slot])
                        self._apply_cow(slot, self.pool.prepare_write(slot, p, p + 1))
            self._states["page_table"] = self._put(self._pt)

        self._key, sub = jax.random.split(self._key)
        logits, self._states = self._decode(
            self.params,
            self._states,
            self._put(self._tokens),
            self._put(mask),
        )
        self.last_decode_logits = logits
        cols = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(self._temps), sub))
        self.total_decode_steps += 1

        now = time.perf_counter()
        for slot, rs in list(self._active.items()):
            if rs.status is not RequestStatus.ACTIVE or slot in handled:
                continue  # still streaming its prompt in, or emitted via spec
            rs.decode_steps += 1
            self._pos_host[slot] += 1
            tok = int(cols[slot])
            rs.tokens.append(tok)
            rs.t_tokens.append(now)
            self._tokens[slot, 0] = tok
            self._maybe_finish(rs, now)
        return True

    # -- chunked prefill (unified token-budget step) -------------------------
    def _prefill_chunk_step(self) -> bool:
        """Stream one prompt chunk for the oldest PREFILLING slot.

        Chunk sizes come from a *fixed* power-of-two bucket set —
        ``min_chunk`` up to ``pow2_floor(chunk_budget)`` — independent of
        how many decode rows ride the same step: a load-dependent size
        would compile fresh chunk shapes exactly when the system is busy
        (the warmup, run idle, would never have seen them). The decode
        rows' tokens therefore ride on top of the chunk's; per-step work
        stays bounded by ``chunk_budget + n_slots``. Returns True if a
        chunk program ran."""
        prefilling = sorted(
            (rs for rs in self._active.values() if rs.status is RequestStatus.PREFILLING),
            key=lambda r: r.rid,
        )
        if not prefilling:
            return False
        sc = self.sched
        rs = prefilling[0]
        slot = rs.slot
        src = (
            rs.replay_tokens
            if rs.replay_tokens is not None
            else np.asarray(rs.request.prompt)
        )
        remaining = len(src) - rs.chunk_pos
        max_b = _pow2_floor(sc.chunk_budget)
        bucket = min(max(_pow2_ceil(min(remaining, max_b)), sc.min_chunk), max_b)
        n_real = min(bucket, remaining)
        start = rs.chunk_pos

        page_ids = None
        if self._paged:
            need = self.pages.pages_for_len(start + n_real)
            if not self._ensure_pages(slot, need, rid=rs.rid):
                self.deferred_admissions += 1
                return False
            held = len(self.pool.allocated(slot))
            if need > held:
                self._pt[slot, held:need] = self.pool.grow_to(slot, need)
            if self._sharing:
                # Fork any shared page in the chunk's write range before the
                # chunk program touches it (steady-state no-op: chunks only
                # write at or past the first unadopted position).
                self._apply_cow(
                    slot, self.pool.prepare_write(slot, start, start + n_real)
                )
            # The chunk only attends to pages covering [0, start + n_real);
            # pass a power-of-two page-count bucket of the table row so the
            # gather/kernel cost tracks the live prefix, not the table
            # width (one compile per (chunk, page) bucket pair — early
            # chunks of a long prompt stay cheap).
            n_lp = min(_pow2_ceil(max(need, 1)), self.pages.max_pages)
            page_ids = self._put(self._pt[slot, :n_lp])

        toks = src[start : start + n_real].astype(np.int32)
        if n_real < bucket:
            toks = np.concatenate([toks, np.zeros(bucket - n_real, np.int32)])
        args = [
            self._states["layers"], self._states["pos"], self._put(toks[None, :]),
            jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32),
            jnp.asarray(n_real, jnp.int32),
        ]
        if self._paged:
            args.append(page_ids)
        logits, layers, pos = self._chunk_jit(*args)
        self._states["layers"] = layers
        self._states["pos"] = pos
        rs.chunk_pos += n_real
        self._pos_host[slot] = rs.chunk_pos
        self.total_chunk_steps += 1
        if self._sharing and slot in self._slot_keys:
            # Register newly-completed full prompt pages in the prefix
            # index (first writer wins; adopted pages are already indexed).
            keys = self._slot_keys[slot]
            done = min(rs.chunk_pos // self.pages.page_size, len(keys))
            for j in range(self._slot_reg.get(slot, 0), done):
                self.pool.register_page(slot, j, keys[j])
            self._slot_reg[slot] = max(self._slot_reg.get(slot, 0), done)
        if rs.chunk_pos == len(src):
            self._finish_prefill(rs, logits)
        return True

    def _finish_prefill(self, rs: RequestState, logits: jax.Array) -> None:
        """The prompt is fully streamed: join the decode batch."""
        slot = rs.slot
        now = time.perf_counter()
        req = rs.request
        if rs.replay_tokens is not None:
            # Recompute resume: the last generated token was never fed back;
            # it is the next decode input, not a fresh sample.
            rs.replay_tokens = None
            self._tokens[slot, 0] = rs.tokens[-1]
        else:
            self._key, sub = jax.random.split(self._key)
            first = int(
                np.asarray(
                    self._sample(
                        logits[:, -1, :],
                        jnp.full((1,), req.temperature, jnp.float32),
                        sub,
                    )
                )[0]
            )
            rs.tokens = [first]
            rs.prefill_logits = np.asarray(logits[:, -1:, :])
            rs.t_first_token = now
            rs.t_tokens.append(now)
            self._tokens[slot, 0] = first
        rs.status = RequestStatus.ACTIVE
        self._temps[slot] = req.temperature
        self._active_mask[slot] = True
        self._maybe_finish(rs, now)

    # -- speculative decoding -------------------------------------------------
    def _spec_step(self) -> set[int]:
        """Draft + verify for every eligible ACTIVE slot; returns the slots
        that emitted tokens here (they sit out this step's decode).

        Eligibility is per request: greedy only (acceptance compares the
        model's argmax — a sampled token has no "the" correct value), no
        modality extras (chunk_step is token-only), and at least one token
        of budget beyond this step's guaranteed emission. A slot whose
        draft can't get page backing falls back to plain decoding for this
        step rather than stalling (``spec_fallbacks``)."""
        handled: set[int] = set()
        for slot in sorted(self._active):
            rs = self._active.get(slot)
            if rs is None or rs.status is not RequestStatus.ACTIVE:
                continue  # may have been preempted by an earlier verify
            req = rs.request
            if req.temperature > 0.0 or req.extras:
                continue
            budget = req.max_new_tokens - len(rs.tokens) - 1
            if budget < 1:
                continue
            ctx = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(rs.tokens, np.int32)]
            )
            k = min(self.sched.draft_k, budget)
            draft = np.asarray(
                self._drafter.propose(ctx, k), np.int32
            ).reshape(-1)[:k]
            if draft.size == 0:
                continue
            if self._verify_slot(slot, rs, draft):
                handled.add(slot)
        return handled

    def _verify_slot(self, slot: int, rs: RequestState, draft: np.ndarray) -> bool:
        """Score ``[pending token, draft...]`` in one all-logits chunk call
        and emit the longest greedy-matching run plus the model's own next
        token. Returns False (no tokens emitted; slot decodes plainly this
        step) only when the draft can't get page backing.

        The invariant in and out: the cache holds ``prompt + generated - 1``
        tokens and ``_tokens[slot]`` is the last generated token, not yet
        fed. Verify feeds it along with the draft at positions ``start..``;
        greedy logits at chunk index ``i`` answer "what follows token i",
        so ``accepted`` counts matching draft positions and index
        ``accepted`` supplies the bonus/correction token — between 1 and
        ``k + 1`` tokens per call, token-identical to plain decoding."""
        k = len(draft)
        n_real = k + 1
        # Fixed bucket set: pow2 of the verify length, capped at the
        # configured maximum — one compile per (k-bucket, page-bucket).
        bucket = min(_pow2_ceil(n_real), _pow2_ceil(self.sched.draft_k + 1))
        start = int(self._pos_host[slot])
        page_ids = None
        need = 0
        if self._paged:
            need = self.pages.pages_for_len(start + n_real)
            held = len(self.pool.allocated(slot))
            if need > held:
                if not self._ensure_pages(slot, need, rid=rs.rid):
                    self.spec_fallbacks += 1
                    return False
                self._pt[slot, held:need] = self.pool.grow_to(slot, need)
            if self._sharing:
                # Defensive CoW guard, like the decode step's: the verify
                # range starts at/after the first generated position, past
                # any shared prompt page, so this is a steady-state no-op.
                self._apply_cow(
                    slot, self.pool.prepare_write(slot, start, start + n_real)
                )
            n_lp = min(_pow2_ceil(max(need, 1)), self.pages.max_pages)
            page_ids = self._put(self._pt[slot, :n_lp])

        # Pre-verify snapshot for rollback-by-replay (recurrent carries,
        # windowed ring folds). Taken *after* CoW so forked pages are in
        # it; JAX array immutability makes this a free reference, not a
        # copy — it only pins memory until the verify result replaces it.
        snap = self._states["layers"] if self._needs_replay else None

        toks = np.zeros(bucket, np.int32)
        toks[0] = self._tokens[slot, 0]
        toks[1:n_real] = draft
        toks_dev = self._put(toks[None, :])
        slot_t = jnp.asarray(slot, jnp.int32)
        start_t = jnp.asarray(start, jnp.int32)
        args = [
            self._states["layers"], self._states["pos"], toks_dev,
            slot_t, start_t, jnp.asarray(n_real, jnp.int32),
        ]
        if self._paged:
            args.append(page_ids)
        logits, layers, pos = self._verify_jit(*args)

        # Greedy acceptance on host, matching _sample_fn's cast + argmax.
        lg = np.asarray(logits[0, :n_real, : self.cfg.vocab_size]).astype(np.float32)
        greedy = lg.argmax(axis=-1).astype(np.int32)
        accept = 0
        while accept < k and greedy[accept] == draft[accept]:
            accept += 1
        emitted = [int(t) for t in draft[:accept]] + [int(greedy[accept])]
        n_new = accept + 1  # tokens the cache should have gained

        if accept == k:
            # Full acceptance: the verify pass already cached exactly the
            # accepted run and set pos = start + n_real.
            self._states["layers"] = layers
            self._states["pos"] = pos
        else:
            if self._paged:
                # Return the pages grown for rejected positions (always
                # private: sharing only covers the prompt prefix). Under
                # worst-case reservations the backing stays owed to this
                # slot; reservation-free, it returns to the pool.
                keep = self.pages.pages_for_len(start + n_new)
                removed = self.pool.truncate_to(
                    slot, keep, keep_reservation=self.sched.preemption == "off"
                )
                if removed:
                    self._pt[slot, keep : keep + len(removed)] = self.pages.trash
                    n_lp = min(_pow2_ceil(max(keep, 1)), self.pages.max_pages)
                    page_ids = self._put(self._pt[slot, :n_lp])
            if self._needs_replay:
                # State advanced through rejected tokens (recurrence) or
                # rejected writes folded onto live ring entries: re-run the
                # accepted run from the snapshot through the chunk program
                # (same shapes as verify, so no fresh compile per accept
                # count — chunk_len is a traced scalar).
                rargs = [
                    snap, self._states["pos"], toks_dev, slot_t, start_t,
                    jnp.asarray(n_new, jnp.int32),
                ]
                if self._paged:
                    rargs.append(page_ids)
                _, rlayers, rpos = self._chunk_jit(*rargs)
                self._states["layers"] = rlayers
                self._states["pos"] = rpos
                self.total_spec_replays += 1
            else:
                # Dense/MLA: garbage past the accepted position is inert
                # under positional masks; only the position needs fixing.
                self._states["layers"] = layers
                self._states["pos"] = self._setpos_jit(
                    pos, slot_t, jnp.asarray(start + n_new, jnp.int32)
                )

        self._pos_host[slot] = start + n_new
        rs.spec_steps += 1
        rs.drafted += k
        rs.accepted += accept
        self.total_spec_steps += 1
        self.drafted_tokens_total += k
        self.accepted_tokens_total += accept
        now = time.perf_counter()
        for tok in emitted:
            rs.tokens.append(tok)
            rs.t_tokens.append(now)
            self._tokens[slot, 0] = tok
            self._maybe_finish(rs, now)
            if rs.done:
                break  # stop token mid-run: drop the rest, as plain decode would
        return True

    # -- pages: growth, reservation-free accounting, preemption --------------
    def _apply_cow(self, slot: int, forks: list[tuple[int, int, int]]) -> None:
        """Materialise ``prepare_write`` forks: re-point the host page-table
        mirror and copy page contents old -> new in every pool leaf."""
        if not forks:
            return
        for j, _, new in forks:
            self._pt[slot, j] = new
        src = jnp.asarray([old for _, old, _ in forks], jnp.int32)
        dst = jnp.asarray([new for _, _, new in forks], jnp.int32)
        self._states["layers"] = self._cow_jit(self._states["layers"], src, dst)

    def _ensure_pages(self, slot: int, n_total: int, rid: int | None = None) -> bool:
        """Make ``slot``'s reservation cover ``n_total`` pages. Under
        worst-case reservations this always holds; reservation-free
        (preemption on), extend incrementally and reclaim victims' pages
        until the pool can back it. ``rid`` is the requesting request's id
        (ordering key for the younger-streamer victim rule)."""
        if self.sched.preemption == "off":
            return True  # admission reserved the worst case
        while not self.pool.extend_to(slot, n_total):
            if not self._preempt_lru(protect=slot, requester_rid=rid):
                return False
        return True

    def _grow_pages(self, skip: set[int] = frozenset()) -> None:
        """Allocate the page backing the position each decoding slot writes
        this step. Worst-case reservations guarantee this; reservation-free
        admission may have to preempt first — including the growing slot
        *itself* when everyone else's pages are pinned (e.g. an *older*
        PREFILLING streamer holds the pool; only younger streamers are
        victims): the grower is parked and resumes once pages free up.
        ``skip`` names slots sitting out this decode (already emitted via
        speculative verify): they write nothing, so growing for them now
        would only add pool pressure."""
        for slot, rs in list(self._active.items()):
            if rs.status is not RequestStatus.ACTIVE or slot in skip:
                continue
            need = self.pages.pages_for_len(int(self._pos_host[slot]) + 1)
            held = len(self.pool.allocated(slot))
            if need <= held:
                continue
            if not self._ensure_pages(slot, need, rid=rs.rid):
                if self._can_preempt(rs):
                    self._preempt_slot(slot)
                    continue
                raise RuntimeError(
                    f"slot {slot}: cannot back page growth to {need} and the "
                    "request is not preemptable (recompute cannot replay "
                    "modality extras); use preemption=\"swap\" or a larger "
                    "pool for such workloads"
                )
            self._pt[slot, held:need] = self.pool.grow_to(slot, need)

    def _can_preempt(self, rs: RequestState) -> bool:
        """Swap restores any slot verbatim; recompute replays tokens through
        chunked streaming, which cannot re-feed modality extras or enc-dec
        caches — such requests are not recompute victims."""
        if self.sched.preemption == "swap":
            return True
        return self._stream_capable and not rs.request.extras

    def _preempt_lru(self, protect: int, requester_rid: int | None = None) -> bool:
        """Reclaim the least-recently-(re)admitted decoding slot's pages.

        ``swap``: snapshot the slot's page contents + per-slot states to
        host and restore them verbatim on resume. ``recompute``: drop
        everything and re-stream prompt + generated tokens (teacher-forced)
        on resume. Either way the resumed request continues greedy
        token-identically.

        When no ACTIVE victim exists (concurrent streamers contending for
        pages), a *younger* PREFILLING streamer (rid > requester) is
        restarted instead — streaming admissions are token-only, so
        re-streaming from chunk 0 is valid under either policy, and
        preferring the youngest guarantees the oldest in-flight request
        always wins the pages it needs: no two-streamer deadlock, no
        livelock. Returns False when no victim exists."""
        victims = [
            rs
            for s, rs in self._active.items()
            if rs.status is RequestStatus.ACTIVE and s != protect
            and self._can_preempt(rs)
        ]
        if victims:
            self._preempt_slot(min(victims, key=lambda r: r.t_admit).slot)
            return True
        if requester_rid is None:
            return False
        streamers = [
            rs
            for s, rs in self._active.items()
            if rs.status is RequestStatus.PREFILLING and s != protect
            and rs.rid > requester_rid
        ]
        if not streamers:
            return False
        self._preempt_slot(max(streamers, key=lambda r: r.rid).slot)
        return True

    def _preempt_slot(self, slot: int) -> None:
        rs = self._active[slot]
        if rs.status is RequestStatus.PREFILLING:
            # A parked streamer restarts from chunk 0 on resume under either
            # policy — its source (prompt, or replay_tokens after an earlier
            # recompute preemption) is token-only by construction, and any
            # pages it registered in the prefix index survive in the pool's
            # cached list, so the restart re-adopts instead of recomputing.
            rs.chunk_pos = 0
        elif self.sched.preemption == "swap":
            snap = self._swap_out_jit(
                self._states["layers"],
                self._put(self._pt[slot]),
                jnp.asarray(slot, jnp.int32),
            )
            rs.swap = (jax.tree.map(np.asarray, snap), int(self._pos_host[slot]))
        else:  # recompute
            rs.replay_tokens = np.concatenate(
                [np.asarray(rs.request.prompt, np.int32),
                 np.asarray(rs.tokens[:-1], np.int32)]
            )
            rs.chunk_pos = 0
        rs.status = RequestStatus.PREEMPTED
        rs.preemptions += 1
        self.preemptions_total += 1
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        self.pool.release(slot)
        self._pt[slot, :] = self.pages.trash
        self._pos_host[slot] = 0
        self._slot_keys.pop(slot, None)
        self._slot_reg.pop(slot, None)
        self._slot_worst.pop(slot, None)
        rs.slot = None
        self._preempted.append(rs)

    # -- admission -----------------------------------------------------------
    def _bucket_len(self, token_len: int) -> int:
        """Power-of-two padded token count (identity when bucketing is off).

        Dense prompts never exceed ``cache_len`` (asserted at admission),
        so buckets cap there to keep the padded prompt in one row. Prompts
        legitimately *past* the cap (windowed / long-context models) stay
        on uncapped power-of-two buckets: at most log2(longest prompt)
        distinct shapes, never the raw length (which would compile one
        prefill program per prompt and defeat the bounded-compile
        guarantee)."""
        if not self._bucketed:
            return token_len
        b = max(self.sched.min_bucket, 1)
        while b < token_len:
            b *= 2
        cap = self.sched.cache_len - (self.cfg.prefix_len or 0)
        if token_len > cap:
            if self.cfg.supports_long_context or self.cfg.window_size:
                return b
            raise RuntimeError(
                f"prompt of {token_len} tokens exceeds the dense prefill cap "
                f"{cap} (cache_len {self.sched.cache_len}); admission "
                "validation should have rejected this request"
            )
        return min(b, cap)

    def _worst_pages(self, rs: RequestState) -> int:
        """Worst-case page footprint of a request (0 when not paged)."""
        if not self._paged:
            return 0
        req = rs.request
        prompt_len = req.prompt.shape[0] + (self.cfg.prefix_len or 0)
        return self.pages.pages_for_len(prompt_len + req.max_new_tokens)

    def _tenant_pages(self, tenant: str) -> int:
        """Worst-case pages currently charged to ``tenant``'s slots."""
        return sum(w for t, w in self._slot_worst.values() if t == tenant)

    def _pick_next(self, blocked: set[str]) -> RequestState | None:
        """Weighted-fair pick: among each unblocked tenant's head-of-line
        request, take the one whose tenant has the lowest stride pass
        (ties by rid). Tenants first seen mid-flight join at the current
        minimum pass, so a newcomer is served promptly but cannot burn
        accumulated credit."""
        heads: dict[str, RequestState] = {}
        for rs in self._queue:
            t = rs.request.tenant
            if t in blocked or t in heads:
                continue
            heads[t] = rs
        if not heads:
            return None
        floor = min(self._tenant_pass.values(), default=0.0)

        def pass_of(t: str) -> float:
            return self._tenant_pass.get(t, floor)

        return min(heads.values(), key=lambda r: (pass_of(r.request.tenant), r.rid))

    def _charge_tenant(self, rs: RequestState) -> None:
        req = rs.request
        weights = self.sched.tenant_weights or {}
        w = weights.get(req.tenant, 1.0)
        floor = min(self._tenant_pass.values(), default=0.0)
        cost = (req.prompt.shape[0] + req.max_new_tokens) / w
        self._tenant_pass[req.tenant] = (
            self._tenant_pass.get(req.tenant, floor) + cost
        )

    def _admit_pending(self) -> None:
        # Preempted requests resume first: they hold generated progress and
        # FIFO-resuming them bounds preemption churn. A *deferred* resume
        # (not enough free pages yet) blocks fresh admissions too —
        # otherwise younger requests would keep taking the pages the
        # swapped-out request is waiting for and starve it indefinitely.
        while self._free_slots and self._preempted:
            if not self._try_resume(self._preempted[0]):
                return
            self._preempted.popleft()
        sc = self.sched
        if sc.tenant_quota is None and not sc.tenant_weights:
            # Single-tenant: exact FIFO (the historical admission order).
            while self._free_slots and self._queue:
                rs = self._queue[0]
                if not self._admit(rs):
                    break
                self._queue.popleft()
            return
        # Multi-tenant: weighted-fair ordering with per-tenant page quotas.
        # A quota-blocked tenant is skipped (its requests keep FIFO order
        # within the tenant) while other tenants continue to admit; pool
        # backpressure blocks everyone (FIFO fairness of the pool itself).
        blocked: set[str] = set()
        while self._free_slots and self._queue:
            rs = self._pick_next(blocked)
            if rs is None:
                break
            tenant = rs.request.tenant
            if self._paged and sc.tenant_quota is not None:
                n_worst = self._worst_pages(rs)
                if n_worst > sc.tenant_quota:
                    raise RuntimeError(
                        f"request {rs.rid} needs {n_worst} pages worst-case, "
                        f"more than tenant {tenant!r}'s whole quota "
                        f"({sc.tenant_quota}); raise tenant_quota or lower "
                        "max_new_tokens"
                    )
                if self._tenant_pages(tenant) + n_worst > sc.tenant_quota:
                    blocked.add(tenant)
                    self.quota_deferrals += 1
                    continue
            if not self._admit(rs):
                break
            # identity, not ==: Request's dataclass __eq__ compares prompt
            # arrays elementwise
            for i, q in enumerate(self._queue):
                if q is rs:
                    del self._queue[i]
                    break
            self._charge_tenant(rs)

    def _admit(self, rs: RequestState) -> bool:
        if self._stream_capable and not rs.request.extras:
            return self._admit_streaming(rs)
        return self._admit_prefill(rs)

    def _check_fits(self, rs: RequestState, prompt_len: int) -> int:
        """Shared admission validation; returns the worst-case page count."""
        req = rs.request
        assert (
            prompt_len + req.max_new_tokens <= self.sched.cache_len
            or self.cfg.supports_long_context
            or self.cfg.window_size
        ), (
            f"cache_len {self.sched.cache_len} too small for "
            f"{prompt_len}+{req.max_new_tokens}"
        )
        if not self._paged:
            return 0
        n_worst = self.pages.pages_for_len(prompt_len + req.max_new_tokens)
        if n_worst > self.pages.n_pages:
            # Never admissible even into an empty pool: fail fast instead
            # of deferring forever (run() would spin).
            raise RuntimeError(
                f"request {rs.rid} needs {n_worst} pages worst-case "
                f"({prompt_len}+{req.max_new_tokens} tokens @ "
                f"{self.pages.page_size}/page) but the pool has only "
                f"{self.pages.n_pages}; raise n_pages or lower "
                "max_new_tokens"
            )
        return n_worst

    def _admit_streaming(self, rs: RequestState) -> bool:
        """Assign a slot and start streaming the prompt in chunks, adopting
        any indexed prefix pages first (their tokens are skipped, not
        recomputed). Under worst-case reservations this is where OOM
        backpressure defers; reservation-free admission always proceeds
        (chunks reserve as they stream, preempting younger streamers or
        LRU decoders if needed — no single-streamer gate)."""
        req = rs.request
        prompt_len = req.prompt.shape[0]
        n_worst = self._check_fits(rs, prompt_len)
        if self._paged and self.sched.preemption == "off":
            if not self.pool.can_reserve(n_worst):
                self.deferred_admissions += 1
                return False
        slot = heapq.heappop(self._free_slots)
        start = 0
        if self._paged:
            self.pool.reserve(slot, 0)
            self._pt[slot, :] = self.pages.trash
            if self._sharing:
                P = self.pages.page_size
                keys = prefix_page_keys(req.prompt, P)
                src_len = (
                    len(rs.replay_tokens)
                    if rs.replay_tokens is not None
                    else prompt_len
                )
                # Cap adoption below the streamed source so at least one
                # token still streams: the final chunk's logits seed the
                # first sampled token.
                adopted = self.pool.adopt_prefix(slot, keys[: (src_len - 1) // P])
                if adopted:
                    self._pt[slot, :adopted] = self.pool.allocated(slot)
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += adopted * P
                    start = adopted * P
                self._slot_keys[slot] = keys
                self._slot_reg[slot] = adopted
            if self.sched.preemption == "off" and not self.pool.extend_to(
                slot, n_worst
            ):
                # Adoption revives cached pages (no longer evictable), but
                # it adopts at least as many pages as it revives, so the
                # pre-checked headroom still covers the remainder; this
                # rollback is defensive.
                self.pool.release(slot)
                self._pt[slot, :] = self.pages.trash
                self._slot_keys.pop(slot, None)
                self._slot_reg.pop(slot, None)
                heapq.heappush(self._free_slots, slot)
                self.deferred_admissions += 1
                return False
            self._slot_worst[slot] = (req.tenant, n_worst)
        layers, pos = self._reset_jit(
            self._states["layers"], self._states["pos"], jnp.asarray(slot, jnp.int32),
            jnp.asarray(start, jnp.int32),
        )
        self._states["layers"] = layers
        self._states["pos"] = pos
        self._pos_host[slot] = start
        rs.slot = slot
        rs.prompt_len = prompt_len
        rs.chunk_pos = start
        rs.adopted_tokens = start
        rs.status = RequestStatus.PREFILLING
        rs.t_admit = time.perf_counter()
        self._active[slot] = rs
        return True

    def _try_resume(self, rs: RequestState) -> bool:
        """Re-admit a preempted request: swap its snapshot back in, or
        restart streaming (recompute). False defers (not enough pages)."""
        if rs.swap is not None:
            snap, pos_v = rs.swap
            need = self.pages.pages_for_len(pos_v)
            if need > self.pool.available():
                self.deferred_admissions += 1
                return False
            slot = heapq.heappop(self._free_slots)
            self.pool.reserve(slot, 0)
            if not self.pool.extend_to(slot, need):  # pragma: no cover - race-free
                raise RuntimeError("pool accounting violated availability check")
            self._pt[slot, :] = self.pages.trash
            if need:
                self._pt[slot, :need] = self.pool.grow_to(slot, need)
            layers, pos = self._swap_in_jit(
                self._states["layers"], self._states["pos"],
                jax.tree.map(self._put, snap),
                self._put(self._pt[slot]), jnp.asarray(slot, jnp.int32),
                jnp.asarray(pos_v, jnp.int32),
            )
            self._states["layers"] = layers
            self._states["pos"] = pos
            self._pos_host[slot] = pos_v
            rs.swap = None
            rs.slot = slot
            self._slot_worst[slot] = (rs.request.tenant, self._worst_pages(rs))
            rs.status = RequestStatus.ACTIVE
            rs.t_admit = time.perf_counter()
            self._tokens[slot, 0] = rs.tokens[-1]
            self._temps[slot] = rs.request.temperature
            self._active_mask[slot] = True
            self._active[slot] = rs
            return True
        # recompute: restart chunk streaming over prompt + generated tokens
        return self._admit_streaming(rs)

    def _admit_prefill(self, rs: RequestState) -> bool:
        """Whole-prompt prefill + graft at admission (the PR-1/2 path; also
        the fallback for modality-prefix / enc-dec requests when chunked
        streaming is on). Returns False to defer on pool backpressure."""
        req = rs.request
        prompt_len = req.prompt.shape[0] + (self.cfg.prefix_len or 0)
        n_reserve = self._check_fits(rs, prompt_len)
        page_ids_arr = None
        if self._paged:
            if not self.pool.can_reserve(n_reserve):
                # OOM backpressure: not enough pool headroom for this
                # request's worst case — defer admission (FIFO order is
                # preserved; live pages are never reclaimed or aliased).
                self.deferred_admissions += 1
                return False
        slot = heapq.heappop(self._free_slots)
        if self._paged:
            self.pool.reserve(slot, n_reserve)
            self._slot_worst[slot] = (req.tenant, n_reserve)
            n_admit = self.pages.pages_for_len(prompt_len)
            self._pt[slot, :] = self.pages.trash
            self._pt[slot, :n_admit] = self.pool.grow_to(slot, n_admit)
            page_ids_arr = self._put(self._pt[slot])

        tok_len = req.prompt.shape[0]
        pad_to = self._bucket_len(tok_len)
        toks = np.asarray(req.prompt)
        if pad_to != tok_len:
            toks = np.concatenate([toks, np.zeros(pad_to - tok_len, np.int32)])
        batch = {"tokens": self._put(toks[None, :])}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v)
        if self._bucketed:
            batch["logit_pos"] = jnp.asarray(prompt_len - 1, jnp.int32)
        logits, pstates = self._prefill(self.params, batch)

        plen_t = jnp.asarray(prompt_len, jnp.int32)
        slot_t = jnp.asarray(slot, jnp.int32)
        if self._paged:
            layers, pos = self._admit_jit(
                self._states["layers"], self._states["pos"], pstates["layers"],
                slot_t, page_ids_arr, plen_t,
            )
        else:
            layers, pos = self._admit_jit(
                self._states["layers"], self._states["pos"], pstates["layers"],
                slot_t, plen_t,
            )
        self._states["layers"] = layers
        self._states["pos"] = pos
        self._pos_host[slot] = prompt_len

        now = time.perf_counter()
        self._key, sub = jax.random.split(self._key)
        first = int(
            np.asarray(
                self._sample(
                    logits[:, -1, :],
                    jnp.full((1,), req.temperature, jnp.float32),
                    sub,
                )
            )[0]
        )
        rs.slot = slot
        rs.prompt_len = prompt_len
        rs.status = RequestStatus.ACTIVE
        rs.tokens = [first]
        rs.prefill_logits = np.asarray(logits[:, -1:, :])
        rs.t_admit = now
        rs.t_first_token = now
        rs.t_tokens.append(now)
        self._tokens[slot, 0] = first
        self._temps[slot] = req.temperature
        self._active_mask[slot] = True
        self._active[slot] = rs
        # A 1-token request (or an immediate stop) retires before ever
        # riding the decode step, freeing the slot for this admission loop.
        self._maybe_finish(rs, now)
        return True

    def _maybe_finish(self, rs: RequestState, now: float) -> None:
        req = rs.request
        reason = None
        if req.stop_token >= 0 and rs.tokens[-1] == req.stop_token:
            reason = "stop"
        elif len(rs.tokens) >= req.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        slot = rs.slot
        assert slot is not None
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        self._pos_host[slot] = 0
        self._slot_keys.pop(slot, None)
        self._slot_reg.pop(slot, None)
        self._slot_worst.pop(slot, None)
        if self._paged:
            # Free pages and point the table row at the trash page so the
            # retired slot's frozen-position garbage writes can never touch
            # a future tenant of these pages. Pages this slot registered in
            # the prefix index park in the pool's cached list at refcount
            # zero — the next same-prefix admission revives them for free.
            self.pool.release(slot)
            self._pt[slot, :] = self.pages.trash
        rs.status = RequestStatus.FINISHED
        rs.finish_reason = reason
        rs.t_finish = now
        self._finished[rs.rid] = rs
        self.finished_total += 1
        self.generated_tokens_total += len(rs.tokens)
        # Bound retention for long-running serving: evict the oldest finished
        # states (dict preserves insertion order) beyond keep_finished.
        while len(self._finished) > self.sched.keep_finished:
            self._finished.pop(next(iter(self._finished)))

    def stats(self) -> dict[str, Any]:
        out = {
            # Cumulative — monotone even after keep_finished eviction.
            "finished": self.finished_total,
            "generated_tokens": self.generated_tokens_total,
            "retained": len(self._finished),
            "decode_steps": self.total_decode_steps,
            "chunk_steps": self.total_chunk_steps,
            "spec_steps": self.total_spec_steps,
            "spec_replays": self.total_spec_replays,
            "spec_fallbacks": self.spec_fallbacks,
            "drafted_tokens": self.drafted_tokens_total,
            "accepted_tokens": self.accepted_tokens_total,
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
            "admit_traces": self.admit_traces,
            "chunk_traces": self.chunk_traces,
            "swap_traces": self.swap_traces,
            "cow_traces": self.cow_traces,
            "verify_traces": self.verify_traces,
            "pending": self.pending,
            "active": self.num_active,
            "deferred_admissions": self.deferred_admissions,
            "quota_deferrals": self.quota_deferrals,
            "preemptions": self.preemptions_total,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
        }
        out["mesh"] = (
            None if self.sctx.mesh is None else dict(self.sctx.mesh.shape)
        )
        out["mesh_devices"] = self.sctx.device_count()
        if self._paged:
            out["pages"] = self.pool.stats()
        return out

    # -- capacity accounting -------------------------------------------------
    def paged_cache_bytes(self) -> dict[str, int]:
        """Actual (peak pages in use) vs contiguous-equivalent cache bytes
        for the paged KV leaves. Zeros when the model has no paged layer."""
        if not self._paged:
            return {
                "bytes_per_page": 0,
                "peak_bytes": 0,
                "contiguous_bytes": 0,
                "bytes_per_page_per_device": 0,
            }
        # Bytes of one page summed across every paged leaf (a physical page
        # id addresses page-sized storage in every paged layer at once).
        # Sharded, each leaf's per-device share divides by the product of
        # mesh axes its resolved PartitionSpec actually uses (replicated
        # leaves divide by 1) — the number the device-local pool holds.
        per_page = 0
        per_page_dev = 0
        caps = blk.stack_paged_caps(self.cfg, self.sched.cache_len)
        cap_leaves = jax.tree.leaves(caps)
        arr_leaves = jax.tree.leaves(self._states["layers"])
        sh_leaves = (
            jax.tree.leaves(self._layer_shardings, is_leaf=lambda x: x is None)
            if self._layer_shardings is not None
            else [None] * len(arr_leaves)
        )
        mesh_axes = dict(self.sctx.mesh.shape) if self.sctx.mesh else {}
        for cap, leafarr, sh in zip(cap_leaves, arr_leaves, sh_leaves):
            if not cap:
                continue
            shape = leafarr.shape
            lead = len(shape) - 4  # stacked layer axis
            n_layers = shape[0] if lead else 1
            page_elems = int(np.prod(shape[lead + 1:]))  # page * kv * hd
            leaf_bytes = n_layers * page_elems * jnp.dtype(leafarr.dtype).itemsize
            per_page += leaf_bytes
            div = 1
            if sh is not None:
                for ax in sh.spec:
                    for a in ax if isinstance(ax, tuple) else ((ax,) if ax else ()):
                        div *= mesh_axes.get(a, 1)
            per_page_dev += leaf_bytes // div
        peak = self.pool.peak_in_use * per_page
        contiguous = self.sched.n_slots * self.pages.max_pages * per_page
        return {
            "bytes_per_page": int(per_page),
            "peak_bytes": int(peak),
            "contiguous_bytes": int(contiguous),
            "bytes_per_page_per_device": int(per_page_dev),
        }
