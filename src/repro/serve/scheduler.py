"""Continuous-batching request scheduler.

The scheduler owns ``n_slots`` persistent decode slots backed by one batched
decode state (KV/ring/recurrent caches at ``cache_len``). Requests flow
through an admission queue; each admitted request gets a free slot:

  1. **prefill** — the request's prompt runs through the jitted prefill
     (compiled per prompt length), producing prompt-length caches,
  2. **graft** — those caches are grafted into a slot-shaped serving cache
     and inserted into the batched state at the slot's batch row (one
     compiled program per prompt length; slot index is traced),
  3. **decode** — the slot rides the shared ``(n_slots, 1)`` decode step with
     an active mask and per-slot position indices,
  4. **retire** — on stop-token or length the slot is freed and immediately
     backfilled from the queue at the next step.

The decode hot path is shape-stable by construction: tokens are always
``(n_slots, 1)``, the active mask ``(n_slots,)``, positions ``(n_slots,)``
— requests joining or leaving only changes array *values*, so the step
never recompiles after its single warmup trace (``decode_traces`` counts
traces for tests/monitoring). Inactive slots keep decoding garbage tokens
with a frozen position; that is safe because a slot's cache row is always
rewritten (graft at admission, write-before-read during decode) before any
of it becomes visible through the position mask.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serve.cache import graft_states, insert_slot
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.step import init_decode_state
from repro.sharding.rules import ShardingCtx


@dataclass
class SchedulerConfig:
    n_slots: int = 4  # concurrent sequences in the batched decode state
    cache_len: int = 256  # per-slot cache slots (>= prompt + new tokens for dense)
    seed: int = 0
    keep_finished: int = 1024  # finished RequestStates retained for result()


class Scheduler:
    def __init__(
        self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, sched: SchedulerConfig
    ):
        self.cfg = cfg
        self.params = params
        self.sctx = sctx
        self.sched = sched
        n = sched.n_slots

        state = init_decode_state(cfg, n, sched.cache_len)
        state["pos"] = jnp.zeros((n,), jnp.int32)  # per-slot positions
        self._states: dict[str, Any] = state
        self._tokens = np.zeros((n, 1), np.int32)  # next input token per slot
        self._temps = np.zeros((n,), np.float32)
        self._active_mask = np.zeros((n,), bool)

        self._queue: deque[RequestState] = deque()
        self._active: dict[int, RequestState] = {}  # slot -> request
        self._free_slots: list[int] = list(range(n))
        heapq.heapify(self._free_slots)
        self._finished: dict[int, RequestState] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(sched.seed)

        self.decode_traces = 0  # jit trace count of the decode hot path
        self.total_decode_steps = 0
        self.last_decode_logits: jax.Array | None = None

        def _decode_fn(params, states, token, active):
            # Python body runs only when jit (re)traces: counts compilations.
            self.decode_traces += 1
            logits, new_states = lm.decode_step(params, self.cfg, states, token, self.sctx)
            # Freeze retired slots in place; their writes stay confined to one
            # cache row that admission will overwrite.
            new_pos = jnp.where(active, new_states["pos"], states["pos"])
            return logits, {"layers": new_states["layers"], "pos": new_pos}

        self._decode = jax.jit(_decode_fn)
        self._prefill = jax.jit(lambda p, b: lm.prefill(p, self.cfg, b, self.sctx))

        def _admit_fn(layers, pos, prefill_layers, slot, prompt_len):
            target = init_decode_state(self.cfg, 1, self.sched.cache_len)
            slot_layers = graft_states(target["layers"], prefill_layers, prompt_len)
            new_layers = insert_slot(layers, slot_layers, slot)
            return new_layers, pos.at[slot].set(prompt_len)

        # prompt_len is static (ring placement is computed at trace time);
        # slot is traced, so admission compiles once per prompt length.
        self._admit_jit = jax.jit(_admit_fn, static_argnums=(4,))

        def _sample_fn(logits, temps, key):
            lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
            greedy = jnp.argmax(lg, axis=-1)
            scaled = lg / jnp.maximum(temps, 1e-6)[:, None]
            sampled = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)

        self._sample = jax.jit(_sample_fn)

    # -- client API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            RequestState(request=request, rid=rid, t_submit=time.perf_counter())
        )
        return rid

    def reset_rng(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def result(self, rid: int) -> RequestState:
        return self._finished[rid]

    def run(self) -> list[RequestState]:
        """Drive steps until queue and slots drain; returns finished states
        for the requests that were in flight at call time, in submission
        order. Results are collected as requests retire, so they survive
        ``keep_finished`` eviction even when one drain outruns the cap."""
        in_flight = {rs.rid for rs in self._queue} | {
            rs.rid for rs in self._active.values()
        }
        results: dict[int, RequestState] = {}
        while self._queue or self._active:
            self.step()
            for rid in list(in_flight):
                rs = self._finished.get(rid)
                if rs is not None:
                    results[rid] = rs
                    in_flight.discard(rid)
        return [results[r] for r in sorted(results)]

    # -- one scheduling iteration ------------------------------------------
    def step(self) -> bool:
        """Admit from the queue, then run one decode step over active slots.

        Returns True if a decode step ran."""
        self._admit_pending()
        if not self._active:
            return False

        self._key, sub = jax.random.split(self._key)
        logits, self._states = self._decode(
            self.params,
            self._states,
            jnp.asarray(self._tokens),
            jnp.asarray(self._active_mask),
        )
        self.last_decode_logits = logits
        cols = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(self._temps), sub))
        self.total_decode_steps += 1

        now = time.perf_counter()
        for slot, rs in list(self._active.items()):
            rs.decode_steps += 1
            tok = int(cols[slot])
            rs.tokens.append(tok)
            self._tokens[slot, 0] = tok
            self._maybe_finish(rs, now)
        return True

    # -- internals ----------------------------------------------------------
    def _admit_pending(self) -> None:
        while self._free_slots and self._queue:
            rs = self._queue.popleft()
            req = rs.request
            slot = heapq.heappop(self._free_slots)

            prompt_len = req.prompt.shape[0] + (self.cfg.prefix_len or 0)
            assert (
                prompt_len + req.max_new_tokens <= self.sched.cache_len
                or self.cfg.supports_long_context
                or self.cfg.window_size
            ), (
                f"cache_len {self.sched.cache_len} too small for "
                f"{prompt_len}+{req.max_new_tokens}"
            )

            batch = {"tokens": jnp.asarray(req.prompt)[None, :]}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)
            logits, pstates = self._prefill(self.params, batch)

            layers, pos = self._admit_jit(
                self._states["layers"],
                self._states["pos"],
                pstates["layers"],
                jnp.asarray(slot, jnp.int32),
                prompt_len,
            )
            self._states = {"layers": layers, "pos": pos}

            now = time.perf_counter()
            self._key, sub = jax.random.split(self._key)
            first = int(
                np.asarray(
                    self._sample(
                        logits[:, -1, :],
                        jnp.full((1,), req.temperature, jnp.float32),
                        sub,
                    )
                )[0]
            )
            rs.slot = slot
            rs.status = RequestStatus.ACTIVE
            rs.tokens = [first]
            rs.prefill_logits = np.asarray(logits[:, -1:, :])
            rs.t_admit = now
            rs.t_first_token = now
            self._tokens[slot, 0] = first
            self._temps[slot] = req.temperature
            self._active_mask[slot] = True
            self._active[slot] = rs
            # A 1-token request (or an immediate stop) retires before ever
            # riding the decode step, freeing the slot for this admission loop.
            self._maybe_finish(rs, now)

    def _maybe_finish(self, rs: RequestState, now: float) -> None:
        req = rs.request
        reason = None
        if req.stop_token >= 0 and rs.tokens[-1] == req.stop_token:
            reason = "stop"
        elif len(rs.tokens) >= req.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        slot = rs.slot
        assert slot is not None
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        rs.status = RequestStatus.FINISHED
        rs.finish_reason = reason
        rs.t_finish = now
        self._finished[rs.rid] = rs
        # Bound retention for long-running serving: evict the oldest finished
        # states (dict preserves insertion order) beyond keep_finished.
        while len(self._finished) > self.sched.keep_finished:
            self._finished.pop(next(iter(self._finished)))

    def stats(self) -> dict[str, Any]:
        done = [r for r in self._finished.values()]
        toks = sum(len(r.tokens) for r in done)
        return {
            "finished": len(done),
            "generated_tokens": toks,
            "decode_steps": self.total_decode_steps,
            "decode_traces": self.decode_traces,
            "pending": self.pending,
            "active": self.num_active,
        }
