"""Continuous-batching serving core: the executor over plan / program /
memory layers.

Four layers with narrow interfaces: **plan** (serve/plan.py) makes pure
host-side decisions from plain values plus MemoryManager capacity
queries (no JAX); **programs** (serve/programs.py) owns every jitted
program plus trace accounting and sharding glue; **memory**
(serve/memory.py) fronts the refcounted PagePool(s), CoW forks, prefix
index, and host page-table mirror — with a `data` mesh axis the pool
splits into per-shard sub-pools aligned with the GSPMD blocks of the
page-axis-sharded pool leaves, so `data > 1` partitions state instead
of replicating it; the **Scheduler** here (with executors in
admission.py / chunk_exec.py / preempt.py / spec_exec.py) owns request
lifecycle and loops plan → execute → observe, publishing each step's
decisions as an immutable `BatchPlan` (`last_plan`) and planner time as
`plan_time_s`. Semantics are unchanged from the pre-split scheduler and
pinned by the serve suites: greedy outputs token-identical to
`generate_static`, one trace per program bucket, inactive slots decode
garbage into trash pages behind frozen positions.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, replace as _dc_replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import lm
from repro.serve import admission, chunk_exec, preempt, spec_exec
from repro.serve import plan as planlib
from repro.serve.draft import Drafter, NgramDrafter
from repro.serve.memory import MemoryManager
from repro.serve.pages import PageLayout, cdiv, model_page_span
from repro.serve.programs import ProgramRegistry, paged_cache_bytes
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.step import (
    decode_state_shardings,
    init_decode_state,
    init_paged_decode_state,
)
from repro.sharding.rules import ShardingCtx, get_profile

_RECURRENT_KINDS = {"rglru", "mlstm", "slstm"}


@dataclass
class SchedulerConfig:
    n_slots: int = 4  # concurrent sequences in the batched decode state
    cache_len: int = 256  # per-slot logical cache slots
    seed: int = 0
    keep_finished: int = 1024  # finished RequestStates retained for result()
    # Paged KV pool; n_pages=None sizes it at contiguous capacity parity.
    paged: bool = True
    page_size: int = 16  # tokens per page
    n_pages: int | None = None
    # Pow2 prompt buckets: prefill/admit compile once per bucket
    # (auto-disabled for recurrent models).
    prefill_buckets: bool = True
    min_bucket: int = 8
    # Unified token-budget step: one decode token per slot plus a prefill
    # chunk <= pow2_floor(chunk_budget). None -> whole-prompt prefill.
    chunk_budget: int | None = None
    min_chunk: int = 16
    # "off" reserves the worst case at admission; "swap" / "recompute"
    # admit reservation-free and reclaim LRU pages on OOM (needs chunking).
    preemption: str = "off"
    # Content-address full prompt pages and adopt matches at admission
    # (CoW-protected); fully-paged streaming-capable models only.
    prefix_sharing: bool = True
    # Per-tenant worst-case page quota (None -> unlimited) and stride-
    # scheduled ordering over weights (None -> exact FIFO).
    tenant_quota: int | None = None
    tenant_weights: dict[str, float] | None = None
    # Draft up to draft_k tokens per greedy ACTIVE slot, verify in one
    # all-logits chunk call; drafter=None installs NgramDrafter.
    speculative: bool = False
    draft_k: int = 4
    drafter: Drafter | None = None
    # ("data", "model") mesh: model shards heads/experts per the profile;
    # a data axis dividing n_slots AND n_pages partitions slots and the
    # page pool per shard (serve/memory.py). Tables stay host-side.
    mesh_shape: tuple[int, int] | None = None
    sharding_profile: str = "decode_default"


class Scheduler:
    def __init__(
        self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, sched: SchedulerConfig
    ):
        self.cfg = cfg
        self.params = params
        if sched.mesh_shape is not None and sctx.mesh is None:
            d, m = (int(x) for x in sched.mesh_shape)
            if d * m > 1:
                from repro.launch.mesh import make_test_mesh

                sctx = ShardingCtx(
                    make_test_mesh(data=d, model=m),
                    get_profile(sched.sharding_profile),
                )
        self.sctx = sctx
        self.sched = sched
        n = sched.n_slots
        if sched.preemption not in ("off", "swap", "recompute"):
            raise ValueError(f"unknown preemption policy {sched.preemption!r}")
        if sched.preemption != "off" and sched.chunk_budget is None:
            raise ValueError(
                "preemption requires the unified token-budget step "
                "(set chunk_budget)"
            )
        if sched.tenant_quota is not None and sched.tenant_quota < 1:
            raise ValueError(f"tenant_quota must be >= 1, got {sched.tenant_quota}")
        if sched.tenant_weights is not None and any(
            w <= 0 for w in sched.tenant_weights.values()
        ):
            raise ValueError("tenant_weights must be positive")
        self._chunked = sched.chunk_budget is not None
        if self._chunked and sched.chunk_budget < sched.min_chunk:
            raise ValueError(
                f"chunk_budget {sched.chunk_budget} < min_chunk {sched.min_chunk}"
            )
        # Chunked streaming handles token-only requests; modality prefixes
        # and enc-dec cross caches go through whole-prompt prefill.
        self._stream_capable = self._chunked and not cfg.enc_dec and not cfg.prefix_len
        if sched.speculative and sched.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {sched.draft_k}")
        # Speculation rides chunk_step (token-only decoder stacks); per-
        # request gating (greedy only, no extras) happens in spec_exec.
        self._spec = sched.speculative and not cfg.enc_dec and not cfg.prefix_len
        self._drafter: Drafter | None = None
        if self._spec:
            self._drafter = (
                sched.drafter if sched.drafter is not None else NgramDrafter()
            )

        span = model_page_span(cfg, sched.cache_len) if sched.paged else 0
        self._paged = span > 0
        if self._paged:
            n_pages = (
                sched.n_pages
                if sched.n_pages is not None
                else n * cdiv(span, sched.page_size)
            )
            # Data-parallel pool partitioning kicks in when the data axis
            # divides both the slot count and the pool — otherwise the pool
            # stays single-shard (its leaves replicate over data, exactly
            # the pre-partitioning layout).
            dsize = sctx.axis_size("data")
            d_eff = (
                dsize if dsize > 1 and n_pages % dsize == 0 and n % dsize == 0
                else 1
            )
            if d_eff > 1:
                # Tell the model layer the pool is truly partitioned so
                # shard_map'd paged kernels localize page ids per shard.
                sctx = _dc_replace(sctx, pool_data_shards=d_eff)
                self.sctx = sctx
            layout = PageLayout(
                page_size=sched.page_size, n_pages=n_pages, span=span,
                data_shards=d_eff,
            )
            self.mem = MemoryManager(layout, n)
            state = init_paged_decode_state(cfg, n, sched.cache_len, layout, sctx=sctx)
        else:
            self.mem = MemoryManager(None, n)
            state = init_decode_state(cfg, n, sched.cache_len, sctx=sctx)
            state["pos"] = jnp.zeros((n,), jnp.int32)
        self._layer_shardings = decode_state_shardings(
            cfg, n, sched.cache_len, sctx, pages=self.mem.layout
        )
        if self._layer_shardings is not None:
            from repro.models.schema import shard_tree

            self.params = shard_tree(params, lm.model_schema(cfg), sctx)

        # The program registry owns every jitted closure (and the sharded
        # params reference the chunk body closes over — shard first).
        self._layouts = blk.stack_layouts(cfg, sched.cache_len, paged=self._paged)
        caps = blk.stack_paged_caps(cfg, sched.cache_len) if self._paged else None
        self.programs = ProgramRegistry(
            cfg, sctx, self.params,
            cache_len=sched.cache_len, layouts=self._layouts, caps=caps,
            layer_shardings=self._layer_shardings,
            page_size=sched.page_size if self._paged else 0, paged=self._paged,
        )
        if self._paged:
            state["page_table"] = self._put(self.mem.pt)
        self._states: dict[str, Any] = state
        self._tokens = np.zeros((n, 1), np.int32)  # next input token per slot
        self._temps = np.zeros((n,), np.float32)
        self._active_mask = np.zeros((n,), bool)
        self._pos_host = np.zeros((n,), np.int64)  # tokens cached per slot

        kinds = set(cfg.block_pattern) | set(cfg.first_blocks)
        self._bucketed = sched.prefill_buckets and not (kinds & _RECURRENT_KINDS)
        # Rejected draft positions leave inert garbage in dense/MLA caches,
        # but corrupt state that *advanced* (recurrent carries, windowed
        # ring folds) — those archs roll back by snapshot replay.
        self._needs_replay = bool(kinds & _RECURRENT_KINDS) or (
            "local_attn" in kinds
        )
        # Prefix sharing needs every stateful leaf behind the page table:
        # only fully dense-paged streaming models share.
        self._sharing = (
            sched.prefix_sharing
            and self._paged
            and self._stream_capable
            and kinds <= {"attn_mlp", "attn_moe"}
            and kinds <= blk.paged_kv_kinds(cfg)
        )
        self._slot_worst: dict[int, tuple[str, int]] = {}  # slot -> (tenant, pages)
        self._tenant_pass: dict[str, float] = {}  # stride-scheduling virtual time

        self._queue: deque[RequestState] = deque()
        self._preempted: deque[RequestState] = deque()  # resume before admits
        self._active: dict[int, RequestState] = {}  # slot -> request
        self._free_slots: list[int] = list(range(n))
        heapq.heapify(self._free_slots)
        self._finished: dict[int, RequestState] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(sched.seed)

        self.total_decode_steps = 0
        self.total_chunk_steps = 0
        self.total_spec_steps = 0  # verify calls (one slot each)
        self.total_spec_replays = 0  # partial-accept rollback replays
        self.spec_fallbacks = 0  # drafts dropped for lack of pages
        self.drafted_tokens_total = 0
        self.accepted_tokens_total = 0
        self.deferred_admissions = 0  # pool-backpressure events
        self.quota_deferrals = 0  # tenant-quota skip events
        self.preemptions_total = 0
        self.prefix_hits = 0  # admissions that adopted >= 1 indexed page
        self.prefix_hit_tokens = 0  # prompt tokens satisfied by adoption
        self.finished_total = 0  # cumulative, survives keep_finished eviction
        self.generated_tokens_total = 0
        self.last_decode_logits: jax.Array | None = None
        self.last_plan: planlib.BatchPlan = planlib.BatchPlan()
        self.plan_time_s = 0.0  # cumulative time inside plan-layer calls
        self._ev: dict[str, Any] = {
            "admits": [], "chunk": None, "verifies": [], "rows": (),
            "preempted": [],
        }

    # -- layer glue ----------------------------------------------------------
    def _plan(self, fn, *args, **kw):
        """Run a plan-layer function, accounting its wall time."""
        t = time.perf_counter()
        out = fn(*args, **kw)
        self.plan_time_s += time.perf_counter() - t
        return out

    def _put(self, x):
        return self.programs.put(x)

    def _constrain_layers(self, layers):
        return self.programs.constrain_layers(layers)

    @property
    def pool(self):
        return self.mem.pool

    @property
    def pages(self):
        return self.mem.layout

    @property
    def _pt(self):
        return self.mem.pt

    @property
    def _slot_keys(self):
        return self.mem.slot_keys

    @property
    def _slot_reg(self):
        return self.mem.slot_reg

    # -- client API ----------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            RequestState(request=request, rid=rid, t_submit=time.perf_counter())
        )
        return rid

    def reset_rng(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)

    def set_drafter(self, drafter: Drafter) -> None:
        """Swap the draft proposer. No-op with speculation off."""
        if self._spec:
            self._drafter = drafter

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._preempted)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def result(self, rid: int) -> RequestState:
        rs = self._finished.get(rid)
        if rs is not None:
            return rs
        in_flight = (
            any(r.rid == rid for r in self._active.values())
            or any(r.rid == rid for r in self._queue)
            or any(r.rid == rid for r in self._preempted)
        )
        if in_flight:
            raise KeyError(f"request {rid} is not finished yet")
        if 0 <= rid < self._next_rid:
            raise KeyError(
                f"request {rid} finished but its result was evicted "
                f"(keep_finished={self.sched.keep_finished}); raise "
                "keep_finished or collect results as requests retire (run())"
            )
        raise KeyError(f"unknown request id {rid}")

    def run(self) -> list[RequestState]:
        """Drive steps until queue and slots drain; returns finished states
        for the requests in flight at call time, in submission order
        (collected as requests retire, surviving keep_finished)."""
        in_flight = (
            {rs.rid for rs in self._queue}
            | {rs.rid for rs in self._active.values()}
            | {rs.rid for rs in self._preempted}
        )
        results: dict[int, RequestState] = {}
        while self._queue or self._active or self._preempted:
            self.step()
            for rid in list(in_flight):
                rs = self._finished.get(rid)
                if rs is not None:
                    results[rid] = rs
                    in_flight.discard(rid)
        return [results[r] for r in sorted(results)]

    # -- one scheduling iteration --------------------------------------------
    def step(self) -> bool:
        """One plan → execute → observe iteration: admit/resume, stream at
        most one prefill chunk, per-slot speculative verifies, then one
        decode step over the remaining rows. The decisions taken are
        published as `last_plan`. Returns True if any program ran."""
        self._ev = {
            "admits": [], "chunk": None, "verifies": [], "rows": (),
            "preempted": [],
        }
        try:
            return self._step()
        finally:
            e = self._ev
            self.last_plan = planlib.BatchPlan(
                admitted=tuple(e["admits"]), chunk=e["chunk"],
                verifies=tuple(e["verifies"]), decode_rows=e["rows"],
                preempted=tuple(e["preempted"]),
            )

    def _step(self) -> bool:
        self._admit_pending()
        ran = False
        if self._chunked:
            ran = self._prefill_chunk_step()
        handled: set[int] = set()
        if self._spec and self._active_mask.any():
            handled = self._spec_step()
            ran = ran or bool(handled)
        # Slots that already emitted via verify sit out this decode: their
        # cleared mask freezes pos and per-slot states exactly like a
        # PREFILLING slot's.
        rows = self._plan(planlib.decode_rows, self._active_mask, handled)
        self._ev["rows"] = rows
        if not rows:
            return ran
        mask = np.zeros_like(self._active_mask)
        mask[list(rows)] = True
        if self._paged:
            self._grow_pages(skip=handled)
            if self._sharing:
                # CoW guard: decode writes one token per ACTIVE slot at its
                # current position — fork first if that page is shared (the
                # scheduler's write pattern keeps this a no-op, but the
                # invariant is enforced here, not assumed).
                for slot, rs in list(self._active.items()):
                    if rs.status is RequestStatus.ACTIVE and slot not in handled:
                        p = int(self._pos_host[slot])
                        self._apply_cow(self.mem.prepare_write(slot, p, p + 1))
            self._states["page_table"] = self._put(self.mem.pt)

        self._key, sub = jax.random.split(self._key)
        logits, self._states = self.programs.decode(
            self.params, self._states, self._put(self._tokens), self._put(mask)
        )
        self.last_decode_logits = logits
        cols = np.asarray(
            self.programs.sample(logits[:, -1, :], jnp.asarray(self._temps), sub)
        )
        self.total_decode_steps += 1

        now = time.perf_counter()
        for slot, rs in list(self._active.items()):
            if rs.status is not RequestStatus.ACTIVE or slot in handled:
                continue  # still streaming its prompt in, or emitted via spec
            rs.decode_steps += 1
            self._pos_host[slot] += 1
            tok = int(cols[slot])
            rs.tokens.append(tok)
            rs.t_tokens.append(now)
            self._tokens[slot, 0] = tok
            self._maybe_finish(rs, now)
        return True

    # -- chunked prefill (executor in serve/chunk_exec.py) -------------------
    def _prefill_chunk_step(self) -> bool:
        return chunk_exec.prefill_chunk_step(self)

    def _finish_prefill(self, rs: RequestState, logits: jax.Array) -> None:
        chunk_exec.finish_prefill(self, rs, logits)

    # -- speculative decoding (executor in serve/spec_exec.py) ---------------
    def _spec_step(self) -> set[int]:
        return spec_exec.spec_step(self)

    def _verify_slot(self, slot: int, rs: RequestState, draft: np.ndarray) -> bool:
        return spec_exec.verify_slot(self, slot, rs, draft)

    # -- pages & preemption (executor in serve/preempt.py) -------------------
    def _apply_cow(self, forks: list[tuple[int, int, int]]) -> None:
        preempt.apply_cow(self, forks)

    def _ensure_pages(self, slot: int, n_total: int, rid: int | None = None) -> bool:
        return preempt.ensure_pages(self, slot, n_total, rid=rid)

    def _grow_pages(self, skip: set[int] = frozenset()) -> None:
        preempt.grow_pages(self, skip=skip)

    def _can_preempt(self, rs: RequestState) -> bool:
        return preempt.can_preempt(self, rs)

    def _preempt_lru(
        self, protect: int, requester_rid: int | None = None,
        shard: int | None = None,
    ) -> bool:
        return preempt.preempt_lru(
            self, protect, requester_rid=requester_rid, shard=shard
        )

    def _preempt_slot(self, slot: int) -> None:
        preempt.preempt_slot(self, slot)

    # -- admission (executor in serve/admission.py) --------------------------
    def _bucket_len(self, token_len: int) -> int:
        """Power-of-two padded token count (plan layer; identity when
        bucketing is off)."""
        return self._plan(
            planlib.bucket_len, token_len,
            bucketed=self._bucketed, min_bucket=self.sched.min_bucket,
            cache_len=self.sched.cache_len, prefix_len=self.cfg.prefix_len or 0,
            long_ok=bool(self.cfg.supports_long_context or self.cfg.window_size),
        )

    def _worst_pages(self, rs: RequestState) -> int:
        """Worst-case page footprint of a request (0 when not paged)."""
        if not self._paged:
            return 0
        req = rs.request
        prompt_len = req.prompt.shape[0] + (self.cfg.prefix_len or 0)
        return self.mem.pages_for_len(prompt_len + req.max_new_tokens)

    def _tenant_pages(self, tenant: str) -> int:
        """Worst-case pages currently charged to ``tenant``'s slots."""
        return sum(w for t, w in self._slot_worst.values() if t == tenant)

    def _pick_next(self, blocked: set[str]) -> RequestState | None:
        """Weighted-fair pick (plan-layer stride scheduling)."""
        rid = self._plan(
            planlib.pick_next,
            [planlib.QueueView(rs.rid, rs.request.tenant) for rs in self._queue],
            blocked, self._tenant_pass,
        )
        if rid is None:
            return None
        for rs in self._queue:
            if rs.rid == rid:
                return rs
        return None  # pragma: no cover - rid came from the queue

    def _charge_tenant(self, rs: RequestState) -> None:
        req = rs.request
        weights = self.sched.tenant_weights or {}
        self._tenant_pass = self._plan(
            planlib.charge_tenant, self._tenant_pass, req.tenant,
            req.prompt.shape[0] + req.max_new_tokens,
            weights.get(req.tenant, 1.0),
        )

    def _admit_pending(self) -> None:
        admission.admit_pending(self)

    def _admit(self, rs: RequestState) -> bool:
        return admission.admit(self, rs)

    def _check_fits(self, rs: RequestState, prompt_len: int) -> int:
        return admission.check_fits(self, rs, prompt_len)

    def _admit_streaming(self, rs: RequestState) -> bool:
        return admission.admit_streaming(self, rs)

    def _try_resume(self, rs: RequestState) -> bool:
        return admission.try_resume(self, rs)

    def _admit_prefill(self, rs: RequestState) -> bool:
        return admission.admit_prefill(self, rs)

    def _maybe_finish(self, rs: RequestState, now: float) -> None:
        req = rs.request
        reason = None
        if req.stop_token >= 0 and rs.tokens[-1] == req.stop_token:
            reason = "stop"
        elif len(rs.tokens) >= req.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        slot = rs.slot
        assert slot is not None
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        self._pos_host[slot] = 0
        self._slot_worst.pop(slot, None)
        if self._paged:
            # Free pages and trash-point the table row so the retired slot's
            # frozen-position garbage writes can never touch a future tenant
            # of these pages; indexed pages park in the pool's cached list
            # for the next same-prefix admission.
            self.mem.release(slot)
        rs.status = RequestStatus.FINISHED
        rs.finish_reason = reason
        rs.t_finish = now
        self._finished[rs.rid] = rs
        self.finished_total += 1
        self.generated_tokens_total += len(rs.tokens)
        # Bound retention for long-running serving.
        while len(self._finished) > self.sched.keep_finished:
            self._finished.pop(next(iter(self._finished)))

    def stats(self) -> dict[str, Any]:
        out = {
            # Cumulative — monotone even after keep_finished eviction.
            "finished": self.finished_total,
            "generated_tokens": self.generated_tokens_total,
            "retained": len(self._finished),
            "decode_steps": self.total_decode_steps,
            "chunk_steps": self.total_chunk_steps,
            "spec_steps": self.total_spec_steps,
            "spec_replays": self.total_spec_replays,
            "spec_fallbacks": self.spec_fallbacks,
            "drafted_tokens": self.drafted_tokens_total,
            "accepted_tokens": self.accepted_tokens_total,
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
            "admit_traces": self.admit_traces,
            "chunk_traces": self.chunk_traces,
            "swap_traces": self.swap_traces,
            "cow_traces": self.cow_traces,
            "verify_traces": self.verify_traces,
            "pending": self.pending,
            "active": self.num_active,
            "deferred_admissions": self.deferred_admissions,
            "quota_deferrals": self.quota_deferrals,
            "preemptions": self.preemptions_total,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "plan_time_s": self.plan_time_s,
        }
        out["mesh"] = (
            None if self.sctx.mesh is None else dict(self.sctx.mesh.shape)
        )
        out["mesh_devices"] = self.sctx.device_count()
        if self._paged:
            out["pages"] = self.mem.stats()
        return out

    def paged_cache_bytes(self) -> dict[str, int]:
        """Actual vs contiguous-equivalent cache bytes (see programs.py)."""
        return paged_cache_bytes(
            self.cfg, self.sched.cache_len, self.sched.n_slots, self._states,
            self._layer_shardings, self.sctx, self.mem,
        )


def _delegate_trace(name: str):
    return property(
        lambda self: getattr(self.programs, name),
        lambda self, v: setattr(self.programs, name, v),
    )


def _delegate_prog(name: str):
    return property(lambda self: getattr(self.programs, name))


# Trace counters live on the program registry (incremented inside jit
# trace bodies); the historical Scheduler attributes stay as delegates,
# as do the historical names of the jitted callables.
for _n in (
    "decode_traces", "prefill_traces", "admit_traces", "chunk_traces",
    "swap_traces", "cow_traces", "verify_traces",
):
    setattr(Scheduler, _n, _delegate_trace(_n))
for _old, _new in (
    ("_decode", "decode"), ("_prefill", "prefill"), ("_admit_jit", "admit"),
    ("_chunk_jit", "chunk"), ("_verify_jit", "verify"),
    ("_setpos_jit", "setpos"), ("_reset_jit", "reset"), ("_cow_jit", "cow"),
    ("_swap_out_jit", "swap_out"), ("_swap_in_jit", "swap_in"),
    ("_sample", "sample"),
):
    setattr(Scheduler, _old, _delegate_prog(_new))
del _n, _old, _new
