"""Continuous-batching request scheduler over a paged KV block pool.

The scheduler owns ``n_slots`` persistent decode slots backed by one batched
decode state. Dense and windowed attention KV caches live in a shared
**page pool** — ``n_pages`` fixed-size pages multiplexed across all slots
through a per-slot page table (see serve/pages.py) — so a slot's cache
footprint is its live tokens rounded up to pages, not a worst-case
``cache_len`` row. MLA compressed caches, recurrent states, and enc-dec
caches keep their per-slot layout behind the same interface; models with
no paged layer kind run exactly the PR-1 contiguous path.

**Unified token-budget step.** With ``chunk_budget`` set, each ``step()``
composes one bounded batch of work: every decoding slot contributes one
token, plus a prefill *chunk* of the oldest prompt still streaming in
(``RequestStatus.PREFILLING``). Long prompts therefore enter the paged
KV over several steps — decode cadence never stalls behind a 4k-token
prefill. Chunk sizes are drawn from a fixed power-of-two bucket set
(``min_chunk`` .. ``pow2_floor(chunk_budget)``), deliberately independent
of the live decode count so the loaded system never meets a chunk shape
the idle warmup didn't compile; per-step work is bounded by
``chunk_budget + n_slots`` tokens. With ``chunk_budget=None`` the PR-1/2
lifecycle is unchanged: whole-prompt prefill + graft at admission.

**Page-aware preemption.** ``preemption="off"`` keeps worst-case page
reservations at admission (prompt + max_new_tokens; OOM backpressure
defers the queue). ``"swap"`` / ``"recompute"`` admit **reservation-free**:
pages are reserved incrementally per chunk and per decode page-boundary
crossing, and when the pool runs dry the LRU decoding slot is preempted —
its pages (and per-slot states) snapshot to host memory (``swap``) or are
dropped and re-derived by re-streaming prompt + generated tokens
(``recompute``). Preempted requests resume ahead of fresh admissions and
continue token-identically (greedy) from where they left off.

The decode hot path is shape-stable by construction: tokens ``(n_slots,
1)``, active mask ``(n_slots,)``, positions ``(n_slots,)``, page table
``(n_slots, max_pages)`` int32 — joins, leaves, chunk streaming, page
growth, and preemption only change array *values*, so the step never
recompiles after its single warmup trace (``decode_traces``;
``prefill_traces``/``admit_traces`` count per-bucket compiles of the
legacy path, ``chunk_traces`` per chunk bucket, ``swap_traces`` the
swap-out/in pair). Inactive slots keep decoding garbage with a frozen
position; their writes land in the trash page (paged) or their own
about-to-be-overwritten row (contiguous), so no live state is ever
visible through the masks.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import lm
from repro.serve.cache import (
    _graft_leaf,
    extract_slot_leaf,
    gather_pages_leaf,
    graft_pages_leaf,
    graft_states,
    insert_slot,
    insert_slot_leaf,
    scatter_pages_leaf,
)
from repro.serve.pages import PageLayout, PagePool, cdiv, model_page_span
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.step import (
    fresh_slot_layers,
    init_decode_state,
    init_paged_decode_state,
)
from repro.sharding.rules import ShardingCtx

_RECURRENT_KINDS = {"rglru", "mlstm", "slstm"}


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class SchedulerConfig:
    n_slots: int = 4  # concurrent sequences in the batched decode state
    cache_len: int = 256  # per-slot logical cache slots (>= prompt + new tokens for dense)
    seed: int = 0
    keep_finished: int = 1024  # finished RequestStates retained for result()
    # Paged KV pool (dense/windowed attention caches). n_pages=None sizes the
    # pool at capacity parity with the contiguous layout (n_slots full rows);
    # shrink it to multiplex a smaller pool across mixed-size requests.
    paged: bool = True
    page_size: int = 16  # tokens per page
    n_pages: int | None = None
    # Pad prompts to power-of-two buckets so prefill/admit compile once per
    # bucket (auto-disabled for recurrent models, whose states would absorb
    # the pad tokens).
    prefill_buckets: bool = True
    min_bucket: int = 8
    # Unified token-budget step: bounds per-step work at one token per
    # decoding slot plus a prefill chunk of at most pow2_floor(chunk_budget)
    # tokens (power-of-two buckets >= min_chunk). None -> whole-prompt
    # prefill at admission.
    chunk_budget: int | None = None
    min_chunk: int = 16
    # Page-aware preemption (requires chunk_budget): "off" reserves the
    # worst case at admission; "swap" / "recompute" admit reservation-free
    # and reclaim the LRU decoding slot's pages on OOM.
    preemption: str = "off"


class Scheduler:
    def __init__(
        self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, sched: SchedulerConfig
    ):
        self.cfg = cfg
        self.params = params
        self.sctx = sctx
        self.sched = sched
        n = sched.n_slots
        if sched.preemption not in ("off", "swap", "recompute"):
            raise ValueError(f"unknown preemption policy {sched.preemption!r}")
        if sched.preemption != "off" and sched.chunk_budget is None:
            raise ValueError(
                "preemption requires the unified token-budget step "
                "(set chunk_budget)"
            )
        self._chunked = sched.chunk_budget is not None
        if self._chunked and sched.chunk_budget < sched.min_chunk:
            raise ValueError(
                f"chunk_budget {sched.chunk_budget} < min_chunk {sched.min_chunk}"
            )
        # Chunked streaming handles token-only requests; modality prefixes
        # and enc-dec cross caches go through whole-prompt prefill.
        self._stream_capable = self._chunked and not cfg.enc_dec and not cfg.prefix_len

        span = model_page_span(cfg, sched.cache_len) if sched.paged else 0
        self._paged = span > 0
        if self._paged:
            n_pages = (
                sched.n_pages
                if sched.n_pages is not None
                else n * cdiv(span, sched.page_size)
            )
            self.pages: PageLayout | None = PageLayout(
                page_size=sched.page_size, n_pages=n_pages, span=span
            )
            self.pool: PagePool | None = PagePool(self.pages)
            state = init_paged_decode_state(cfg, n, sched.cache_len, self.pages)
            self._pt = np.full((n, self.pages.max_pages), self.pages.trash, np.int32)
            state["page_table"] = jnp.asarray(self._pt)
        else:
            self.pages = None
            self.pool = None
            state = init_decode_state(cfg, n, sched.cache_len)
            state["pos"] = jnp.zeros((n,), jnp.int32)
        self._states: dict[str, Any] = state
        self._tokens = np.zeros((n, 1), np.int32)  # next input token per slot
        self._temps = np.zeros((n,), np.float32)
        self._active_mask = np.zeros((n,), bool)
        self._pos_host = np.zeros((n,), np.int64)  # tokens cached per slot

        kinds = set(cfg.block_pattern) | set(cfg.first_blocks)
        self._bucketed = sched.prefill_buckets and not (kinds & _RECURRENT_KINDS)

        self._queue: deque[RequestState] = deque()
        self._preempted: deque[RequestState] = deque()  # resume before admits
        self._active: dict[int, RequestState] = {}  # slot -> request
        self._free_slots: list[int] = list(range(n))
        heapq.heapify(self._free_slots)
        self._finished: dict[int, RequestState] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(sched.seed)

        self.decode_traces = 0  # jit trace count of the decode hot path
        self.prefill_traces = 0  # one per prompt bucket
        self.admit_traces = 0  # one per prompt bucket
        self.chunk_traces = 0  # one per chunk bucket
        self.swap_traces = 0  # swap-out + swap-in programs
        self.total_decode_steps = 0
        self.total_chunk_steps = 0
        self.deferred_admissions = 0  # pool-backpressure events
        self.preemptions_total = 0
        self.finished_total = 0  # cumulative, survives keep_finished eviction
        self.generated_tokens_total = 0
        self.last_decode_logits: jax.Array | None = None

        # Per-leaf logical capacities: >0 marks a shared-pool KV leaf (no
        # batch axis; passed through untouched by per-slot surgery).
        caps = blk.stack_paged_caps(cfg, sched.cache_len) if self._paged else None

        def _slot_surgery_trees():
            template = init_decode_state(self.cfg, 1, self.sched.cache_len)["layers"]
            c = caps if caps is not None else jax.tree.map(lambda _: 0, template)
            return c, template

        def _freeze_inactive(active, new_layers, old_layers):
            # Inactive slots (free, or PREFILLING between chunks) must keep
            # their per-slot states verbatim across other slots' decode
            # steps: positional KV survives by write-before-read, but a
            # recurrence would absorb the masked slot's garbage token.
            # Shared-pool leaves have no batch row to freeze — their
            # garbage writes stay behind the trash page / the positions the
            # next chunk overwrites.
            c, template = _slot_surgery_trees()

            def leaf(cap, new, old, t):
                if cap:
                    return new
                nd, td = jnp.asarray(new), jnp.asarray(t)
                if nd.shape == td.shape:  # n_slots == 1
                    return jnp.where(active[0], nd, old)
                ax = [i for i in range(nd.ndim) if nd.shape[i] != td.shape[i]][0]
                shape = [1] * nd.ndim
                shape[ax] = nd.shape[ax]
                return jnp.where(active.reshape(shape), nd, old)

            return jax.tree.map(leaf, c, new_layers, old_layers, template)

        def _decode_fn(params, states, token, active):
            # Python body runs only when jit (re)traces: counts compilations.
            self.decode_traces += 1
            logits, new_states = lm.decode_step(params, self.cfg, states, token, self.sctx)
            # Freeze inactive slots in place (position and per-slot states).
            new_pos = jnp.where(active, new_states["pos"], states["pos"])
            out = {
                "layers": _freeze_inactive(
                    active, new_states["layers"], states["layers"]
                ),
                "pos": new_pos,
            }
            if "page_table" in new_states:
                out["page_table"] = new_states["page_table"]
            return logits, out

        self._decode = jax.jit(_decode_fn)

        def _prefill_fn(p, b):
            self.prefill_traces += 1
            return lm.prefill(p, self.cfg, b, self.sctx)

        self._prefill = jax.jit(_prefill_fn)

        if self._paged:
            page_size = self.pages.page_size

            def _admit_fn(layers, pos, prefill_layers, slot, page_ids, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(self.cfg, 1, self.sched.cache_len)["layers"]

                def leaf(cap, full, tgt, src):
                    if cap:  # shared-pool KV leaf: scatter pages
                        return graft_pages_leaf(
                            full, src, page_ids, prompt_len, cap, page_size
                        )
                    return insert_slot_leaf(full, _graft_leaf(tgt, src, prompt_len), slot)

                new_layers = jax.tree.map(leaf, caps, layers, target, prefill_layers)
                return new_layers, pos.at[slot].set(prompt_len)

        else:

            def _admit_fn(layers, pos, prefill_layers, slot, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(self.cfg, 1, self.sched.cache_len)
                slot_layers = graft_states(target["layers"], prefill_layers, prompt_len)
                new_layers = insert_slot(layers, slot_layers, slot)
                return new_layers, pos.at[slot].set(prompt_len)

        # slot and prompt_len are traced, so admission compiles once per
        # prefill *shape* — with bucketing, once per bucket.
        self._admit_jit = jax.jit(_admit_fn)

        # -- unified-step programs (chunk streaming, slot reset, swap) -------
        def _chunk_body(layers, pos, tokens, slot, start, chunk_len, page_ids):
            c, template = _slot_surgery_trees()
            slot_layers = jax.tree.map(
                lambda cap, full, t: full if cap else extract_slot_leaf(full, t, slot),
                c, layers, template,
            )
            states: dict[str, Any] = {"layers": slot_layers, "pos": start}
            if page_ids is not None:
                states["page_table"] = page_ids[None, :]
            logits, new = lm.chunk_step(
                self.params, self.cfg, states, tokens, chunk_len, self.sctx
            )
            new_layers = jax.tree.map(
                lambda cap, full, s: s if cap else insert_slot_leaf(full, s, slot),
                c, layers, new["layers"],
            )
            return logits, new_layers, pos.at[slot].set(start + chunk_len)

        if self._paged:

            def _chunk_fn(layers, pos, tokens, slot, start, chunk_len, page_ids):
                self.chunk_traces += 1
                return _chunk_body(layers, pos, tokens, slot, start, chunk_len, page_ids)

        else:

            def _chunk_fn(layers, pos, tokens, slot, start, chunk_len):
                self.chunk_traces += 1
                return _chunk_body(layers, pos, tokens, slot, start, chunk_len, None)

        self._chunk_jit = jax.jit(_chunk_fn)

        def _reset_fn(layers, pos, slot):
            # Reset the slot's per-slot leaves to the empty-recurrence state
            # so a chunked prefill starts from what a from-scratch prefill
            # would derive. Pool leaves stay: the trash-pointed table row
            # isolates them.
            c, _ = _slot_surgery_trees()
            fresh = fresh_slot_layers(self.cfg, self.sched.cache_len)
            new_layers = jax.tree.map(
                lambda cap, full, t: full if cap else insert_slot_leaf(full, t, slot),
                c, layers, fresh,
            )
            return new_layers, pos.at[slot].set(0)

        self._reset_jit = jax.jit(_reset_fn)

        if self._paged:

            def _swap_out_fn(layers, page_ids, slot):
                self.swap_traces += 1
                c, template = _slot_surgery_trees()
                return jax.tree.map(
                    lambda cap, full, t: (
                        gather_pages_leaf(full, page_ids)
                        if cap
                        else extract_slot_leaf(full, t, slot)
                    ),
                    c, layers, template,
                )

            def _swap_in_fn(layers, pos, snap, page_ids, slot, pos_val):
                self.swap_traces += 1
                c, _ = _slot_surgery_trees()
                new_layers = jax.tree.map(
                    lambda cap, full, s: (
                        scatter_pages_leaf(full, s, page_ids)
                        if cap
                        else insert_slot_leaf(full, s, slot)
                    ),
                    c, layers, snap,
                )
                return new_layers, pos.at[slot].set(pos_val)

            self._swap_out_jit = jax.jit(_swap_out_fn)
            self._swap_in_jit = jax.jit(_swap_in_fn)

        def _sample_fn(logits, temps, key):
            lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
            greedy = jnp.argmax(lg, axis=-1)
            scaled = lg / jnp.maximum(temps, 1e-6)[:, None]
            sampled = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)

        self._sample = jax.jit(_sample_fn)

    # -- client API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            RequestState(request=request, rid=rid, t_submit=time.perf_counter())
        )
        return rid

    def reset_rng(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._preempted)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def result(self, rid: int) -> RequestState:
        rs = self._finished.get(rid)
        if rs is not None:
            return rs
        in_flight = (
            any(r.rid == rid for r in self._active.values())
            or any(r.rid == rid for r in self._queue)
            or any(r.rid == rid for r in self._preempted)
        )
        if in_flight:
            raise KeyError(f"request {rid} is not finished yet")
        if 0 <= rid < self._next_rid:
            raise KeyError(
                f"request {rid} finished but its result was evicted "
                f"(keep_finished={self.sched.keep_finished}); raise "
                "keep_finished or collect results as requests retire (run())"
            )
        raise KeyError(f"unknown request id {rid}")

    def run(self) -> list[RequestState]:
        """Drive steps until queue and slots drain; returns finished states
        for the requests that were in flight at call time, in submission
        order. Results are collected as requests retire, so they survive
        ``keep_finished`` eviction even when one drain outruns the cap."""
        in_flight = (
            {rs.rid for rs in self._queue}
            | {rs.rid for rs in self._active.values()}
            | {rs.rid for rs in self._preempted}
        )
        results: dict[int, RequestState] = {}
        while self._queue or self._active or self._preempted:
            self.step()
            for rid in list(in_flight):
                rs = self._finished.get(rid)
                if rs is not None:
                    results[rid] = rs
                    in_flight.discard(rid)
        return [results[r] for r in sorted(results)]

    # -- one scheduling iteration ------------------------------------------
    def step(self) -> bool:
        """Admit/resume from the queues, stream at most one prefill chunk
        (fixed power-of-two buckets up to the token budget), then run one
        decode step over the decoding slots. Returns True if any model
        program ran."""
        self._admit_pending()
        ran = False
        if self._chunked:
            ran = self._prefill_chunk_step()
        if not self._active_mask.any():
            return ran
        if self._paged:
            self._grow_pages()
            self._states["page_table"] = jnp.asarray(self._pt)

        self._key, sub = jax.random.split(self._key)
        logits, self._states = self._decode(
            self.params,
            self._states,
            jnp.asarray(self._tokens),
            jnp.asarray(self._active_mask),
        )
        self.last_decode_logits = logits
        cols = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(self._temps), sub))
        self.total_decode_steps += 1

        now = time.perf_counter()
        for slot, rs in list(self._active.items()):
            if rs.status is not RequestStatus.ACTIVE:
                continue  # still streaming its prompt in
            rs.decode_steps += 1
            self._pos_host[slot] += 1
            tok = int(cols[slot])
            rs.tokens.append(tok)
            rs.t_tokens.append(now)
            self._tokens[slot, 0] = tok
            self._maybe_finish(rs, now)
        return True

    # -- chunked prefill (unified token-budget step) -------------------------
    def _prefill_chunk_step(self) -> bool:
        """Stream one prompt chunk for the oldest PREFILLING slot.

        Chunk sizes come from a *fixed* power-of-two bucket set —
        ``min_chunk`` up to ``pow2_floor(chunk_budget)`` — independent of
        how many decode rows ride the same step: a load-dependent size
        would compile fresh chunk shapes exactly when the system is busy
        (the warmup, run idle, would never have seen them). The decode
        rows' tokens therefore ride on top of the chunk's; per-step work
        stays bounded by ``chunk_budget + n_slots``. Returns True if a
        chunk program ran."""
        prefilling = sorted(
            (rs for rs in self._active.values() if rs.status is RequestStatus.PREFILLING),
            key=lambda r: r.rid,
        )
        if not prefilling:
            return False
        sc = self.sched
        rs = prefilling[0]
        slot = rs.slot
        src = (
            rs.replay_tokens
            if rs.replay_tokens is not None
            else np.asarray(rs.request.prompt)
        )
        remaining = len(src) - rs.chunk_pos
        max_b = _pow2_floor(sc.chunk_budget)
        bucket = min(max(_pow2_ceil(min(remaining, max_b)), sc.min_chunk), max_b)
        n_real = min(bucket, remaining)
        start = rs.chunk_pos

        page_ids = None
        if self._paged:
            need = self.pages.pages_for_len(start + n_real)
            if not self._ensure_pages(slot, need):
                self.deferred_admissions += 1
                return False
            held = len(self.pool.allocated(slot))
            if need > held:
                self._pt[slot, held:need] = self.pool.grow_to(slot, need)
            # The chunk only attends to pages covering [0, start + n_real);
            # pass a power-of-two page-count bucket of the table row so the
            # gather/kernel cost tracks the live prefix, not the table
            # width (one compile per (chunk, page) bucket pair — early
            # chunks of a long prompt stay cheap).
            n_lp = min(_pow2_ceil(max(need, 1)), self.pages.max_pages)
            page_ids = jnp.asarray(self._pt[slot, :n_lp])

        toks = src[start : start + n_real].astype(np.int32)
        if n_real < bucket:
            toks = np.concatenate([toks, np.zeros(bucket - n_real, np.int32)])
        args = [
            self._states["layers"], self._states["pos"], jnp.asarray(toks)[None, :],
            jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32),
            jnp.asarray(n_real, jnp.int32),
        ]
        if self._paged:
            args.append(page_ids)
        logits, layers, pos = self._chunk_jit(*args)
        self._states["layers"] = layers
        self._states["pos"] = pos
        rs.chunk_pos += n_real
        self._pos_host[slot] = rs.chunk_pos
        self.total_chunk_steps += 1
        if rs.chunk_pos == len(src):
            self._finish_prefill(rs, logits)
        return True

    def _finish_prefill(self, rs: RequestState, logits: jax.Array) -> None:
        """The prompt is fully streamed: join the decode batch."""
        slot = rs.slot
        now = time.perf_counter()
        req = rs.request
        if rs.replay_tokens is not None:
            # Recompute resume: the last generated token was never fed back;
            # it is the next decode input, not a fresh sample.
            rs.replay_tokens = None
            self._tokens[slot, 0] = rs.tokens[-1]
        else:
            self._key, sub = jax.random.split(self._key)
            first = int(
                np.asarray(
                    self._sample(
                        logits[:, -1, :],
                        jnp.full((1,), req.temperature, jnp.float32),
                        sub,
                    )
                )[0]
            )
            rs.tokens = [first]
            rs.prefill_logits = np.asarray(logits[:, -1:, :])
            rs.t_first_token = now
            rs.t_tokens.append(now)
            self._tokens[slot, 0] = first
        rs.status = RequestStatus.ACTIVE
        self._temps[slot] = req.temperature
        self._active_mask[slot] = True
        self._maybe_finish(rs, now)

    # -- pages: growth, reservation-free accounting, preemption --------------
    def _ensure_pages(self, slot: int, n_total: int) -> bool:
        """Make ``slot``'s reservation cover ``n_total`` pages. Under
        worst-case reservations this always holds; reservation-free
        (preemption on), extend incrementally and reclaim LRU victims'
        pages until the pool can back it."""
        if self.sched.preemption == "off":
            return True  # admission reserved the worst case
        while not self.pool.extend_to(slot, n_total):
            if not self._preempt_lru(protect=slot):
                return False
        return True

    def _grow_pages(self) -> None:
        """Allocate the page backing the position each decoding slot writes
        this step. Worst-case reservations guarantee this; reservation-free
        admission may have to preempt first — including the growing slot
        *itself* when everyone else's pages are pinned (e.g. a PREFILLING
        streamer holds the pool and streamers are never victims): the
        grower is parked and resumes once pages free up."""
        for slot, rs in list(self._active.items()):
            if rs.status is not RequestStatus.ACTIVE:
                continue
            need = self.pages.pages_for_len(int(self._pos_host[slot]) + 1)
            held = len(self.pool.allocated(slot))
            if need <= held:
                continue
            if not self._ensure_pages(slot, need):
                if self._can_preempt(rs):
                    self._preempt_slot(slot)
                    continue
                raise RuntimeError(
                    f"slot {slot}: cannot back page growth to {need} and the "
                    "request is not preemptable (recompute cannot replay "
                    "modality extras); use preemption=\"swap\" or a larger "
                    "pool for such workloads"
                )
            self._pt[slot, held:need] = self.pool.grow_to(slot, need)

    def _can_preempt(self, rs: RequestState) -> bool:
        """Swap restores any slot verbatim; recompute replays tokens through
        chunked streaming, which cannot re-feed modality extras or enc-dec
        caches — such requests are not recompute victims."""
        if self.sched.preemption == "swap":
            return True
        return self._stream_capable and not rs.request.extras

    def _preempt_lru(self, protect: int) -> bool:
        """Reclaim the least-recently-(re)admitted decoding slot's pages.

        ``swap``: snapshot the slot's page contents + per-slot states to
        host and restore them verbatim on resume. ``recompute``: drop
        everything and re-stream prompt + generated tokens (teacher-forced)
        on resume. Either way the resumed request continues greedy
        token-identically. Returns False when no victim exists."""
        victims = [
            rs
            for s, rs in self._active.items()
            if rs.status is RequestStatus.ACTIVE and s != protect
            and self._can_preempt(rs)
        ]
        if not victims:
            return False
        self._preempt_slot(min(victims, key=lambda r: r.t_admit).slot)
        return True

    def _preempt_slot(self, slot: int) -> None:
        rs = self._active[slot]
        if self.sched.preemption == "swap":
            snap = self._swap_out_jit(
                self._states["layers"],
                jnp.asarray(self._pt[slot]),
                jnp.asarray(slot, jnp.int32),
            )
            rs.swap = (jax.tree.map(np.asarray, snap), int(self._pos_host[slot]))
        else:  # recompute
            rs.replay_tokens = np.concatenate(
                [np.asarray(rs.request.prompt, np.int32),
                 np.asarray(rs.tokens[:-1], np.int32)]
            )
            rs.chunk_pos = 0
        rs.status = RequestStatus.PREEMPTED
        rs.preemptions += 1
        self.preemptions_total += 1
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        self.pool.release(slot)
        self._pt[slot, :] = self.pages.trash
        self._pos_host[slot] = 0
        rs.slot = None
        self._preempted.append(rs)

    # -- admission -----------------------------------------------------------
    def _bucket_len(self, token_len: int) -> int:
        """Power-of-two padded token count (identity when bucketing is off)."""
        if not self._bucketed:
            return token_len
        b = max(self.sched.min_bucket, 1)
        while b < token_len:
            b *= 2
        # Dense prompts never exceed cache_len (asserted at admission), so
        # buckets are capped there to keep the padded prompt in one row.
        cap = self.sched.cache_len - (self.cfg.prefix_len or 0)
        return min(b, max(cap, token_len))

    def _streaming(self) -> bool:
        return any(
            rs.status is RequestStatus.PREFILLING for rs in self._active.values()
        )

    def _admit_pending(self) -> None:
        # Preempted requests resume first: they hold generated progress and
        # FIFO-resuming them bounds preemption churn. A *deferred* resume
        # (not enough free pages yet) blocks fresh admissions too —
        # otherwise younger requests would keep taking the pages the
        # swapped-out request is waiting for and starve it indefinitely.
        while self._free_slots and self._preempted:
            if not self._try_resume(self._preempted[0]):
                return
            self._preempted.popleft()
        while self._free_slots and self._queue:
            rs = self._queue[0]
            if self._stream_capable and not rs.request.extras:
                ok = self._admit_streaming(rs)
            else:
                ok = self._admit_prefill(rs)
            if not ok:
                break
            self._queue.popleft()

    def _stream_gate_ok(self) -> bool:
        """Reservation-free streaming admits one prompt at a time. Two
        concurrent streamers can deadlock — each holds pages, each needs
        more, and PREFILLING slots are not preemptable victims — whereas a
        lone streamer can always reclaim ACTIVE slots' pages, and the
        admission fail-fast guarantees it fits the empty pool. Worst-case
        reservations (preemption off) stream concurrently as before."""
        return self.sched.preemption == "off" or not self._streaming()

    def _check_fits(self, rs: RequestState, prompt_len: int) -> int:
        """Shared admission validation; returns the worst-case page count."""
        req = rs.request
        assert (
            prompt_len + req.max_new_tokens <= self.sched.cache_len
            or self.cfg.supports_long_context
            or self.cfg.window_size
        ), (
            f"cache_len {self.sched.cache_len} too small for "
            f"{prompt_len}+{req.max_new_tokens}"
        )
        if not self._paged:
            return 0
        n_worst = self.pages.pages_for_len(prompt_len + req.max_new_tokens)
        if n_worst > self.pages.n_pages:
            # Never admissible even into an empty pool: fail fast instead
            # of deferring forever (run() would spin).
            raise RuntimeError(
                f"request {rs.rid} needs {n_worst} pages worst-case "
                f"({prompt_len}+{req.max_new_tokens} tokens @ "
                f"{self.pages.page_size}/page) but the pool has only "
                f"{self.pages.n_pages}; raise n_pages or lower "
                "max_new_tokens"
            )
        return n_worst

    def _admit_streaming(self, rs: RequestState) -> bool:
        """Assign a slot and start streaming the prompt in chunks. Under
        worst-case reservations this is where OOM backpressure defers;
        reservation-free admission always proceeds (chunks reserve as they
        stream, preempting if needed)."""
        req = rs.request
        prompt_len = req.prompt.shape[0]
        n_worst = self._check_fits(rs, prompt_len)
        if self._paged:
            if self.sched.preemption == "off":
                if not self.pool.can_reserve(n_worst):
                    self.deferred_admissions += 1
                    return False
                n_reserve = n_worst
            else:
                if not self._stream_gate_ok():
                    self.deferred_admissions += 1
                    return False
                n_reserve = 0
        slot = heapq.heappop(self._free_slots)
        if self._paged:
            self.pool.reserve(slot, n_reserve)
            self._pt[slot, :] = self.pages.trash
        layers, pos = self._reset_jit(
            self._states["layers"], self._states["pos"], jnp.asarray(slot, jnp.int32)
        )
        self._states["layers"] = layers
        self._states["pos"] = pos
        self._pos_host[slot] = 0
        rs.slot = slot
        rs.prompt_len = prompt_len
        rs.chunk_pos = 0
        rs.status = RequestStatus.PREFILLING
        rs.t_admit = time.perf_counter()
        self._active[slot] = rs
        return True

    def _try_resume(self, rs: RequestState) -> bool:
        """Re-admit a preempted request: swap its snapshot back in, or
        restart streaming (recompute). False defers (not enough pages)."""
        if rs.swap is not None:
            snap, pos_v = rs.swap
            need = self.pages.pages_for_len(pos_v)
            if need > self.pool.available():
                self.deferred_admissions += 1
                return False
            slot = heapq.heappop(self._free_slots)
            self.pool.reserve(slot, 0)
            if not self.pool.extend_to(slot, need):  # pragma: no cover - race-free
                raise RuntimeError("pool accounting violated availability check")
            self._pt[slot, :] = self.pages.trash
            if need:
                self._pt[slot, :need] = self.pool.grow_to(slot, need)
            layers, pos = self._swap_in_jit(
                self._states["layers"], self._states["pos"],
                jax.tree.map(jnp.asarray, snap),
                jnp.asarray(self._pt[slot]), jnp.asarray(slot, jnp.int32),
                jnp.asarray(pos_v, jnp.int32),
            )
            self._states["layers"] = layers
            self._states["pos"] = pos
            self._pos_host[slot] = pos_v
            rs.swap = None
            rs.slot = slot
            rs.status = RequestStatus.ACTIVE
            rs.t_admit = time.perf_counter()
            self._tokens[slot, 0] = rs.tokens[-1]
            self._temps[slot] = rs.request.temperature
            self._active_mask[slot] = True
            self._active[slot] = rs
            return True
        # recompute: restart chunk streaming over prompt + generated tokens
        return self._admit_streaming(rs)

    def _admit_prefill(self, rs: RequestState) -> bool:
        """Whole-prompt prefill + graft at admission (the PR-1/2 path; also
        the fallback for modality-prefix / enc-dec requests when chunked
        streaming is on). Returns False to defer on pool backpressure."""
        req = rs.request
        prompt_len = req.prompt.shape[0] + (self.cfg.prefix_len or 0)
        n_reserve = self._check_fits(rs, prompt_len)
        page_ids_arr = None
        if self._paged:
            if not self.pool.can_reserve(n_reserve):
                # OOM backpressure: not enough pool headroom for this
                # request's worst case — defer admission (FIFO order is
                # preserved; live pages are never reclaimed or aliased).
                self.deferred_admissions += 1
                return False
        slot = heapq.heappop(self._free_slots)
        if self._paged:
            self.pool.reserve(slot, n_reserve)
            n_admit = self.pages.pages_for_len(prompt_len)
            self._pt[slot, :] = self.pages.trash
            self._pt[slot, :n_admit] = self.pool.grow_to(slot, n_admit)
            page_ids_arr = jnp.asarray(self._pt[slot])

        tok_len = req.prompt.shape[0]
        pad_to = self._bucket_len(tok_len)
        toks = np.asarray(req.prompt)
        if pad_to != tok_len:
            toks = np.concatenate([toks, np.zeros(pad_to - tok_len, np.int32)])
        batch = {"tokens": jnp.asarray(toks)[None, :]}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v)
        if self._bucketed:
            batch["logit_pos"] = jnp.asarray(prompt_len - 1, jnp.int32)
        logits, pstates = self._prefill(self.params, batch)

        plen_t = jnp.asarray(prompt_len, jnp.int32)
        slot_t = jnp.asarray(slot, jnp.int32)
        if self._paged:
            layers, pos = self._admit_jit(
                self._states["layers"], self._states["pos"], pstates["layers"],
                slot_t, page_ids_arr, plen_t,
            )
        else:
            layers, pos = self._admit_jit(
                self._states["layers"], self._states["pos"], pstates["layers"],
                slot_t, plen_t,
            )
        self._states["layers"] = layers
        self._states["pos"] = pos
        self._pos_host[slot] = prompt_len

        now = time.perf_counter()
        self._key, sub = jax.random.split(self._key)
        first = int(
            np.asarray(
                self._sample(
                    logits[:, -1, :],
                    jnp.full((1,), req.temperature, jnp.float32),
                    sub,
                )
            )[0]
        )
        rs.slot = slot
        rs.prompt_len = prompt_len
        rs.status = RequestStatus.ACTIVE
        rs.tokens = [first]
        rs.prefill_logits = np.asarray(logits[:, -1:, :])
        rs.t_admit = now
        rs.t_first_token = now
        rs.t_tokens.append(now)
        self._tokens[slot, 0] = first
        self._temps[slot] = req.temperature
        self._active_mask[slot] = True
        self._active[slot] = rs
        # A 1-token request (or an immediate stop) retires before ever
        # riding the decode step, freeing the slot for this admission loop.
        self._maybe_finish(rs, now)
        return True

    def _maybe_finish(self, rs: RequestState, now: float) -> None:
        req = rs.request
        reason = None
        if req.stop_token >= 0 and rs.tokens[-1] == req.stop_token:
            reason = "stop"
        elif len(rs.tokens) >= req.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        slot = rs.slot
        assert slot is not None
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        self._pos_host[slot] = 0
        if self._paged:
            # Free pages and point the table row at the trash page so the
            # retired slot's frozen-position garbage writes can never touch
            # a future tenant of these pages.
            self.pool.release(slot)
            self._pt[slot, :] = self.pages.trash
        rs.status = RequestStatus.FINISHED
        rs.finish_reason = reason
        rs.t_finish = now
        self._finished[rs.rid] = rs
        self.finished_total += 1
        self.generated_tokens_total += len(rs.tokens)
        # Bound retention for long-running serving: evict the oldest finished
        # states (dict preserves insertion order) beyond keep_finished.
        while len(self._finished) > self.sched.keep_finished:
            self._finished.pop(next(iter(self._finished)))

    def stats(self) -> dict[str, Any]:
        out = {
            # Cumulative — monotone even after keep_finished eviction.
            "finished": self.finished_total,
            "generated_tokens": self.generated_tokens_total,
            "retained": len(self._finished),
            "decode_steps": self.total_decode_steps,
            "chunk_steps": self.total_chunk_steps,
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
            "admit_traces": self.admit_traces,
            "chunk_traces": self.chunk_traces,
            "swap_traces": self.swap_traces,
            "pending": self.pending,
            "active": self.num_active,
            "deferred_admissions": self.deferred_admissions,
            "preemptions": self.preemptions_total,
        }
        if self._paged:
            out["pages"] = self.pool.stats()
        return out

    # -- capacity accounting -------------------------------------------------
    def paged_cache_bytes(self) -> dict[str, int]:
        """Actual (peak pages in use) vs contiguous-equivalent cache bytes
        for the paged KV leaves. Zeros when the model has no paged layer."""
        if not self._paged:
            return {"bytes_per_page": 0, "peak_bytes": 0, "contiguous_bytes": 0}
        # Bytes of one page summed across every paged leaf (a physical page
        # id addresses page-sized storage in every paged layer at once).
        per_page = 0
        caps = blk.stack_paged_caps(self.cfg, self.sched.cache_len)
        for cap, leafarr in zip(
            jax.tree.leaves(caps), jax.tree.leaves(self._states["layers"])
        ):
            if not cap:
                continue
            shape = leafarr.shape
            lead = len(shape) - 4  # stacked layer axis
            n_layers = shape[0] if lead else 1
            page_elems = int(np.prod(shape[lead + 1:]))  # page * kv * hd
            per_page += n_layers * page_elems * jnp.dtype(leafarr.dtype).itemsize
        peak = self.pool.peak_in_use * per_page
        contiguous = self.sched.n_slots * self.pages.max_pages * per_page
        return {
            "bytes_per_page": int(per_page),
            "peak_bytes": int(peak),
            "contiguous_bytes": int(contiguous),
        }
