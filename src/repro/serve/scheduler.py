"""Continuous-batching request scheduler over a paged KV block pool.

The scheduler owns ``n_slots`` persistent decode slots backed by one batched
decode state. Dense and windowed attention KV caches live in a shared
**page pool** — ``n_pages`` fixed-size pages multiplexed across all slots
through a per-slot page table (see serve/pages.py) — so a slot's cache
footprint is its live tokens rounded up to pages, not a worst-case
``cache_len`` row. MLA compressed caches, recurrent states, and enc-dec
caches keep their per-slot layout behind the same interface; models with
no paged layer kind run exactly the PR-1 contiguous path.

Requests flow through an admission queue; each admitted request gets a
free slot **and** a page reservation:

  1. **admit** — admission checks pool capacity for the request's
     worst-case page count (prompt + max_new_tokens, ring-folded). If the
     pool can't cover it the queue defers (OOM backpressure: the request
     waits, live pages are never touched). Otherwise the prompt's pages
     are allocated and the slot's page-table row is written.
  2. **prefill** — the prompt runs through the jitted prefill. With
     ``prefill_buckets`` (attention-only models) prompts are right-padded
     to power-of-two buckets so prefill/admit compile once per bucket,
     not once per distinct length; the true last-token logits are read at
     a traced ``logit_pos`` and padded cache garbage is handled by
     positional validity masking.
  3. **graft** — prompt-length caches are rewritten page-by-page into the
     pool (dense left-aligned, windowed ring-folded) and per-slot states
     are inserted at the slot's batch row; one compiled program per
     prefill *shape*, slot index and true prompt length traced.
  4. **decode** — the slot rides the shared ``(n_slots, 1)`` decode step;
     crossing a page boundary allocates the next page from its
     reservation (never fails) and updates the table row.
  5. **retire** — on stop-token or length the slot frees its pages back
     to the pool, its table row is pointed at the trash page, and the
     slot is backfilled from the queue at the next step.

The decode hot path is shape-stable by construction: tokens ``(n_slots,
1)``, active mask ``(n_slots,)``, positions ``(n_slots,)``, page table
``(n_slots, max_pages)`` int32 — joins, leaves, and page growth only
change array *values*, so the step never recompiles after its single
warmup trace (``decode_traces`` counts traces for tests/monitoring;
``prefill_traces``/``admit_traces`` count per-bucket compiles). Inactive
slots keep decoding garbage with a frozen position; their writes land in
the trash page (paged) or their own about-to-be-overwritten row
(contiguous), so no live state is ever visible through the masks.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import lm
from repro.serve.cache import (
    _graft_leaf,
    graft_pages_leaf,
    graft_states,
    insert_slot,
    insert_slot_leaf,
)
from repro.serve.pages import PageLayout, PagePool, cdiv, model_page_span
from repro.serve.request import Request, RequestState, RequestStatus
from repro.serve.step import init_decode_state, init_paged_decode_state
from repro.sharding.rules import ShardingCtx

_RECURRENT_KINDS = {"rglru", "mlstm", "slstm"}


@dataclass
class SchedulerConfig:
    n_slots: int = 4  # concurrent sequences in the batched decode state
    cache_len: int = 256  # per-slot logical cache slots (>= prompt + new tokens for dense)
    seed: int = 0
    keep_finished: int = 1024  # finished RequestStates retained for result()
    # Paged KV pool (dense/windowed attention caches). n_pages=None sizes the
    # pool at capacity parity with the contiguous layout (n_slots full rows);
    # shrink it to multiplex a smaller pool across mixed-size requests.
    paged: bool = True
    page_size: int = 16  # tokens per page
    n_pages: int | None = None
    # Pad prompts to power-of-two buckets so prefill/admit compile once per
    # bucket (auto-disabled for recurrent models, whose states would absorb
    # the pad tokens).
    prefill_buckets: bool = True
    min_bucket: int = 8


class Scheduler:
    def __init__(
        self, cfg: ModelConfig, params: Any, sctx: ShardingCtx, sched: SchedulerConfig
    ):
        self.cfg = cfg
        self.params = params
        self.sctx = sctx
        self.sched = sched
        n = sched.n_slots

        span = model_page_span(cfg, sched.cache_len) if sched.paged else 0
        self._paged = span > 0
        if self._paged:
            n_pages = (
                sched.n_pages
                if sched.n_pages is not None
                else n * cdiv(span, sched.page_size)
            )
            self.pages: PageLayout | None = PageLayout(
                page_size=sched.page_size, n_pages=n_pages, span=span
            )
            self.pool: PagePool | None = PagePool(self.pages)
            state = init_paged_decode_state(cfg, n, sched.cache_len, self.pages)
            self._pt = np.full((n, self.pages.max_pages), self.pages.trash, np.int32)
            state["page_table"] = jnp.asarray(self._pt)
        else:
            self.pages = None
            self.pool = None
            state = init_decode_state(cfg, n, sched.cache_len)
            state["pos"] = jnp.zeros((n,), jnp.int32)
        self._states: dict[str, Any] = state
        self._tokens = np.zeros((n, 1), np.int32)  # next input token per slot
        self._temps = np.zeros((n,), np.float32)
        self._active_mask = np.zeros((n,), bool)

        kinds = set(cfg.block_pattern) | set(cfg.first_blocks)
        self._bucketed = sched.prefill_buckets and not (kinds & _RECURRENT_KINDS)

        self._queue: deque[RequestState] = deque()
        self._active: dict[int, RequestState] = {}  # slot -> request
        self._free_slots: list[int] = list(range(n))
        heapq.heapify(self._free_slots)
        self._finished: dict[int, RequestState] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(sched.seed)

        self.decode_traces = 0  # jit trace count of the decode hot path
        self.prefill_traces = 0  # one per prompt bucket
        self.admit_traces = 0  # one per prompt bucket
        self.total_decode_steps = 0
        self.deferred_admissions = 0  # pool-backpressure events
        self.finished_total = 0  # cumulative, survives keep_finished eviction
        self.generated_tokens_total = 0
        self.last_decode_logits: jax.Array | None = None

        def _decode_fn(params, states, token, active):
            # Python body runs only when jit (re)traces: counts compilations.
            self.decode_traces += 1
            logits, new_states = lm.decode_step(params, self.cfg, states, token, self.sctx)
            # Freeze retired slots in place; their writes stay confined to the
            # trash page (paged) or one cache row admission will overwrite.
            new_pos = jnp.where(active, new_states["pos"], states["pos"])
            out = {"layers": new_states["layers"], "pos": new_pos}
            if "page_table" in new_states:
                out["page_table"] = new_states["page_table"]
            return logits, out

        self._decode = jax.jit(_decode_fn)

        def _prefill_fn(p, b):
            self.prefill_traces += 1
            return lm.prefill(p, self.cfg, b, self.sctx)

        self._prefill = jax.jit(_prefill_fn)

        if self._paged:
            caps = blk.stack_paged_caps(cfg, sched.cache_len)
            page_size = self.pages.page_size

            def _admit_fn(layers, pos, prefill_layers, slot, page_ids, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(self.cfg, 1, self.sched.cache_len)["layers"]

                def leaf(cap, full, tgt, src):
                    if cap:  # shared-pool KV leaf: scatter pages
                        return graft_pages_leaf(
                            full, src, page_ids, prompt_len, cap, page_size
                        )
                    return insert_slot_leaf(full, _graft_leaf(tgt, src, prompt_len), slot)

                new_layers = jax.tree.map(leaf, caps, layers, target, prefill_layers)
                return new_layers, pos.at[slot].set(prompt_len)

        else:

            def _admit_fn(layers, pos, prefill_layers, slot, prompt_len):
                self.admit_traces += 1
                target = init_decode_state(self.cfg, 1, self.sched.cache_len)
                slot_layers = graft_states(target["layers"], prefill_layers, prompt_len)
                new_layers = insert_slot(layers, slot_layers, slot)
                return new_layers, pos.at[slot].set(prompt_len)

        # slot and prompt_len are traced, so admission compiles once per
        # prefill *shape* — with bucketing, once per bucket.
        self._admit_jit = jax.jit(_admit_fn)

        def _sample_fn(logits, temps, key):
            lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
            greedy = jnp.argmax(lg, axis=-1)
            scaled = lg / jnp.maximum(temps, 1e-6)[:, None]
            sampled = jax.random.categorical(key, scaled, axis=-1)
            return jnp.where(temps > 0.0, sampled, greedy).astype(jnp.int32)

        self._sample = jax.jit(_sample_fn)

    # -- client API ---------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(
            RequestState(request=request, rid=rid, t_submit=time.perf_counter())
        )
        return rid

    def reset_rng(self, seed: int) -> None:
        self._key = jax.random.PRNGKey(seed)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return len(self._active)

    def result(self, rid: int) -> RequestState:
        rs = self._finished.get(rid)
        if rs is not None:
            return rs
        in_flight = any(r.rid == rid for r in self._active.values()) or any(
            r.rid == rid for r in self._queue
        )
        if in_flight:
            raise KeyError(f"request {rid} is not finished yet")
        if 0 <= rid < self._next_rid:
            raise KeyError(
                f"request {rid} finished but its result was evicted "
                f"(keep_finished={self.sched.keep_finished}); raise "
                "keep_finished or collect results as requests retire (run())"
            )
        raise KeyError(f"unknown request id {rid}")

    def run(self) -> list[RequestState]:
        """Drive steps until queue and slots drain; returns finished states
        for the requests that were in flight at call time, in submission
        order. Results are collected as requests retire, so they survive
        ``keep_finished`` eviction even when one drain outruns the cap."""
        in_flight = {rs.rid for rs in self._queue} | {
            rs.rid for rs in self._active.values()
        }
        results: dict[int, RequestState] = {}
        while self._queue or self._active:
            self.step()
            for rid in list(in_flight):
                rs = self._finished.get(rid)
                if rs is not None:
                    results[rid] = rs
                    in_flight.discard(rid)
        return [results[r] for r in sorted(results)]

    # -- one scheduling iteration ------------------------------------------
    def step(self) -> bool:
        """Admit from the queue, then run one decode step over active slots.

        Returns True if a decode step ran."""
        self._admit_pending()
        if not self._active:
            return False
        if self._paged:
            self._grow_pages()
            self._states["page_table"] = jnp.asarray(self._pt)

        self._key, sub = jax.random.split(self._key)
        logits, self._states = self._decode(
            self.params,
            self._states,
            jnp.asarray(self._tokens),
            jnp.asarray(self._active_mask),
        )
        self.last_decode_logits = logits
        cols = np.asarray(self._sample(logits[:, -1, :], jnp.asarray(self._temps), sub))
        self.total_decode_steps += 1

        now = time.perf_counter()
        for slot, rs in list(self._active.items()):
            rs.decode_steps += 1
            tok = int(cols[slot])
            rs.tokens.append(tok)
            self._tokens[slot, 0] = tok
            self._maybe_finish(rs, now)
        return True

    # -- internals ----------------------------------------------------------
    def _grow_pages(self) -> None:
        """Allocate the page backing the position each active slot writes
        this step. Reservations guarantee this never fails."""
        for slot, rs in self._active.items():
            write_pos = rs.prompt_len + rs.decode_steps
            need = self.pages.pages_for_len(write_pos + 1)
            held = len(self.pool.allocated(slot))
            if need > held:
                self._pt[slot, held:need] = self.pool.grow_to(slot, need)

    def _bucket_len(self, token_len: int) -> int:
        """Power-of-two padded token count (identity when bucketing is off)."""
        if not self._bucketed:
            return token_len
        b = max(self.sched.min_bucket, 1)
        while b < token_len:
            b *= 2
        # Dense prompts never exceed cache_len (asserted at admission), so
        # buckets are capped there to keep the padded prompt in one row.
        cap = self.sched.cache_len - (self.cfg.prefix_len or 0)
        return min(b, max(cap, token_len))

    def _admit_pending(self) -> None:
        while self._free_slots and self._queue:
            rs = self._queue[0]
            req = rs.request
            prompt_len = req.prompt.shape[0] + (self.cfg.prefix_len or 0)
            assert (
                prompt_len + req.max_new_tokens <= self.sched.cache_len
                or self.cfg.supports_long_context
                or self.cfg.window_size
            ), (
                f"cache_len {self.sched.cache_len} too small for "
                f"{prompt_len}+{req.max_new_tokens}"
            )
            page_ids_arr = None
            if self._paged:
                n_reserve = self.pages.pages_for_len(prompt_len + req.max_new_tokens)
                if n_reserve > self.pages.n_pages:
                    # Never admissible even into an empty pool: fail fast
                    # instead of deferring forever (run() would spin).
                    raise RuntimeError(
                        f"request {rs.rid} needs {n_reserve} pages worst-case "
                        f"({prompt_len}+{req.max_new_tokens} tokens @ "
                        f"{self.pages.page_size}/page) but the pool has only "
                        f"{self.pages.n_pages}; raise n_pages or lower "
                        "max_new_tokens"
                    )
                if not self.pool.can_reserve(n_reserve):
                    # OOM backpressure: not enough pool headroom for this
                    # request's worst case — defer admission (FIFO order is
                    # preserved; live pages are never reclaimed or aliased).
                    self.deferred_admissions += 1
                    break
            self._queue.popleft()
            slot = heapq.heappop(self._free_slots)
            if self._paged:
                self.pool.reserve(slot, n_reserve)
                n_admit = self.pages.pages_for_len(prompt_len)
                self._pt[slot, :] = self.pages.trash
                self._pt[slot, :n_admit] = self.pool.grow_to(slot, n_admit)
                page_ids_arr = jnp.asarray(self._pt[slot])

            tok_len = req.prompt.shape[0]
            pad_to = self._bucket_len(tok_len)
            toks = np.asarray(req.prompt)
            if pad_to != tok_len:
                toks = np.concatenate([toks, np.zeros(pad_to - tok_len, np.int32)])
            batch = {"tokens": jnp.asarray(toks)[None, :]}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)
            if self._bucketed:
                batch["logit_pos"] = jnp.asarray(prompt_len - 1, jnp.int32)
            logits, pstates = self._prefill(self.params, batch)

            plen_t = jnp.asarray(prompt_len, jnp.int32)
            slot_t = jnp.asarray(slot, jnp.int32)
            if self._paged:
                layers, pos = self._admit_jit(
                    self._states["layers"], self._states["pos"], pstates["layers"],
                    slot_t, page_ids_arr, plen_t,
                )
            else:
                layers, pos = self._admit_jit(
                    self._states["layers"], self._states["pos"], pstates["layers"],
                    slot_t, plen_t,
                )
            self._states["layers"] = layers
            self._states["pos"] = pos

            now = time.perf_counter()
            self._key, sub = jax.random.split(self._key)
            first = int(
                np.asarray(
                    self._sample(
                        logits[:, -1, :],
                        jnp.full((1,), req.temperature, jnp.float32),
                        sub,
                    )
                )[0]
            )
            rs.slot = slot
            rs.prompt_len = prompt_len
            rs.status = RequestStatus.ACTIVE
            rs.tokens = [first]
            rs.prefill_logits = np.asarray(logits[:, -1:, :])
            rs.t_admit = now
            rs.t_first_token = now
            self._tokens[slot, 0] = first
            self._temps[slot] = req.temperature
            self._active_mask[slot] = True
            self._active[slot] = rs
            # A 1-token request (or an immediate stop) retires before ever
            # riding the decode step, freeing the slot for this admission loop.
            self._maybe_finish(rs, now)

    def _maybe_finish(self, rs: RequestState, now: float) -> None:
        req = rs.request
        reason = None
        if req.stop_token >= 0 and rs.tokens[-1] == req.stop_token:
            reason = "stop"
        elif len(rs.tokens) >= req.max_new_tokens:
            reason = "length"
        if reason is None:
            return
        slot = rs.slot
        assert slot is not None
        self._active_mask[slot] = False
        self._tokens[slot, 0] = 0
        del self._active[slot]
        heapq.heappush(self._free_slots, slot)
        if self._paged:
            # Free pages and point the table row at the trash page so the
            # retired slot's frozen-position garbage writes can never touch
            # a future tenant of these pages.
            self.pool.release(slot)
            self._pt[slot, :] = self.pages.trash
        rs.status = RequestStatus.FINISHED
        rs.finish_reason = reason
        rs.t_finish = now
        self._finished[rs.rid] = rs
        self.finished_total += 1
        self.generated_tokens_total += len(rs.tokens)
        # Bound retention for long-running serving: evict the oldest finished
        # states (dict preserves insertion order) beyond keep_finished.
        while len(self._finished) > self.sched.keep_finished:
            self._finished.pop(next(iter(self._finished)))

    def stats(self) -> dict[str, Any]:
        out = {
            # Cumulative — monotone even after keep_finished eviction.
            "finished": self.finished_total,
            "generated_tokens": self.generated_tokens_total,
            "retained": len(self._finished),
            "decode_steps": self.total_decode_steps,
            "decode_traces": self.decode_traces,
            "prefill_traces": self.prefill_traces,
            "admit_traces": self.admit_traces,
            "pending": self.pending,
            "active": self.num_active,
            "deferred_admissions": self.deferred_admissions,
        }
        if self._paged:
            out["pages"] = self.pool.stats()
        return out

    # -- capacity accounting -------------------------------------------------
    def paged_cache_bytes(self) -> dict[str, int]:
        """Actual (peak pages in use) vs contiguous-equivalent cache bytes
        for the paged KV leaves. Zeros when the model has no paged layer."""
        if not self._paged:
            return {"bytes_per_page": 0, "peak_bytes": 0, "contiguous_bytes": 0}
        # Bytes of one page summed across every paged leaf (a physical page
        # id addresses page-sized storage in every paged layer at once).
        per_page = 0
        caps = blk.stack_paged_caps(self.cfg, self.sched.cache_len)
        for cap, leafarr in zip(
            jax.tree.leaves(caps), jax.tree.leaves(self._states["layers"])
        ):
            if not cap:
                continue
            shape = leafarr.shape
            lead = len(shape) - 4  # stacked layer axis
            n_layers = shape[0] if lead else 1
            page_elems = int(np.prod(shape[lead + 1:]))  # page * kv * hd
            per_page += n_layers * page_elems * jnp.dtype(leafarr.dtype).itemsize
        peak = self.pool.peak_in_use * per_page
        contiguous = self.sched.n_slots * self.pages.max_pages * per_page
        return {
            "bytes_per_page": int(per_page),
            "peak_bytes": int(peak),
            "contiguous_bytes": int(contiguous),
        }
