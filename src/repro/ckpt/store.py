"""Sharded training-state checkpoints: per-leaf binary shards + manifest,
async writer, atomic commit, keep-last-k, mesh-change-tolerant restore.

Layout:
    <root>/step_<N>/
        manifest.json          # tree structure, shapes, dtypes, leaf files
        leaf_<i>.npy           # one file per pytree leaf (np.save format)
    <root>/LATEST              # committed step number (written last)

Crash safety: leaves are written into a ``.wip-`` directory which is
``os.replace``d into place, and LATEST is only updated after the rename —
a torn write can never be mistaken for a complete checkpoint. Restore maps
leaves back through ``jax.device_put`` with the *target* shardings, so a run
restarted on a different mesh (elastic scaling) re-shards transparently.

Multi-host note: in a true multi-host deployment each host writes only the
shards it owns (addressable shards) under a per-host subdir; this container
is single-host so leaves are written whole. The manifest format already
carries per-leaf sharding metadata to support the split.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.core.exceptions import CheckpointError


def _flatten_with_paths(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    from repro.compat import tree_flatten_with_path

    flat, treedef = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class CheckpointStore:
    def __init__(self, root: str | os.PathLike[str], keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._async_thread: threading.Thread | None = None
        self._async_err: Exception | None = None

    # -- write -------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        """Snapshot ``state`` (device->host copy happens before returning so
        training can mutate buffers), then write; async unless blocking."""
        leaves, _ = _flatten_with_paths(state)
        host_leaves = [(k, np.asarray(v)) for k, v in leaves]

        if blocking:
            self._write(step, host_leaves)
            return
        self.wait()  # one in-flight write at a time

        def work() -> None:
            try:
                self._write(step, host_leaves)
            except Exception as e:  # surfaced on next wait()
                self._async_err = e

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise CheckpointError(f"async checkpoint write failed: {err}") from err

    def _write(self, step: int, host_leaves: list[tuple[str, np.ndarray]]) -> None:
        final = self.root / f"step_{step:08d}"
        tmp = Path(tempfile.mkdtemp(prefix=".wip-", dir=self.root))
        try:
            manifest = {"step": step, "written_unix": time.time(), "leaves": []}
            for i, (key, arr) in enumerate(host_leaves):
                fname = f"leaf_{i:05d}.npy"
                np.save(tmp / fname, arr, allow_pickle=False)
                manifest["leaves"].append(
                    {
                        "key": key,
                        "file": fname,
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                    }
                )
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            latest_tmp = self.root / ".LATEST.tmp"
            latest_tmp.write_text(str(step))
            os.replace(latest_tmp, self.root / "LATEST")
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for child in self.root.iterdir():
            if child.is_dir() and child.name.startswith("step_"):
                if (child / "manifest.json").exists():
                    out.append(int(child.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        latest = self.root / "LATEST"
        if latest.exists():
            try:
                s = int(latest.read_text().strip())
                if (self.root / f"step_{s:08d}" / "manifest.json").exists():
                    return s
            except ValueError:
                pass
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        like: Any,
        step: int | None = None,
        shardings: Any | None = None,
    ) -> tuple[int, Any]:
        """Restore into the structure of ``like``; optional target shardings
        (a matching pytree of NamedSharding) re-shard on load (elasticity)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise CheckpointError(f"no checkpoints under {self.root}")
        cdir = self.root / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        like_leaves, treedef = _flatten_with_paths(like)
        by_key = {rec["key"]: rec for rec in manifest["leaves"]}
        if set(by_key) != {k for k, _ in like_leaves}:
            missing = {k for k, _ in like_leaves} - set(by_key)
            extra = set(by_key) - {k for k, _ in like_leaves}
            raise CheckpointError(
                f"checkpoint step {step} tree mismatch: missing={sorted(missing)[:4]} "
                f"extra={sorted(extra)[:4]}"
            )
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(like_leaves)
        )
        out = []
        for (key, ref_leaf), shard in zip(like_leaves, shard_leaves):
            rec = by_key[key]
            arr = np.load(cdir / rec["file"], allow_pickle=False)
            if list(arr.shape) != list(np.shape(ref_leaf)):
                raise CheckpointError(
                    f"leaf {key}: checkpoint shape {arr.shape} != expected "
                    f"{np.shape(ref_leaf)}"
                )
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr))
        return step, jax.tree.unflatten(treedef, out)
