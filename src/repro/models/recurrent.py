"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and xLSTM (mLSTM, sLSTM).

Training-time formulations are TPU-adapted:
  * RG-LRU uses ``jax.lax.associative_scan`` (log-depth parallel scan) — the
    Pallas ``rglru`` kernel is the blocked TPU hot path.
  * mLSTM uses the *chunkwise* parallel form: intra-chunk attention-like
    matmuls (MXU-friendly) + an inter-chunk state recurrence, numerically
    stabilised in log space. ``mlstm_sequential`` is the slow oracle used in
    tests; the Pallas ``mlstm`` kernel mirrors the chunkwise form.
  * sLSTM is inherently sequential (recurrent weights on h_{t-1}); it runs as
    a ``lax.scan`` of elementwise ops + per-head (dh x dh) matmuls.

All recurrence states are fp32.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    F32,
    causal_conv1d_step,
    causal_conv1d_train,
    cdt,
    groupnorm_heads,
)
from repro.models.schema import ParamSpec
from repro.sharding.rules import ShardingCtx, constrain

RGLRU_C = 8.0


# ==========================================================================
# RG-LRU
# ==========================================================================
class RGLRUState(NamedTuple):
    h: jax.Array  # (B, d_rnn) fp32
    conv: jax.Array  # (B, K-1, d_rnn)


def rglru_schema(cfg: ModelConfig) -> dict[str, Any]:
    d, dr = cfg.d_model, cfg.d_rnn
    K = cfg.conv_width
    return {
        "w_in_rec": ParamSpec((d, dr), ("embed", "rnn")),
        "w_in_gate": ParamSpec((d, dr), ("embed", "rnn")),
        "conv_w": ParamSpec((K, dr), ("conv", "rnn"), scale=1.0 / math.sqrt(K)),
        "conv_b": ParamSpec((dr,), ("rnn",), init="zeros"),
        "w_rec_gate": ParamSpec((dr, dr), ("rnn", None)),
        "b_rec_gate": ParamSpec((dr,), ("rnn",), init="zeros"),
        "w_inp_gate": ParamSpec((dr, dr), ("rnn", None)),
        "b_inp_gate": ParamSpec((dr,), ("rnn",), init="zeros"),
        "log_lambda": ParamSpec((dr,), ("rnn",), init="normal", scale=0.5),
        "w_out": ParamSpec((dr, d), ("rnn", "embed")),
    }


def _rglru_coeffs(p: dict[str, Any], u: jax.Array) -> tuple[jax.Array, jax.Array]:
    """u: (..., d_rnn) conv output. Returns (a, gated_input) in fp32."""
    uf = u.astype(F32)
    r = jax.nn.sigmoid(uf @ p["w_rec_gate"].astype(F32) + p["b_rec_gate"].astype(F32))
    i = jax.nn.sigmoid(uf @ p["w_inp_gate"].astype(F32) + p["b_inp_gate"].astype(F32))
    log_a = -RGLRU_C * r * jax.nn.softplus(p["log_lambda"].astype(F32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, beta * (i * uf)


def rglru_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """Parallel scan of h_t = a_t h_{t-1} + b_t over axis=1. (B,S,D) fp32."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    mode: str,
    state: RGLRUState | None = None,
    chunk_len: jax.Array | None = None,  # valid tokens (chunk mode)
    sctx: ShardingCtx,
) -> tuple[jax.Array, RGLRUState | None]:
    dt = cdt(cfg)
    u = jnp.einsum("bsd,dr->bsr", x, p["w_in_rec"].astype(dt), preferred_element_type=F32).astype(dt)
    g = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, p["w_in_gate"].astype(dt), preferred_element_type=F32)
    ).astype(dt)
    u = constrain(u, ("batch", "seq", "rnn"), sctx)
    K = cfg.conv_width

    new_state: RGLRUState | None = None
    if mode == "decode":
        assert state is not None
        u_t, conv_state = causal_conv1d_step(u[:, 0], state.conv, p["conv_w"], p["conv_b"])
        a, gated = _rglru_coeffs(p, u_t)
        h = a * state.h + gated  # (B, dr) fp32
        new_state = RGLRUState(h=h, conv=conv_state)
        h = h[:, None, :]
    elif mode == "chunk":
        # Chunked prefill: carry the recurrence across chunks. The conv sees
        # the previous chunk's tap state as left context; the scan starts
        # from the carried h. Padded tail positions run but the new state is
        # read at chunk_len - 1, so they influence nothing downstream.
        assert state is not None and chunk_len is not None
        u_ext = jnp.concatenate([state.conv.astype(u.dtype), u], axis=1)
        u_c = causal_conv1d_train(u_ext, p["conv_w"], p["conv_b"])[:, K - 1 :]
        a, gated = _rglru_coeffs(p, u_c)
        gated = gated.at[:, 0].add(a[:, 0] * state.h)
        h = rglru_scan(a, gated)  # (B, S, dr) fp32
        h_last = jax.lax.dynamic_slice_in_dim(h, chunk_len - 1, 1, axis=1)[:, 0]
        conv_new = jax.lax.dynamic_slice_in_dim(u_ext, chunk_len, K - 1, axis=1)
        new_state = RGLRUState(h=h_last, conv=conv_new.astype(F32))
    else:
        u_c = causal_conv1d_train(u, p["conv_w"], p["conv_b"])
        a, gated = _rglru_coeffs(p, u_c)
        h = rglru_scan(a, gated)  # (B, S, dr) fp32
        if mode == "prefill":
            new_state = RGLRUState(
                h=h[:, -1], conv=u[:, -(K - 1) :].astype(F32)
            )
    y = h.astype(dt) * g
    out = jnp.einsum("bsr,rd->bsd", y, p["w_out"].astype(dt), preferred_element_type=F32)
    return constrain(out.astype(dt), ("batch", "seq", "embed_act"), sctx), new_state


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict[str, ParamSpec]:
    return {
        "h": ParamSpec((batch, cfg.d_rnn), ("batch", "rnn"), dtype=F32, init="zeros"),
        "conv": ParamSpec(
            (batch, cfg.conv_width - 1, cfg.d_rnn), ("batch", None, "rnn"), dtype=F32, init="zeros"
        ),
    }


# ==========================================================================
# mLSTM (xLSTM matrix-memory block)
# ==========================================================================
class MLSTMState(NamedTuple):
    C: jax.Array  # (B, nh, dh, dh) fp32
    n: jax.Array  # (B, nh, dh)
    m: jax.Array  # (B, nh)
    conv: jax.Array  # (B, K-1, dp) conv tap state (dp = proj dim)


def mlstm_schema(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    dp = int(cfg.mlstm_proj_factor * d)
    nh = cfg.n_heads
    dh = dp // nh
    K = cfg.conv_width
    # All axes model-replicated: the (B,S,dp)->(B,S,nh,dh) head reshape does
    # not commute with a 16-way dp sharding (measured: XLA "involuntary full
    # rematerialization" per chunk). xLSTM at 1.3B parallelises with wide DP
    # (dp_wide profile: batch over data x model); masters/moments still ZeRO-
    # shard over both axes.
    return {
        "w_up_main": ParamSpec((d, dp), ("embed", None)),
        "w_up_gate": ParamSpec((d, dp), ("embed", None)),
        "conv_w": ParamSpec((K, dp), ("conv", None), scale=1.0 / math.sqrt(K)),
        "conv_b": ParamSpec((dp,), (None,), init="zeros"),
        # Per-head (block-diagonal) q/k/v projections.
        "wq": ParamSpec((nh, dh, dh), (None, None, None)),
        "wk": ParamSpec((nh, dh, dh), (None, None, None)),
        "wv": ParamSpec((nh, dh, dh), (None, None, None)),
        "w_igate": ParamSpec((dp, nh), (None, None), init="small"),
        "b_igate": ParamSpec((nh,), (None,), init="zeros"),
        "w_fgate": ParamSpec((dp, nh), (None, None), init="small"),
        "b_fgate": ParamSpec((nh,), (None,), init="ones", scale=3.0),
        "learnable_skip": ParamSpec((dp,), (None,), init="ones"),
        "w_down": ParamSpec((dp, d), (None, "embed")),
    }


def mlstm_chunked(
    q: jax.Array,  # (B, S, nh, dh)  (already scaled by dh^-0.5)
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, S, nh) input-gate pre-activation (log-space gate)
    f_pre: jax.Array,  # (B, S, nh) forget-gate pre-activation
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    chunk: int = 64,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Chunkwise-parallel stabilised mLSTM. Returns (h (B,S,nh,dh), final state)."""
    B, S, nh, dh = q.shape
    L = max(1, min(chunk, S))
    assert S % L == 0, f"seq {S} must divide chunk {L}"
    N = S // L
    f32 = F32

    qf = q.astype(f32)
    kf = k.astype(f32)
    vf = v.astype(f32)
    log_f = -jax.nn.softplus(-f_pre.astype(f32))  # log sigmoid(f_pre)
    a = i_pre.astype(f32)  # log input gate (exponential gating)

    def reshape_chunks(x):
        return x.reshape(B, N, L, *x.shape[2:]).swapaxes(0, 1)  # (N, B, L, ...)

    qs, ks, vs = map(reshape_chunks, (qf, kf, vf))
    a_s = reshape_chunks(a)  # (N, B, L, nh)
    g_s = reshape_chunks(log_f)

    if state is None:
        C0 = jnp.zeros((B, nh, dh, dh), f32)
        n0 = jnp.zeros((B, nh, dh), f32)
        m0 = jnp.full((B, nh), -1e30, f32)
    else:
        C0, n0, m0 = state

    def body(carry, inp):
        C, n, m = carry
        qc, kc, vc, ac, gc = inp  # (B, L, ...)
        b = jnp.cumsum(gc, axis=1)  # (B, L, nh) within-chunk decay cumsum
        btot = b[:, -1]  # (B, nh)

        # Per-position output stabiliser: max(state path, best intra path).
        intra_carry = ac - b  # (B, L, nh): a_s - b_s (add b_t later)
        run_max = jax.lax.cummax(intra_carry, axis=1)
        m_state = b + m[:, None, :]  # (B, L, nh)
        m_out = jnp.maximum(m_state, b + run_max)

        # Intra-chunk weights D[t, s] = exp(a_s + b_t - b_s - m_out_t), s <= t.
        scores = jnp.einsum("blhd,bshd->bhls", qc, kc)  # (B, nh, L, L)
        ldec = b[:, :, None, :].swapaxes(1, 3)  # -> we build explicitly below
        a_sb = (ac - b)  # (B, L, nh)
        logD = (
            b.transpose(0, 2, 1)[:, :, :, None]  # b_t: (B, nh, L, 1)
            + a_sb.transpose(0, 2, 1)[:, :, None, :]  # a_s - b_s: (B, nh, 1, L)
            - m_out.transpose(0, 2, 1)[:, :, :, None]  # m_out_t
        )
        causal = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(causal[None, None], jnp.exp(logD), 0.0)
        intra_num = jnp.einsum("bhls,bshd->blhd", scores * D, vc)
        intra_den = jnp.einsum("bhls,bshd,bshd->blh", D, qc, kc) if False else jnp.einsum(
            "bhls,bhs->blh", scores * D, jnp.ones((B, nh, L), f32)
        )
        # NOTE: denominator uses sum_s D[t,s] * (q_t . k_s) == rowsum of scores*D
        # (matches n_t . q_t for the stabilised recurrence).

        # Inter-chunk (state) contribution.
        sdec = jnp.exp(m_state - m_out)  # (B, L, nh)
        inter_num = jnp.einsum("blhd,bhde->blhe", qc, C) * sdec[..., None]
        inter_den = jnp.einsum("blhd,bhd->blh", qc, n) * sdec

        num = intra_num + inter_num
        den = inter_den + intra_den
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
        h = num / denom[..., None]  # (B, L, nh, dh)

        # State update to chunk end.
        m_a = jnp.max(ac + btot[:, None, :] - b, axis=1)  # (B, nh)
        m_new = jnp.maximum(m + btot, m_a)
        state_scale = jnp.exp(m + btot - m_new)  # (B, nh)
        in_w = jnp.exp(ac + btot[:, None, :] - b - m_new[:, None, :])  # (B, L, nh)
        C_new = C * state_scale[..., None, None] + jnp.einsum(
            "bshd,bshe,bsh->bhde", kc, vc, in_w
        )
        n_new = n * state_scale[..., None] + jnp.einsum("bshd,bsh->bhd", kc, in_w)
        return (C_new, n_new, m_new), h

    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks, vs, a_s, g_s))
    h = hs.swapaxes(0, 1).reshape(B, S, nh, dh)
    return h, (Cf, nf, mf)


def mlstm_sequential(
    q: jax.Array, k: jax.Array, v: jax.Array, i_pre: jax.Array, f_pre: jax.Array,
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Step-by-step oracle (tests only)."""
    B, S, nh, dh = q.shape
    if state is None:
        C = jnp.zeros((B, nh, dh, dh), F32)
        n = jnp.zeros((B, nh, dh), F32)
        m = jnp.full((B, nh), -1e30, F32)
    else:
        C, n, m = state

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt = q[:, t].astype(F32), k[:, t].astype(F32), v[:, t].astype(F32)
        at = i_pre[:, t].astype(F32)
        lf = -jax.nn.softplus(-f_pre[:, t].astype(F32))
        m_new = jnp.maximum(lf + m, at)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(at - m_new)
        C = C * fp[..., None, None] + ip[..., None, None] * jnp.einsum("bhd,bhe->bhde", kt, vt)
        n = n * fp[..., None] + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.einsum("bhd,bhd->bh", qt, n)
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        h = num / denom[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (C, n, m), jnp.arange(S))
    return hs.swapaxes(0, 1).reshape(B, S, nh, dh), (C, n, m)


def mlstm_step(
    q, k, v, i_pre, f_pre, state
):
    """One decode step. q/k/v: (B, nh, dh); gates: (B, nh)."""
    C, n, m = state
    at = i_pre.astype(F32)
    lf = -jax.nn.softplus(-f_pre.astype(F32))
    m_new = jnp.maximum(lf + m, at)
    fp = jnp.exp(lf + m - m_new)
    ip = jnp.exp(at - m_new)
    C = C * fp[..., None, None] + ip[..., None, None] * jnp.einsum("bhd,bhe->bhde", k.astype(F32), v.astype(F32))
    n = n * fp[..., None] + ip[..., None] * k.astype(F32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(F32), C)
    den = jnp.einsum("bhd,bhd->bh", q.astype(F32), n)
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    return num / denom[..., None], (C, n, m_new)


def mlstm_block(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    mode: str,
    state: MLSTMState | None = None,
    chunk_len: jax.Array | None = None,  # valid tokens (chunk mode)
    sctx: ShardingCtx,
) -> tuple[jax.Array, MLSTMState | None]:
    dt = cdt(cfg)
    B, S, d = x.shape
    dp = int(cfg.mlstm_proj_factor * d)
    nh = cfg.n_heads
    dh = dp // nh

    u = jnp.einsum("bsd,dp->bsp", x, p["w_up_main"].astype(dt), preferred_element_type=F32).astype(dt)
    z = jnp.einsum("bsd,dp->bsp", x, p["w_up_gate"].astype(dt), preferred_element_type=F32).astype(dt)
    u = constrain(u, ("batch", "seq", None), sctx)

    K = cfg.conv_width
    new_conv = None
    u_ext = None
    if mode == "decode":
        assert state is not None
        uc_t, new_conv = causal_conv1d_step(u[:, 0], state.conv, p["conv_w"], p["conv_b"])
        uc = jax.nn.silu(uc_t.astype(F32)).astype(dt)[:, None, :]
    elif mode == "chunk":
        # Chunked prefill: the previous chunk's conv taps are the left
        # context; gate masking below makes padded tail steps exact
        # identity updates of the recurrence state.
        assert state is not None and chunk_len is not None
        u_ext = jnp.concatenate([state.conv.astype(u.dtype), u], axis=1)
        uc = jax.nn.silu(
            causal_conv1d_train(u_ext, p["conv_w"], p["conv_b"])[:, K - 1 :].astype(F32)
        ).astype(dt)
    else:
        uc = jax.nn.silu(
            causal_conv1d_train(u, p["conv_w"], p["conv_b"]).astype(F32)
        ).astype(dt)

    uc_h = uc.reshape(B, -1, nh, dh)
    q = jnp.einsum("bshd,hde->bshe", uc_h, p["wq"].astype(dt), preferred_element_type=F32).astype(dt) * (dh ** -0.5)
    k = jnp.einsum("bshd,hde->bshe", uc_h, p["wk"].astype(dt), preferred_element_type=F32).astype(dt) * (dh ** -0.5)
    u_h = u.reshape(B, -1, nh, dh)
    v = jnp.einsum("bshd,hde->bshe", u_h, p["wv"].astype(dt), preferred_element_type=F32).astype(dt)
    i_pre = jnp.einsum("bsp,ph->bsh", uc, p["w_igate"].astype(F32)) + p["b_igate"].astype(F32)
    f_pre = jnp.einsum("bsp,ph->bsh", uc, p["w_fgate"].astype(F32)) + p["b_fgate"].astype(F32)

    new_state: MLSTMState | None = None
    if mode == "decode":
        h, (C, n, m) = mlstm_step(
            q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0], (state.C, state.n, state.m)
        )
        h = h[:, None]
        new_state = MLSTMState(C=C, n=n, m=m, conv=new_conv)
    elif mode == "chunk":
        # Padded tail steps become exact no-ops: forget gate saturates to
        # log f = 0 and the input gate to weight 0 (both exact in fp32), so
        # the chunk-end state equals the state at chunk_len - 1.
        valid = (jnp.arange(S) < chunk_len)[None, :, None]
        i_pre = jnp.where(valid, i_pre, -1e30)
        f_pre = jnp.where(valid, f_pre, 1e9)
        h, (C, n, m) = mlstm_chunked(
            q, k, v, i_pre, f_pre,
            state=(state.C, state.n, state.m), chunk=64 if S >= 64 else S,
        )
        conv_new = jax.lax.dynamic_slice_in_dim(u_ext, chunk_len, K - 1, axis=1)
        new_state = MLSTMState(C=C, n=n, m=m, conv=conv_new.astype(F32))
    else:
        h, (C, n, m) = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=64 if S >= 64 else S)
        if mode == "prefill":
            new_state = MLSTMState(
                C=C, n=n, m=m, conv=u[:, -(K - 1) :].astype(F32)
            )

    h = groupnorm_heads(h).reshape(B, -1, dp).astype(dt)
    h = h + p["learnable_skip"].astype(dt) * uc
    y = h * jax.nn.silu(z.astype(F32)).astype(dt)
    out = jnp.einsum("bsp,pd->bsd", y, p["w_down"].astype(dt), preferred_element_type=F32)
    return constrain(out.astype(dt), ("batch", "seq", "embed_act"), sctx), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict[str, ParamSpec]:
    dp = int(cfg.mlstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    dh = dp // nh
    return {
        "C": ParamSpec((batch, nh, dh, dh), ("batch", "heads", "state_row", "state_col"), dtype=F32, init="zeros"),
        "n": ParamSpec((batch, nh, dh), ("batch", "heads", "state_col"), dtype=F32, init="zeros"),
        "m": ParamSpec((batch, nh), ("batch", "heads"), dtype=F32, init="zeros"),
        "conv": ParamSpec((batch, cfg.conv_width - 1, dp), ("batch", None, "mlp"), dtype=F32, init="zeros"),
    }


# ==========================================================================
# sLSTM (xLSTM scalar-memory block)
# ==========================================================================
class SLSTMState(NamedTuple):
    c: jax.Array  # (B, nh, dh) fp32
    n: jax.Array
    h: jax.Array
    m: jax.Array


def slstm_schema(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ffs = int(cfg.slstm_proj_factor * d)
    # Recurrent weights stay replicated over the model axis: sharding the
    # tiny (dh x dh) recurrences would emit collectives inside the
    # per-timestep scan (measured: ~600k all-gathers per step). sLSTM is
    # data-parallel by construction; the FFN below still tensor-parallelises.
    return {
        "w_gates": ParamSpec((d, 4, nh, dh), ("embed", None, None, None)),
        "r_gates": ParamSpec((nh, dh, 4, dh), (None, None, None, None), init="small"),
        "b_gates": ParamSpec((4, nh, dh), (None, None, None), init="zeros"),
        "ffn_gate": ParamSpec((d, ffs), ("embed", "mlp")),
        "ffn_up": ParamSpec((d, ffs), ("embed", "mlp")),
        "ffn_down": ParamSpec((ffs, d), ("mlp", "embed")),
    }


def slstm_scan(
    gates: jax.Array,  # (B, S, 4, nh, dh) pre-activations from W x + b
    r: jax.Array,  # (nh, dh, 4, dh) recurrent weights
    state: SLSTMState,
    valid: jax.Array | None = None,  # (S,) True for real tokens (chunk mode)
) -> tuple[jax.Array, SLSTMState]:
    B, S = gates.shape[:2]

    def step(carry: SLSTMState, inp):
        g_t, valid_t = inp
        rec = jnp.einsum("bhd,hdge->bghe", carry.h, r.astype(F32))  # (B,4,nh,dh)
        z_pre, i_pre, f_pre, o_pre = [
            g_t[:, j].astype(F32) + rec[:, j] for j in range(4)
        ]
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        log_f = -jax.nn.softplus(-f_pre)
        m_new = jnp.maximum(log_f + carry.m, i_pre)
        fp = jnp.exp(log_f + carry.m - m_new)
        ip = jnp.exp(i_pre - m_new)
        c = fp * carry.c + ip * z
        n = jnp.maximum(fp * carry.n + ip, 1e-6)
        h = o * (c / n)
        new = SLSTMState(c=c, n=n, h=h, m=m_new)
        # Padded chunk-tail steps must not touch the recurrence (h feeds
        # back through the recurrent weights, so gate saturation alone
        # would not keep it frozen).
        new = jax.tree.map(lambda a, b: jnp.where(valid_t, a, b), new, carry)
        return new, h

    v = jnp.ones((S,), bool) if valid is None else valid
    final, hs = jax.lax.scan(step, state, (gates.swapaxes(0, 1), v))
    return hs.swapaxes(0, 1), final  # (B, S, nh, dh)


def _shard_map_batched(fn, sctx: ShardingCtx, batch_dim_size: int):
    """Run the recurrence per batch shard via shard_map.

    The sLSTM recurrent weight is reused every timestep; under plain SPMD
    with a sharded batch, its gradient accumulation forces an all-reduce per
    timestep (measured: 5.7 TB/step at 4k seq). Inside shard_map the batch
    contraction is local, so the transpose inserts ONE psum at the boundary.
    """
    mesh = sctx.mesh
    if mesh is None:
        return fn
    axes: list = []
    size = 1
    for a in sctx.profile.candidates("batch"):
        if a in mesh.shape and batch_dim_size % (size * mesh.shape[a]) == 0:
            axes.append(a)
            size *= mesh.shape[a]
    if not axes:
        return fn
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map as _sm

    bspec = P(tuple(axes))

    def wrapped(gates, r, state):
        return _sm(
            fn,
            mesh=mesh,
            in_specs=(bspec, P(), jax.tree.map(lambda _: bspec, state)),
            out_specs=(bspec, jax.tree.map(lambda _: bspec, state)),
            check=False,
        )(gates, r, state)

    return wrapped


def slstm_block(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    *,
    mode: str,
    state: SLSTMState | None = None,
    chunk_len: jax.Array | None = None,  # valid tokens (chunk mode)
    sctx: ShardingCtx,
) -> tuple[jax.Array, SLSTMState | None]:
    dt = cdt(cfg)
    B, S, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    gates = (
        jnp.einsum("bsd,dghe->bsghe", x, p["w_gates"].astype(dt), preferred_element_type=F32)
        + p["b_gates"].astype(F32)
    )  # (B, S, 4, nh, dh)
    if state is None:
        state = SLSTMState(
            c=jnp.zeros((B, nh, dh), F32),
            n=jnp.ones((B, nh, dh), F32) * 1e-6,
            h=jnp.zeros((B, nh, dh), F32),
            m=jnp.full((B, nh, dh), -1e30, F32),
        )
    if mode == "chunk":
        # Chunk serving is per-slot (B == 1): run the recurrence directly
        # from the carried state, masking padded tail steps.
        assert chunk_len is not None
        hs, final = slstm_scan(
            gates.astype(F32), p["r_gates"].astype(F32), state,
            valid=jnp.arange(S) < chunk_len,
        )
    else:
        scan_fn = _shard_map_batched(slstm_scan, sctx, B)
        hs, final = scan_fn(gates.astype(F32), p["r_gates"].astype(F32), state)
    h = groupnorm_heads(hs).reshape(B, S, d).astype(dt)
    # Post-recurrence gated FFN (proj factor 4/3), part of the sLSTM block.
    g = jnp.einsum("bsd,df->bsf", h, p["ffn_gate"].astype(dt), preferred_element_type=F32)
    u = jnp.einsum("bsd,df->bsf", h, p["ffn_up"].astype(dt), preferred_element_type=F32)
    y = (jax.nn.gelu(g) * u).astype(dt)
    out = jnp.einsum("bsf,fd->bsd", y, p["ffn_down"].astype(dt), preferred_element_type=F32)
    new_state = final if mode in ("prefill", "decode", "chunk") else None
    return constrain(out.astype(dt), ("batch", "seq", "embed_act"), sctx), new_state


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict[str, ParamSpec]:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    mk = lambda init: ParamSpec((batch, nh, dh), ("batch", "heads", "state_col"), dtype=F32, init=init)
    return {"c": mk("zeros"), "n": mk("zeros"), "h": mk("zeros"), "m": mk("zeros")}
