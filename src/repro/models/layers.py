"""Shared layers: norms, embeddings, RoPE, dense FFNs, chunked cross-entropy.

Numerics policy (uniform across the framework):
  * params stored in ``cfg.param_dtype`` (fp32 master for training, bf16 ok
    for pure serving)
  * matmuls run in ``cfg.compute_dtype`` (bf16) with fp32 accumulation
    (``preferred_element_type``)
  * softmax / norms / recurrence states / losses in fp32
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.schema import ParamSpec
from repro.sharding.rules import ShardingCtx, constrain

F32 = jnp.float32


def cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# -- norms --------------------------------------------------------------------
def rmsnorm_schema(d: int) -> dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(p: dict[str, Any], x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + 0.0) * p["scale"].astype(F32)).astype(x.dtype)


def layernorm_schema(d: int) -> dict[str, ParamSpec]:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layernorm(p: dict[str, Any], x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


def groupnorm_heads(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head normalisation (..., nh, dh) used by xLSTM blocks."""
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# -- embedding / unembedding ----------------------------------------------------
def embedding_schema(cfg: ModelConfig) -> dict[str, ParamSpec]:
    v = cfg.padded_vocab
    sch = {"tok": ParamSpec((v, cfg.d_model), ("vocab", "embed"), init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        sch["unembed"] = ParamSpec(
            (cfg.d_model, v), ("embed", "vocab"), init="normal", scale=0.02
        )
    return sch


def embed_tokens(p: dict[str, Any], cfg: ModelConfig, tokens: jax.Array, sctx: ShardingCtx) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(cdt(cfg))
    return constrain(x, ("batch", "seq", "embed_act"), sctx)


def unembed_weight(p: dict[str, Any], cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return p["tok"].T
    return p["unembed"]


# -- activations / dense FFN -----------------------------------------------------
def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


def mlp_schema(cfg: ModelConfig, d_ff: int | None = None) -> dict[str, ParamSpec]:
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    return {
        "gate": ParamSpec((d, ff), ("embed", "mlp")),
        "up": ParamSpec((d, ff), ("embed", "mlp")),
        "down": ParamSpec((ff, d), ("mlp", "embed")),
    }


def mlp(p: dict[str, Any], cfg: ModelConfig, x: jax.Array, sctx: ShardingCtx) -> jax.Array:
    dt = cdt(cfg)
    g = jnp.einsum("...d,df->...f", x, p["gate"].astype(dt), preferred_element_type=dt)
    u = jnp.einsum("...d,df->...f", x, p["up"].astype(dt), preferred_element_type=dt)
    h = (_act(cfg.act, g.astype(F32)) * u.astype(F32)).astype(dt)
    h = constrain(h, ("batch", "seq", "mlp"), sctx)
    # Row-parallel matmul: with the mlp dim TP-sharded the output is a
    # cross-shard partial sum. Emitting it at the compute dtype makes the
    # Megatron all-reduce ride in bf16 (half the ICI bytes of an fp32
    # reduce); the MXU still accumulates fp32 internally per shard.
    y = jnp.einsum("...f,fd->...d", h, p["down"].astype(dt), preferred_element_type=dt)
    return constrain(y.astype(dt), ("batch", "seq", "embed_act"), sctx)


# -- RoPE ------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (S,) shared across the
    batch, or (B, S) per-sequence (continuous-batching decode, where every
    slot sits at its own position)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    angles = positions.astype(F32)[..., None] * freqs  # (..., S, d/2)
    # Insert singleton head axes so the seq axis of `angles` lines up with
    # the seq axis of x (which may carry trailing head dims). Shared (S,)
    # positions rely on right-aligned broadcast over the batch axes; batched
    # positions already carry them, so only the head axes are missing.
    if positions.ndim <= 1:
        n_insert = x.ndim - angles.ndim - 1
    else:
        n_insert = x.ndim - positions.ndim - 1
    for _ in range(n_insert):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


# -- chunked cross-entropy ---------------------------------------------------------
def chunked_softmax_xent(
    x: jax.Array,  # (B, S, d) final hidden states
    w_unembed: jax.Array,  # (d, V)
    labels: jax.Array,  # (B, S) int32; -1 = masked
    cfg: ModelConfig,
    sctx: ShardingCtx,
) -> tuple[jax.Array, jax.Array]:
    """Per-token xent without ever materialising (B, S, V) in fp32.

    Scans over sequence blocks of ``cfg.xent_chunk``: each block computes
    bf16 logits (B, C, V), fp32 logsumexp, gathers the label logit, and
    discards the block. Returns (sum_loss, n_valid_tokens).
    """
    B, S, d = x.shape
    V = w_unembed.shape[-1]
    chunk = max(1, min(cfg.xent_chunk, S))
    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    dt = cdt(cfg)
    w = w_unembed.astype(dt)

    def block_loss(xb: jax.Array, lb: jax.Array) -> tuple[jax.Array, jax.Array]:
        logits = jnp.einsum("bcd,dv->bcv", xb, w, preferred_element_type=F32)
        logits = constrain(logits, ("batch", "seq", "vocab"), sctx)
        lse = jax.nn.logsumexp(logits, axis=-1)  # (B, C)
        lbl = jnp.clip(lb, 0, V - 1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        valid = (lb >= 0).astype(F32)
        return jnp.sum((lse - gold) * valid), jnp.sum(valid)

    if n_chunks > 0:
        xs = x[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, d).swapaxes(0, 1)
        ls = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)

        def body(carry, inp):
            xb, lb = inp
            s, n = block_loss(xb, lb)
            return (carry[0] + s, carry[1] + n), None

        unroll = bool(int(os.environ.get("REPRO_UNROLL_SCANS", "0")))
        (total, count), _ = jax.lax.scan(
            body, (jnp.zeros((), F32), jnp.zeros((), F32)), (xs, ls),
            unroll=True if unroll else 1,
        )
    else:
        total, count = jnp.zeros((), F32), jnp.zeros((), F32)
    if rem:
        s, n = block_loss(x[:, n_chunks * chunk :], labels[:, n_chunks * chunk :])
        total, count = total + s, count + n
    return total, count


def logits_for_positions(
    x: jax.Array, w_unembed: jax.Array, cfg: ModelConfig, sctx: ShardingCtx
) -> jax.Array:
    """Full logits for small (decode) token counts: (B, Q, V)."""
    logits = jnp.einsum(
        "bqd,dv->bqv", x, w_unembed.astype(cdt(cfg)), preferred_element_type=F32
    )
    return constrain(logits, ("batch", None, "vocab"), sctx)


# -- misc -----------------------------------------------------------------------
def causal_conv1d_train(x: jax.Array, w: jax.Array, b: jax.Array | None) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C), w: (K, C)."""
    K, C = w.shape
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=F32)
    for i in range(K):  # K is tiny (4); unrolled adds, no gather needed
        out = out + pad[:, i : i + x.shape[1], :].astype(F32) * w[i].astype(F32)
    if b is not None:
        out = out + b.astype(F32)
    return out.astype(x.dtype)


def causal_conv1d_step(
    x_t: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array | None
) -> tuple[jax.Array, jax.Array]:
    """One decode step. x_t: (B, C); conv_state: (B, K-1, C) past inputs."""
    K, C = w.shape
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window.astype(F32), w.astype(F32))
    if b is not None:
        out = out + b.astype(F32)
    return out.astype(x_t.dtype), window[:, 1:, :]
