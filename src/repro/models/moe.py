"""Mixture-of-experts FFN with TPU-native sort-based dispatch.

GPU MoE stacks (Megablocks) build CSR block-sparse GEMMs; the TPU-native
adaptation here is:

  * tokens stay sharded over the batch axes (pod, data); expert weights are
    sharded over the ``model`` axis (expert parallelism);
  * inside a ``shard_map`` each model-rank sorts its *local* tokens by
    expert id (local sort — no cross-shard sort), keeps pairs routed to its
    local experts up to a static capacity, and runs a grouped matmul
    (``jax.lax.ragged_dot`` — the Pallas ``moe_gmm`` kernel is the TPU hot
    path) over its expert shard;
  * contributions are combined with a single fused ``psum`` over ``model``
    (shared-expert partial sums ride the same reduction). Replacing this
    psum with an all-to-all dispatch/combine is a recorded hillclimb lever.

Capacity semantics: per-rank capacity = ceil(cf * T_local * top_k /
ep_shards), so the expected load fits with slack cf; overflow pairs are
dropped (GShard semantics) and the aux load-balance loss keeps the router
honest. With a single shard (smoke tests) capacity covers every pair, so
nothing is dropped and the layer is exact.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import F32, _act, cdt
from repro.models.schema import ParamSpec
from repro.sharding.rules import ShardingCtx, constrain

from repro.compat import shard_map as _compat_shard_map

from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------
def moe_schema(cfg: ModelConfig) -> dict[str, Any]:
    mo = cfg.moe
    d = cfg.d_model
    ffe = mo.d_ff_expert
    sch: dict[str, Any] = {
        "router": ParamSpec((d, mo.n_experts), ("embed", "expert"), dtype=jnp.float32, scale=0.02),
        "w_gate": ParamSpec((mo.n_experts, d, ffe), ("expert", "embed", "expert_mlp")),
        "w_up": ParamSpec((mo.n_experts, d, ffe), ("expert", "embed", "expert_mlp")),
        "w_down": ParamSpec((mo.n_experts, ffe, d), ("expert", "expert_mlp", "embed")),
    }
    if mo.n_shared:
        ffs = mo.n_shared * ffe
        sch["shared"] = {
            "gate": ParamSpec((d, ffs), ("embed", "mlp")),
            "up": ParamSpec((d, ffs), ("embed", "mlp")),
            "down": ParamSpec((ffs, d), ("mlp", "embed")),
        }
    return sch


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _local_moe(
    x: jax.Array,  # (T, d) local tokens
    p: dict[str, Any],
    cfg: ModelConfig,
    e0: int,  # first expert id owned by this rank
    n_local: int,  # experts owned by this rank
    cap: int,  # static pair capacity for this rank
) -> tuple[jax.Array, jax.Array]:
    """Sort-based dispatch + grouped matmul for one expert shard.

    Returns (partial_out (T, d), aux_stats (2E,) = [count_frac | mean_prob]).
    """
    mo = cfg.moe
    dt = cdt(cfg)
    T, d = x.shape
    E, k = mo.n_experts, mo.top_k

    logits = jnp.einsum("td,de->te", x.astype(F32), p["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)

    pair_e = top_e.reshape(-1)  # (T*k,)
    pair_p = top_p.reshape(-1)
    local = (pair_e >= e0) & (pair_e < e0 + n_local)
    sort_key = jnp.where(local, pair_e, E)  # non-local pairs pushed last
    order = jnp.argsort(sort_key)  # stable
    sel = order[:cap]  # (cap,)
    sel_e = pair_e[sel]
    sel_valid = local[sel]
    sel_p = jnp.where(sel_valid, pair_p[sel], 0.0)
    tok = sel // k  # (cap,) originating token row

    # Group sizes in sorted order; invalid tail goes to a zero dummy expert.
    local_id = jnp.where(sel_valid, sel_e - e0, n_local)
    onehot = jax.nn.one_hot(local_id, n_local + 1, dtype=jnp.int32)
    group_sizes = jnp.sum(onehot, axis=0).astype(jnp.int32)  # (n_local+1,)

    xs = jnp.take(x, tok, axis=0).astype(dt)  # (cap, d)
    pad = lambda w: jnp.concatenate([w, jnp.zeros_like(w[:1])], axis=0).astype(dt)
    g = jax.lax.ragged_dot(xs, pad(p["w_gate"]), group_sizes, preferred_element_type=F32)
    u = jax.lax.ragged_dot(xs, pad(p["w_up"]), group_sizes, preferred_element_type=F32)
    h = (_act(cfg.act, g) * u).astype(dt)
    y = jax.lax.ragged_dot(h, pad(p["w_down"]), group_sizes, preferred_element_type=F32)
    y = y * sel_p[:, None]  # combine weights (zero for invalid/dropped)

    out = jnp.zeros((T, d), F32).at[tok].add(y)

    # Aux stats for the global load-balance loss: dispatch fractions must be
    # computed over *all* pairs (not just locally-kept ones) so every rank
    # reports identical stats and the psum average is exact.
    counts = jnp.sum(jax.nn.one_hot(top_e, E, dtype=F32), axis=(0, 1)) / (T * k)
    mean_prob = jnp.mean(probs, axis=0)
    return out, jnp.concatenate([counts, mean_prob])


def _shared_ffn_partial(x: jax.Array, sh: dict[str, Any], cfg: ModelConfig) -> jax.Array:
    """Shared-experts FFN with the mlp dim sharded: produces a partial sum."""
    dt = cdt(cfg)
    g = jnp.einsum("td,df->tf", x, sh["gate"].astype(dt), preferred_element_type=F32)
    u = jnp.einsum("td,df->tf", x, sh["up"].astype(dt), preferred_element_type=F32)
    h = (_act(cfg.act, g) * u).astype(dt)
    return jnp.einsum("tf,fd->td", h, sh["down"].astype(dt), preferred_element_type=F32)


def moe_ffn(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    sctx: ShardingCtx,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,d), aux_loss scalar)."""
    mo = cfg.moe
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    mesh = sctx.mesh

    ep_axes: tuple[str, ...] = ()
    tok_axes: tuple[str, ...] = ()
    if mesh is not None:
        ep_axes = tuple(
            a for a in sctx.profile.candidates("expert") if a in mesh.shape
        )
        ep_size = 1
        kept = []
        for a in ep_axes:
            if mo.n_experts % (ep_size * mesh.shape[a]) == 0:
                kept.append(a)
                ep_size *= mesh.shape[a]
        ep_axes = tuple(kept)
        tok_axes = tuple(
            a
            for a in sctx.profile.candidates("batch")
            if a in mesh.shape and a not in ep_axes
        )
        tok_size = 1
        kept = []
        for a in tok_axes:
            if (B * S) % (tok_size * mesh.shape[a]) == 0:
                kept.append(a)
                tok_size *= mesh.shape[a]
        tok_axes = tuple(kept)

    ep_shards = 1
    for a in ep_axes:
        ep_shards *= mesh.shape[a]
    tok_shards = 1
    for a in tok_axes:
        tok_shards *= mesh.shape[a]

    t_local = (B * S) // tok_shards
    n_local = mo.n_experts // ep_shards
    cap = min(
        _round_up(int(mo.capacity_factor * t_local * mo.top_k / ep_shards) or 1, 8),
        t_local * mo.top_k,
    )

    if mesh is None:
        out, stats = _local_moe(x_flat, p, cfg, 0, mo.n_experts, cap)
        if mo.n_shared:
            out = out + _shared_ffn_partial(x_flat, p["shared"], cfg)
    else:
        tok_spec = P(tok_axes if tok_axes else None)
        ep_spec = P(ep_axes if ep_axes else None)
        mlp_spec = sctx.spec((1, mo.n_shared * mo.d_ff_expert or 1), (None, "mlp")) if mo.n_shared else None

        in_specs = (
            P(tok_spec[0], None),  # x_flat: tokens sharded, d replicated
            {
                "router": P(None, None),
                "w_gate": P(ep_spec[0], None, None),
                "w_up": P(ep_spec[0], None, None),
                "w_down": P(ep_spec[0], None, None),
                **(
                    {
                        "shared": {
                            "gate": P(None, mlp_spec[1] if len(mlp_spec) > 1 else None),
                            "up": P(None, mlp_spec[1] if len(mlp_spec) > 1 else None),
                            "down": P(mlp_spec[1] if len(mlp_spec) > 1 else None, None),
                        }
                    }
                    if mo.n_shared
                    else {}
                ),
            },
        )
        out_specs = (P(tok_spec[0], None), P())

        def shard_fn(xl: jax.Array, pl: dict[str, Any]) -> tuple[jax.Array, jax.Array]:
            if ep_axes:
                rank = jax.lax.axis_index(ep_axes[0]) if len(ep_axes) == 1 else (
                    jax.lax.axis_index(ep_axes[0]) * mesh.shape[ep_axes[1]]
                    + jax.lax.axis_index(ep_axes[1])
                )
            else:
                rank = 0
            e0 = rank * n_local
            y, stats = _local_moe(xl, pl, cfg, e0, n_local, cap)
            if mo.n_shared:
                y = y + _shared_ffn_partial(xl, pl["shared"], cfg)
            if ep_axes:
                y = jax.lax.psum(y, ep_axes)
            if tok_axes:
                stats = jax.lax.pmean(stats, tok_axes)
            if ep_axes:
                # stats identical on every ep rank; pmean is a cheap no-op
                # correctness guard so out_specs P() is well-formed.
                stats = jax.lax.pmean(stats, ep_axes)
            return y, stats

        out, stats = _compat_shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check=False,
        )(x_flat, p)

    E = mo.n_experts
    frac, mean_prob = stats[:E], stats[E:]
    aux = E * jnp.sum(frac * mean_prob) * mo.aux_coef
    out = constrain(out.reshape(B, S, d).astype(cdt(cfg)), ("batch", "seq", "embed_act"), sctx)
    return out, aux


def _e0_for_local_rank(rank: int, n_local: int) -> int:
    return rank * n_local
