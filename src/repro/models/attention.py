"""Attention: GQA/MQA/MHA (+qk-norm, +qkv-bias, windows, prefix-LM, cross)
and DeepSeek-style MLA with compressed-KV decode.

Three execution modes share one weight schema:
  * ``train``   — full-sequence, query-block-chunked softmax attention (the
                  XLA-native flash equivalent; the Pallas kernel is the TPU
                  hot path, selected with backend="pallas")
  * ``prefill`` — train-mode math + returns the KV cache
  * ``decode``  — one query token against the cache (ring buffer for
                  windowed layers so 500k-context hybrids stay O(window))
"""
from __future__ import annotations

import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import F32, apply_rope, cdt, rmsnorm, rmsnorm_schema
from repro.models.schema import ParamSpec
from repro.sharding.rules import ShardingCtx, constrain

NEG_INF = -1e30


def _pallas_ok(sctx: ShardingCtx) -> bool:
    """Single-device: Pallas kernels are called directly (GSPMD cannot
    partition a pallas_call). Under a multi-device mesh the *paged*
    kernels instead run per-shard via shard_map when the operands
    partition cleanly (``_paged_kernel_specs``); other kernel call sites
    (flash prefill) still route through the partitionable XLA paths."""
    return sctx.device_count() == 1


def _paged_kernel_specs(
    sctx: ShardingCtx, *, B: int, H: int, KV: int, total_pages: int,
    batch_sharded: bool,
):
    """PartitionSpecs to run a paged Pallas kernel per-shard under the
    current mesh, or None when the operands don't partition cleanly (the
    XLA gather path handles those layouts through GSPMD).

    The head axis splits over ``model`` when it divides both q and KV
    heads. The batch axis (decode only: ``batch_sharded``) splits over
    ``data`` together with the pool's page axis — but only when the pool
    is *truly* partitioned (``sctx.pool_data_shards``), because only then
    do host page ids localize per shard (shard-local sub-pools with their
    own trash rows). A replicated pool under ``data > 1`` still works:
    each data shard keeps the full pool and its slice of slots.
    """
    from jax.sharding import PartitionSpec as P

    if sctx.mesh is None or sctx.device_count() == 1:
        return None
    msize, dsize = sctx.axis_size("model"), sctx.axis_size("data")
    if sctx.device_count() != msize * dsize:
        return None  # extra mesh axes (pod) in play — XLA path
    if msize > 1 and (H % msize or KV % msize):
        return None
    m = "model" if msize > 1 else None
    d = None
    localize = False
    if dsize > 1:
        if not batch_sharded or B % dsize:
            return None
        d = "data"
        localize = sctx.pool_data_shards == dsize and total_pages % dsize == 0
    pages = "data" if localize else None
    return {
        "mesh": sctx.mesh,
        "q_spec": P(d, None, m, None),
        "pool_spec": P(pages, None, m, None),
        "table_spec": P(d, None),
        "vec_spec": P(d),
        "localize_pages": localize,
    }


# ==========================================================================
# Schemas
# ==========================================================================
def gqa_schema(cfg: ModelConfig, cross: bool = False) -> dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    sch: dict[str, Any] = {
        "wq": ParamSpec((d, nq, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, nkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((nq, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamSpec((nq, hd), ("heads", "head_dim"), init="zeros")
        sch["bk"] = ParamSpec((nkv, hd), ("kv_heads", "head_dim"), init="zeros")
        sch["bv"] = ParamSpec((nkv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        sch["q_norm"] = {"scale": ParamSpec((hd,), (None,), init="ones")}
        sch["k_norm"] = {"scale": ParamSpec((hd,), (None,), init="ones")}
    return sch


def mla_schema(cfg: ModelConfig) -> dict[str, Any]:
    m = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    qk = m.nope_dim + m.rope_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora), ("embed", "q_lora")),
        "q_norm": {"scale": ParamSpec((m.q_lora,), (None,), init="ones")},
        "wq_b": ParamSpec((m.q_lora, nq, qk), ("q_lora", "heads", "head_dim")),
        "wkv_a": ParamSpec((d, m.kv_lora + m.rope_dim), ("embed", "kv_lora")),
        "kv_norm": {"scale": ParamSpec((m.kv_lora,), (None,), init="ones")},
        "wk_b": ParamSpec((m.kv_lora, nq, m.nope_dim), ("kv_lora", "heads", "head_dim")),
        "wv_b": ParamSpec((m.kv_lora, nq, m.v_dim), ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((nq, m.v_dim, d), ("heads", "head_dim", "embed")),
    }


def attention_schema(cfg: ModelConfig, cross: bool = False) -> dict[str, Any]:
    if cfg.attn_kind == "mla" and not cross:
        return mla_schema(cfg)
    return gqa_schema(cfg, cross=cross)


# ==========================================================================
# Caches
# ==========================================================================
class KVCache(NamedTuple):
    """Dense GQA cache. ``k``/``v``: (B, S_max, n_kv, hd). For windowed layers
    S_max == window and writes wrap (ring buffer)."""

    k: jax.Array
    v: jax.Array


class MLACache(NamedTuple):
    """Compressed cache: ``ckv``: (B, S_max, kv_lora); ``krope``: (B, S_max, rope_dim)."""

    ckv: jax.Array
    krope: jax.Array


def init_kv_cache(cfg: ModelConfig, batch: int, s_max: int, windowed: bool) -> dict[str, ParamSpec]:
    hd = cfg.resolved_head_dim
    length = min(cfg.window_size, s_max) if windowed and cfg.window_size else s_max
    seq_axis = "window" if windowed and cfg.window_size else "kv_seq"
    return {
        "k": ParamSpec((batch, length, cfg.n_kv_heads, hd), ("batch", seq_axis, "kv_heads", "head_dim"), dtype=jnp.bfloat16, init="zeros"),
        "v": ParamSpec((batch, length, cfg.n_kv_heads, hd), ("batch", seq_axis, "kv_heads", "head_dim"), dtype=jnp.bfloat16, init="zeros"),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, s_max: int) -> dict[str, ParamSpec]:
    m = cfg.mla
    return {
        "ckv": ParamSpec((batch, s_max, m.kv_lora), ("batch", "kv_seq", "kv_lora"), dtype=jnp.bfloat16, init="zeros"),
        "krope": ParamSpec((batch, s_max, m.rope_dim), ("batch", "kv_seq", None), dtype=jnp.bfloat16, init="zeros"),
    }


# ==========================================================================
# Masking
# ==========================================================================
def _mask(
    q_pos: jax.Array,  # (Q,) int32 absolute positions
    k_pos: jax.Array,  # (K,)
    kind: str,  # causal | bidir | prefix | window
    window: int = 0,
    prefix_len: int = 0,
    k_valid: jax.Array | None = None,  # (K,) bool extra validity (ring buffers)
) -> jax.Array:
    q = q_pos[:, None]
    k = k_pos[None, :]
    if kind == "bidir":
        m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    elif kind == "causal":
        m = k <= q
    elif kind == "prefix":
        m = (k <= q) | (k < prefix_len)
    elif kind == "window":
        m = (k <= q) & (k > q - window)
    else:
        raise ValueError(f"unknown mask kind {kind}")
    if k_valid is not None:
        m = m & k_valid[None, :]
    return m


# ==========================================================================
# Core softmax attention (query-block chunked — XLA flash equivalent)
# ==========================================================================
def _sdpa_chunked(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,  # (B, T, KV, Dv)
    q_pos: jax.Array,  # (S,)
    k_pos: jax.Array,  # (T,)
    mask_kind: str,
    cfg: ModelConfig,
    sctx: ShardingCtx,
    window: int = 0,
    prefix_len: int = 0,
    scale: float | None = None,
) -> jax.Array:
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV  # queries per kv head
    sc = scale if scale is not None else D ** -0.5
    # KV heads are broadcast to the full H layout so the contraction keeps a
    # single head axis. With heads TP-sharded, a (KV, G) split would force
    # XLA to reshard inside the chunk loop (measured: per-chunk all-reduces);
    # the broadcast fuses into the dot and keeps TP to one all-reduce at the
    # o-projection.
    if G > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, KV, G, D)).reshape(B, T, H, D)
        v = jnp.broadcast_to(v[:, :, :, None, :], (B, T, KV, G, Dv)).reshape(B, T, H, Dv)
    k = constrain(k, ("batch", None, "heads", None), sctx)
    v = constrain(v, ("batch", None, "heads", None), sctx)
    # Query-chunk size adapts to a fp32-score budget so long-context prefill
    # can never materialise a multi-GB score block on one chip.
    b_loc = sctx.local_size(B, "batch")
    h_loc = sctx.local_size(H, "heads")
    budget = 256 * 2**20
    fit = budget // max(b_loc * h_loc * T * 4, 1)
    chunk = max(1, min(cfg.attn_q_chunk, S, max(64, int(fit))))

    def block(qb: jax.Array, qpb: jax.Array) -> jax.Array:
        # qb: (B, C, H, D)
        s = jnp.einsum("bchd,bthd->bhct", qb, k, preferred_element_type=F32) * sc
        m = _mask(qpb, k_pos, mask_kind, window=window, prefix_len=prefix_len)
        s = jnp.where(m[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhct,bthe->bche", p.astype(cdt(cfg)), v, preferred_element_type=F32)
        return o.astype(cdt(cfg))  # (B, C, H, Dv)

    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    outs = []
    if n_chunks > 0:
        qs = q[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, H, D)
        qs = jnp.moveaxis(qs, 1, 0)  # (n, B, C, H, D)
        qp = q_pos[: n_chunks * chunk].reshape(n_chunks, chunk)
        if bool(int(os.environ.get("REPRO_UNROLL_SCANS", "0"))):
            o = jnp.stack([block(qs[i], qp[i]) for i in range(n_chunks)])
        else:
            o = jax.lax.map(lambda args: block(*args), (qs, qp))
        outs.append(jnp.moveaxis(o, 0, 1).reshape(B, n_chunks * chunk, H, Dv))
    if rem:
        outs.append(block(q[:, n_chunks * chunk :], q_pos[n_chunks * chunk :]))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    return out


def _sdpa_span(
    q: jax.Array,  # (B, C, H, D) query span (C == 1 for decode)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,  # (B, T, KV, Dv)
    k_pos: jax.Array,  # (B, T) absolute positions held in each row's cache slots
    q_pos: jax.Array,  # (B, C) absolute positions of the query tokens
    cfg: ModelConfig,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Masked attention of a query span against position-tagged cache slots.

    Validity is purely positional — ``k_pos`` entries of -1 (never-written
    ring slots, padded chunk tails) and entries beyond each query's causal
    horizon are masked, so the same routine serves single-token decode and
    multi-token chunked prefill over dense, windowed, and paged layouts.
    """
    B, C, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    Dv = v.shape[-1]
    sc = scale if scale is not None else D ** -0.5
    if G > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, KV, G, D)).reshape(B, T, H, D)
        v = jnp.broadcast_to(v[:, :, :, None, :], (B, T, KV, G, Dv)).reshape(B, T, H, Dv)
    s = jnp.einsum("bchd,bthd->bhct", q, k, preferred_element_type=F32) * sc
    kp = k_pos[:, None, :]  # (B, 1, T)
    qp = q_pos[:, :, None]  # (B, C, 1)
    valid = (kp <= qp) & (kp >= 0)
    if window:
        valid = valid & (kp > qp - window)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhct,bthe->bche", p.astype(cdt(cfg)), v, preferred_element_type=F32)
    return o.astype(cdt(cfg))  # (B, C, H, Dv)


def _sdpa_decode(
    q: jax.Array,  # (B, 1, H, D)
    k: jax.Array,  # (B, T, KV, D)
    v: jax.Array,  # (B, T, KV, Dv)
    k_pos: jax.Array,  # (B, T) absolute positions held in each row's cache slots
    cur_pos: jax.Array,  # (B,): position of each row's query token
    cfg: ModelConfig,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    return _sdpa_span(q, k, v, k_pos, cur_pos[:, None], cfg, window=window, scale=scale)


# ==========================================================================
# Chunked-prefill cache streaming (one slot, C tokens per program)
# ==========================================================================
def _chunk_attend(
    q: jax.Array,  # (1, C, H, D)
    k: jax.Array,  # (1, C, KV, D) chunk keys (rope applied)
    v: jax.Array,  # (1, C, KV, Dv)
    cache: KVCache,
    cfg: ModelConfig,
    sctx: ShardingCtx,
    *,
    qpos: jax.Array,  # (C,) absolute positions of the chunk tokens
    valid_tok: jax.Array,  # (C,) True for real (non-padded) tokens
    start: jax.Array,  # scalar: tokens already cached before this chunk
    chunk_len: jax.Array,  # scalar: number of real tokens in the chunk
    window: int,
    page_table: jax.Array | None,  # (1, max_pages) when the leaf is paged
) -> tuple[jax.Array, KVCache]:
    dt = cdt(cfg)
    B, C = q.shape[0], q.shape[1]
    q_pos_b = jnp.broadcast_to(qpos[None, :], (B, C))

    if page_table is not None:
        page = cache.k.shape[1]
        max_pages = page_table.shape[1]
        trash = cache.k.shape[0] - 1
        if window:
            n_lp = min(-(-window // page), max_pages)
            # Read the pre-write ring plus the chunk keys side by side.
            sel = page_table[:, :n_lp]
            T = n_lp * page
            kold = cache.k[sel].reshape(B, T, *cache.k.shape[2:]).astype(dt)
            vold = cache.v[sel].reshape(B, T, *cache.v.shape[2:]).astype(dt)
            k_pos_old = _ring_positions(T, window, start - 1)
            k_pos_c = jnp.where(valid_tok, qpos, -1)
            kk = jnp.concatenate([kold, k.astype(dt)], axis=1)
            vv = jnp.concatenate([vold, v.astype(dt)], axis=1)
            k_pos = jnp.concatenate([k_pos_old, k_pos_c])[None, :]
            out = _sdpa_span(q, kk, vv, k_pos, q_pos_b, cfg, window=window)
            # Ring write: only the last min(window, chunk_len) real tokens
            # survive; everything else (pads, ring-evicted early tokens)
            # goes to the trash page so no live page is ever aliased.
            keep = valid_tok & (qpos >= start + chunk_len - window)
            lslot = qpos % window
            pid = jnp.where(keep, page_table[0, lslot // page], trash)
            off = lslot % page
            ck = cache.k.at[pid, off].set(k[0].astype(cache.k.dtype))
            cv = cache.v.at[pid, off].set(v[0].astype(cache.v.dtype))
        else:
            # Dense: scatter the chunk into its pages first (pads -> trash),
            # then attend over the whole table — stale or trash-backed slots
            # fall out of the positional mask automatically.
            #
            # Shared-page invariant: with prefix sharing a table entry may
            # map a page other slots also read. This write is safe because
            # the scheduler (a) only streams chunks at or past the slot's
            # first unadopted position and (b) runs PagePool.prepare_write
            # over [start, start + chunk_len) before launching the chunk,
            # forking any still-shared page — so every page written here is
            # exclusively owned (refcount 1) by the time the program runs.
            pid = jnp.where(valid_tok, page_table[0, qpos // page], trash)
            off = qpos % page
            ck = cache.k.at[pid, off].set(k[0].astype(cache.k.dtype))
            cv = cache.v.at[pid, off].set(v[0].astype(cache.v.dtype))
            specs = None
            if cfg.attn_backend == "pallas" and not _pallas_ok(sctx):
                # Chunks are single-slot (B == 1): only the head axis can
                # partition, so a data-partitioned pool falls back to XLA.
                specs = _paged_kernel_specs(
                    sctx, B=B, H=q.shape[2], KV=ck.shape[2],
                    total_pages=ck.shape[0], batch_sharded=False,
                )
            if cfg.attn_backend == "pallas" and _pallas_ok(sctx):
                from repro.kernels import ops as _kops

                out = _kops.paged_chunk_attention_op(
                    q, ck, cv, page_table, jnp.broadcast_to(start, (B,)),
                    n_lp=max_pages,
                ).astype(dt)
            elif specs is not None:
                from repro.kernels import ops as _kops

                specs.pop("localize_pages")
                out = _kops.paged_chunk_attention_sharded(
                    q, ck, cv, page_table, jnp.broadcast_to(start, (B,)),
                    n_lp=max_pages, **specs,
                ).astype(dt)
            else:
                sel = page_table  # (B, max_pages)
                T = max_pages * page
                kg = ck[sel].reshape(B, T, *ck.shape[2:]).astype(dt)
                vg = cv[sel].reshape(B, T, *cv.shape[2:]).astype(dt)
                k_pos = jnp.broadcast_to(
                    jnp.arange(T, dtype=jnp.int32)[None, :], (B, T)
                )
                out = _sdpa_span(q, kg, vg, k_pos, q_pos_b, cfg)
        ck = constrain(ck, ("pages", None, "kv_heads", "head_dim"), sctx)
        cv = constrain(cv, ("pages", None, "kv_heads", "head_dim"), sctx)
        return out, KVCache(ck, cv)

    # Contiguous per-slot row.
    T = cache.k.shape[1]
    if window:
        k_pos_old = _ring_positions(T, T, start - 1)[None, :]
        k_pos_c = jnp.where(valid_tok, qpos, -1)[None, :]
        kk = jnp.concatenate([cache.k.astype(dt), k.astype(dt)], axis=1)
        vv = jnp.concatenate([cache.v.astype(dt), v.astype(dt)], axis=1)
        k_pos = jnp.concatenate([k_pos_old, k_pos_c], axis=1)
        out = _sdpa_span(q, kk, vv, k_pos, q_pos_b, cfg, window=window)
        keep = valid_tok & (qpos >= start + chunk_len - T)
        wslot = jnp.where(keep, qpos % T, T)  # T is out of bounds -> dropped
        ck = cache.k.at[0, wslot].set(k[0].astype(cache.k.dtype), mode="drop")
        cv = cache.v.at[0, wslot].set(v[0].astype(cache.v.dtype), mode="drop")
        seq_axis = "window"
    else:
        wslot = jnp.where(valid_tok, qpos, T)  # out of bounds -> dropped
        ck = cache.k.at[0, wslot].set(k[0].astype(cache.k.dtype), mode="drop")
        cv = cache.v.at[0, wslot].set(v[0].astype(cache.v.dtype), mode="drop")
        k_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
        out = _sdpa_span(q, ck.astype(dt), cv.astype(dt), k_pos, q_pos_b, cfg)
        seq_axis = "kv_seq"
    ck = constrain(ck, ("batch", seq_axis, "kv_heads", "head_dim"), sctx)
    cv = constrain(cv, ("batch", seq_axis, "kv_heads", "head_dim"), sctx)
    return out, KVCache(ck, cv)


def _ring_positions(T: int, window: int, cur: jax.Array) -> jax.Array:
    """Absolute position held by each of T ring slots after ``cur + 1``
    tokens: slot i holds the latest p <= cur with p % window == i; negative
    (never written) and out-of-ring slots report -1."""
    idx = jnp.arange(T, dtype=jnp.int32)
    pos = cur - ((cur - idx) % window)
    return jnp.where((idx < window) & (pos >= 0), pos, -1)


# ==========================================================================
# GQA attention block
# ==========================================================================
def _project_qkv(p, cfg, x, xkv=None):
    dt = cdt(cfg)
    xkv = x if xkv is None else xkv
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt), preferred_element_type=dt)
    k = jnp.einsum("bsd,dhe->bshe", xkv, p["wk"].astype(dt), preferred_element_type=dt)
    v = jnp.einsum("bsd,dhe->bshe", xkv, p["wv"].astype(dt), preferred_element_type=dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def gqa_attention(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
    *,
    mode: str,  # train | prefill | chunk | decode
    positions: jax.Array,  # (S,) absolute positions of x's tokens
    mask_kind: str = "causal",
    window: int = 0,
    prefix_len: int = 0,
    cache: KVCache | None = None,
    cur_pos: jax.Array | None = None,  # scalar, decode/chunk only
    use_rope: bool = True,
    page_table: jax.Array | None = None,  # (B, max_pages) int32, paged decode only
    chunk_len: jax.Array | None = None,  # valid tokens in a chunk (chunk mode)
    sctx: ShardingCtx,
) -> tuple[jax.Array, KVCache | None]:
    dt = cdt(cfg)
    q, k, v = _project_qkv(p, cfg, x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None), sctx)

    new_cache: KVCache | None = None
    use_pallas = (
        cfg.attn_backend == "pallas"
        and _pallas_ok(sctx)
        and mode != "decode"
        and mask_kind in ("causal", "bidir")
        and not (cfg.prefix_lm and cfg.prefix_len)
        and x.shape[1] % min(128, x.shape[1]) == 0
    )
    if mode == "chunk":
        # Chunked prefill for ONE slot (B == 1): x holds C tokens at absolute
        # positions cur_pos .. cur_pos + C - 1, of which the first chunk_len
        # are real (the tail is bucket padding). The chunk's K/V stream into
        # the slot's cache — shared page pool (paged) or contiguous row —
        # and the queries attend to the already-cached prefix plus the
        # chunk itself, with purely positional validity. Windowed layers
        # read the pre-write ring and the chunk keys side by side so that
        # in-window positions evicted by later chunk colleagues stay
        # visible to earlier queries.
        assert cache is not None and cur_pos is not None and chunk_len is not None
        B, C = q.shape[0], q.shape[1]
        start = jnp.asarray(cur_pos, jnp.int32)  # tokens already cached
        idx_c = jnp.arange(C, dtype=jnp.int32)
        qpos = start + idx_c  # (C,)
        valid_tok = idx_c < chunk_len  # (C,)
        out, new_cache = _chunk_attend(
            q, k, v, cache, cfg, sctx,
            qpos=qpos, valid_tok=valid_tok, start=start, chunk_len=chunk_len,
            window=window, page_table=page_table,
        )
    elif mode == "decode" and page_table is not None:
        assert cache is not None and cur_pos is not None
        # Paged decode: the cache is a shared page pool (P+1, page, kv, hd)
        # and this slot's logical token s lives in physical page
        # page_table[b, s // page] at offset s % page. Retired slots' table
        # rows all point at the trash page (index P), so their frozen-pos
        # garbage writes can never corrupt a live tenant's pages. With
        # prefix sharing, pages can additionally be mapped by several
        # live slots (refcounted); this one-token write is still safe:
        # decode positions sit past the prompt, adopted/indexed pages
        # cover only full *prompt* pages, and the scheduler runs
        # PagePool.prepare_write (copy-on-write fork) on the write
        # position before every decode step — a written page is always
        # refcount-1 private by the time this program runs.
        B = q.shape[0]
        page = cache.k.shape[1]
        max_pages = page_table.shape[1]
        pos_v = jnp.broadcast_to(jnp.atleast_1d(cur_pos), (B,)).astype(jnp.int32)
        wslot = pos_v % window if window else pos_v  # logical write slot
        rows = jnp.arange(B)
        pid = page_table[rows, wslot // page]  # (B,) physical page per slot
        off = wslot % page
        ck = cache.k.at[pid, off].set(k[:, 0].astype(cache.k.dtype))
        cv = cache.v.at[pid, off].set(v[:, 0].astype(cache.v.dtype))
        ck = constrain(ck, ("pages", None, "kv_heads", "head_dim"), sctx)
        cv = constrain(cv, ("pages", None, "kv_heads", "head_dim"), sctx)
        new_cache = KVCache(ck, cv)
        # Windowed layers ring-fold into the leading ceil(window/page)
        # table entries — a bounded page working set regardless of how
        # wide the table is for dense layers.
        n_lp = min(-(-window // page), max_pages) if window else max_pages
        specs = None
        if cfg.attn_backend == "pallas" and not _pallas_ok(sctx):
            specs = _paged_kernel_specs(
                sctx, B=B, H=q.shape[2], KV=ck.shape[2],
                total_pages=ck.shape[0], batch_sharded=True,
            )
        if cfg.attn_backend == "pallas" and _pallas_ok(sctx):
            from repro.kernels import ops as _kops

            out = _kops.paged_decode_attention_op(
                q, ck, cv, page_table, pos_v, n_lp=n_lp, window=window
            ).astype(dt)
        elif specs is not None:
            from repro.kernels import ops as _kops

            out = _kops.paged_decode_attention_sharded(
                q, ck, cv, page_table, pos_v, n_lp=n_lp, window=window,
                **specs,
            ).astype(dt)
        else:
            sel = page_table[:, :n_lp]  # (B, n_lp)
            T = n_lp * page
            kg = ck[sel].reshape(B, T, *ck.shape[2:]).astype(dt)
            vg = cv[sel].reshape(B, T, *cv.shape[2:]).astype(dt)
            idx = jnp.arange(T, dtype=jnp.int32)
            if window:
                k_pos = pos_v[:, None] - ((pos_v[:, None] - idx[None, :]) % window)
                k_pos = jnp.where(idx[None, :] < window, k_pos, -1)
            else:
                k_pos = jnp.broadcast_to(idx[None, :], (B, T))
            out = _sdpa_decode(q, kg, vg, k_pos, pos_v, cfg, window=window)
    elif mode == "decode":
        assert cache is not None and cur_pos is not None
        B, T = cache.k.shape[0], cache.k.shape[1]
        # cur_pos is a scalar (classic static batch: every row at the same
        # position) or (B,) (continuous batching: each slot at its own
        # position). Both run the same per-row scatter program.
        pos_v = jnp.broadcast_to(jnp.atleast_1d(cur_pos), (B,)).astype(jnp.int32)
        slot = pos_v % T if window else pos_v
        rows = jnp.arange(B)
        ck = cache.k.at[rows, slot].set(k[:, 0].astype(cache.k.dtype))
        cv = cache.v.at[rows, slot].set(v[:, 0].astype(cache.v.dtype))
        ck = constrain(ck, ("batch", "window" if window else "kv_seq", "kv_heads", "head_dim"), sctx)
        cv = constrain(cv, ("batch", "window" if window else "kv_seq", "kv_heads", "head_dim"), sctx)
        new_cache = KVCache(ck, cv)
        # Positions held by each row's cache slots, derived analytically:
        #   full cache: slot i holds position i;
        #   ring buffer: slot i holds the latest p <= cur_pos with p % T == i
        #   (negative -> never written; masked in _sdpa_decode).
        idx = jnp.arange(T, dtype=jnp.int32)
        if window:
            k_pos = pos_v[:, None] - ((pos_v[:, None] - idx[None, :]) % T)
        else:
            k_pos = jnp.broadcast_to(idx[None, :], (B, T))
        out = _sdpa_decode(q, ck.astype(dt), cv.astype(dt), k_pos, pos_v, cfg, window=window)
    else:
        if mode == "prefill":
            new_cache = KVCache(k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))
        kind = "window" if window else mask_kind
        if use_pallas:
            # TPU hot path: the Pallas flash kernel (fwd + bwd custom_vjp).
            from repro.kernels import ops as _kops

            blk = min(128, q.shape[1])
            out = _kops.flash_attention(
                q, k, v, causal=(kind != "bidir"), window=window,
                blk_q=blk, blk_k=blk,
            )
        else:
            out = _sdpa_chunked(
                q, k, v, positions, positions, kind, cfg, sctx,
                window=window, prefix_len=prefix_len,
            )
    # Row-parallel o-projection: bf16 output => bf16 TP all-reduce.
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dt), preferred_element_type=dt)
    return constrain(y.astype(dt), ("batch", "seq", "embed_act"), sctx), new_cache


def cross_attention(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d) decoder states
    enc_kv: KVCache,  # precomputed from encoder output
    sctx: ShardingCtx,
) -> jax.Array:
    """Decoder->encoder attention (bidirectional over encoder frames)."""
    dt = cdt(cfg)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dt), preferred_element_type=F32).astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    B, S, H, D = q.shape
    k, v = enc_kv.k.astype(dt), enc_kv.v.astype(dt)
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, S, KV, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qh, k, preferred_element_type=F32) * (D ** -0.5)
    pmat = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btke->bskge", pmat.astype(dt), v, preferred_element_type=F32)
    o = o.reshape(B, S, H, D).astype(dt)
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(dt), preferred_element_type=F32)
    return constrain(y.astype(dt), ("batch", "seq", "embed_act"), sctx)


def encoder_kv(p: dict[str, Any], cfg: ModelConfig, enc_out: jax.Array) -> KVCache:
    dt = cdt(cfg)
    k = jnp.einsum("btd,dhe->bthe", enc_out, p["wk"].astype(dt), preferred_element_type=F32).astype(jnp.bfloat16)
    v = jnp.einsum("btd,dhe->bthe", enc_out, p["wv"].astype(dt), preferred_element_type=F32).astype(jnp.bfloat16)
    if cfg.qkv_bias:
        k = (k.astype(dt) + p["bk"].astype(dt)).astype(jnp.bfloat16)
        v = (v.astype(dt) + p["bv"].astype(dt)).astype(jnp.bfloat16)
    return KVCache(k, v)


# ==========================================================================
# MLA (DeepSeek-V2)
# ==========================================================================
def mla_attention(
    p: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    *,
    mode: str,
    positions: jax.Array,
    cache: MLACache | None = None,
    cur_pos: jax.Array | None = None,
    chunk_len: jax.Array | None = None,  # valid tokens in a chunk (chunk mode)
    sctx: ShardingCtx,
) -> tuple[jax.Array, MLACache | None]:
    m = cfg.mla
    dt = cdt(cfg)
    B, S, _ = x.shape
    H = cfg.n_heads
    scale = (m.nope_dim + m.rope_dim) ** -0.5

    # Query path: low-rank down -> norm -> up, split nope/rope.
    q_c = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt), preferred_element_type=F32).astype(dt)
    q_c = rmsnorm(p["q_norm"], q_c, cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", q_c, p["wq_b"].astype(dt), preferred_element_type=F32).astype(dt)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # KV path: compressed latent + shared rope key.
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt), preferred_element_type=F32).astype(dt)
    ckv, k_rope = kv[..., : m.kv_lora], kv[..., m.kv_lora :]
    ckv = rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)  # (B, S, rope)

    new_cache: MLACache | None = None
    if mode in ("decode", "chunk"):
        assert cache is not None and cur_pos is not None
        T = cache.ckv.shape[1]
        if mode == "chunk":
            # One slot's prompt chunk (B == 1): scatter the S compressed
            # latents at positions cur_pos .. cur_pos + chunk_len - 1 (the
            # padded tail is dropped), then run the absorbed path with
            # per-query positional validity over the whole row.
            assert chunk_len is not None
            start = jnp.asarray(cur_pos, jnp.int32)
            qpos = start + jnp.arange(S, dtype=jnp.int32)
            wslot = jnp.where(jnp.arange(S) < chunk_len, qpos, T)
            ckv_all = cache.ckv.at[0, wslot].set(
                ckv[0].astype(cache.ckv.dtype), mode="drop"
            )
            krope_all = cache.krope.at[0, wslot].set(
                k_rope[0].astype(cache.krope.dtype), mode="drop"
            )
            q_pos = jnp.broadcast_to(qpos[None, :], (B, S))
        else:
            pos_v = jnp.broadcast_to(jnp.atleast_1d(cur_pos), (B,)).astype(jnp.int32)
            rows = jnp.arange(B)
            ckv_all = cache.ckv.at[rows, pos_v].set(ckv[:, 0].astype(cache.ckv.dtype))
            krope_all = cache.krope.at[rows, pos_v].set(k_rope[:, 0].astype(cache.krope.dtype))
            q_pos = pos_v[:, None]
        ckv_all = constrain(ckv_all, ("batch", "kv_seq", "kv_lora"), sctx)
        new_cache = MLACache(ckv_all, krope_all)
        # Absorbed decode: score against the compressed cache directly.
        q_abs = jnp.einsum("bshe,rhe->bshr", q_nope, p["wk_b"].astype(dt), preferred_element_type=F32).astype(dt)
        s = jnp.einsum("bshr,btr->bhst", q_abs, ckv_all.astype(dt), preferred_element_type=F32)
        s = s + jnp.einsum("bshe,bte->bhst", q_rope, krope_all.astype(dt), preferred_element_type=F32)
        valid = jnp.arange(T)[None, None, :] <= q_pos[:, :, None]  # (B, S, T)
        s = jnp.where(valid[:, None], s * scale, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        ctx_c = jnp.einsum("bhst,btr->bshr", pr.astype(dt), ckv_all.astype(dt), preferred_element_type=F32).astype(dt)
        o = jnp.einsum("bshr,rhe->bshe", ctx_c, p["wv_b"].astype(dt), preferred_element_type=F32).astype(dt)
    else:
        k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["wk_b"].astype(dt), preferred_element_type=F32).astype(dt)
        v = jnp.einsum("bsr,rhe->bshe", ckv, p["wv_b"].astype(dt), preferred_element_type=F32).astype(dt)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.rope_dim))], axis=-1
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa_chunked(
            q_full, k_full, v, positions, positions, "causal", cfg, sctx, scale=scale
        )
        o = out
        if mode == "prefill":
            new_cache = MLACache(ckv.astype(jnp.bfloat16), k_rope.astype(jnp.bfloat16))
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(dt), preferred_element_type=F32)
    return constrain(y.astype(dt), ("batch", "seq", "embed_act"), sctx), new_cache
