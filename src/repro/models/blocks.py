"""Block assembly: pre-norm residual blocks of every kind, plus the scanned
pattern-group machinery that turns 26..88-layer stacks into a single
``lax.scan`` over stacked weights (fast compiles, one remat lever).

A config's layer stack = ``first_blocks`` (unscanned, e.g. DeepSeek-V2's
dense layer 0) followed by ``n_pattern_groups`` repetitions of
``block_pattern`` (scanned). Each pattern element owns its params stacked on
a leading "layer" axis.
"""
from __future__ import annotations

import functools
import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec_mod
from repro.models.attention import KVCache, MLACache
from repro.models.layers import cdt, mlp, mlp_schema, rmsnorm, rmsnorm_schema
from repro.models.recurrent import MLSTMState, RGLRUState, SLSTMState
from repro.models.schema import LeafLayout, ParamSpec, layout_for_spec, stack_specs
from repro.sharding.rules import ShardingCtx

F32 = jnp.float32


# ==========================================================================
# Per-kind schemas
# ==========================================================================
def block_schema(cfg: ModelConfig, kind: str) -> dict[str, Any]:
    d = cfg.d_model
    if kind in ("attn_mlp", "local_attn"):
        return {
            "ln1": rmsnorm_schema(d),
            "attn": attn_mod.attention_schema(cfg),
            "ln2": rmsnorm_schema(d),
            "mlp": mlp_schema(cfg),
        }
    if kind == "attn_moe":
        return {
            "ln1": rmsnorm_schema(d),
            "attn": attn_mod.attention_schema(cfg),
            "ln2": rmsnorm_schema(d),
            "moe": moe_mod.moe_schema(cfg),
        }
    if kind == "rglru":
        return {
            "ln1": rmsnorm_schema(d),
            "rec": rec_mod.rglru_schema(cfg),
            "ln2": rmsnorm_schema(d),
            "mlp": mlp_schema(cfg),
        }
    if kind == "mlstm":
        return {"ln": rmsnorm_schema(d), "core": rec_mod.mlstm_schema(cfg)}
    if kind == "slstm":
        return {"ln": rmsnorm_schema(d), "core": rec_mod.slstm_schema(cfg)}
    if kind == "cross_attn_mlp":
        return {
            "ln1": rmsnorm_schema(d),
            "attn": attn_mod.gqa_schema(cfg),
            "ln_x": rmsnorm_schema(d),
            "xattn": attn_mod.gqa_schema(cfg, cross=True),
            "ln2": rmsnorm_schema(d),
            "mlp": mlp_schema(cfg),
        }
    raise ValueError(f"unknown block kind {kind}")


def paged_kv_kinds(cfg: ModelConfig) -> set[str]:
    """Block kinds whose decode KV caches live in the serving page pool.

    Dense GQA and windowed attention page; MLA compressed caches,
    recurrent states, and enc-dec cross blocks keep their per-slot
    layout behind the same cache interface.
    """
    kinds = {"local_attn"}
    if cfg.attn_kind != "mla":
        kinds |= {"attn_mlp", "attn_moe"}
    return kinds & (set(cfg.block_pattern) | set(cfg.first_blocks))


def _paged_kv_pool_schema(cfg: ModelConfig, pages) -> dict[str, ParamSpec]:
    """Pool-shaped KV leaves: (n_pages + 1, page_size, n_kv, head_dim).

    The +data_shards pages are per-shard trash pages all unused
    page-table entries point at (see serve/pages.py). The page axis
    carries the "pages" logical name: decode profiles shard it over
    data when the pool is data-partitioned (total_pages divisible —
    each data shard then owns a contiguous sub-pool ending in its own
    trash page), falling back to replication otherwise. Heads keep
    their TP sharding.
    """
    hd = cfg.resolved_head_dim
    shape = (pages.total_pages, pages.page_size, cfg.n_kv_heads, hd)
    axes = ("pages", None, "kv_heads", "head_dim")
    return {
        "k": ParamSpec(shape, axes, dtype=jnp.bfloat16, init="zeros"),
        "v": ParamSpec(shape, axes, dtype=jnp.bfloat16, init="zeros"),
    }


def block_state_schema(
    cfg: ModelConfig, kind: str, batch: int, s_max: int, pages=None
) -> dict[str, Any] | None:
    """Decode-state schema for one block (None when stateless).

    With ``pages`` (a serve.pages.PageLayout), paged kinds store their KV
    as a shared page pool instead of per-slot rows; everything else is
    unchanged.
    """
    if pages is not None and kind in paged_kv_kinds(cfg):
        return _paged_kv_pool_schema(cfg, pages)
    if kind in ("attn_mlp", "attn_moe"):
        if cfg.attn_kind == "mla":
            return attn_mod.init_mla_cache(cfg, batch, s_max)
        return attn_mod.init_kv_cache(cfg, batch, s_max, windowed=False)
    if kind == "local_attn":
        return attn_mod.init_kv_cache(cfg, batch, s_max, windowed=True)
    if kind == "rglru":
        return rec_mod.init_rglru_state(cfg, batch)
    if kind == "mlstm":
        return rec_mod.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return rec_mod.init_slstm_state(cfg, batch)
    if kind == "cross_attn_mlp":
        self_c = attn_mod.init_kv_cache(cfg, batch, s_max, windowed=False)
        hd = cfg.resolved_head_dim
        cross_c = {
            "k": ParamSpec((batch, cfg.enc_seq, cfg.n_kv_heads, hd), ("batch", "frames", "kv_heads", "head_dim"), dtype=jnp.bfloat16, init="zeros"),
            "v": ParamSpec((batch, cfg.enc_seq, cfg.n_kv_heads, hd), ("batch", "frames", "kv_heads", "head_dim"), dtype=jnp.bfloat16, init="zeros"),
        }
        return {"self": self_c, "cross": cross_c}
    raise ValueError(f"unknown block kind {kind}")


def _state_to_struct(kind: str, cfg: ModelConfig, raw: dict[str, Any] | None):
    """Wrap a raw state dict into the typed containers the block fns expect."""
    if raw is None:
        return None
    if kind in ("attn_mlp", "attn_moe"):
        if cfg.attn_kind == "mla":
            return MLACache(ckv=raw["ckv"], krope=raw["krope"])
        return KVCache(k=raw["k"], v=raw["v"])
    if kind == "local_attn":
        return KVCache(k=raw["k"], v=raw["v"])
    if kind == "rglru":
        return RGLRUState(h=raw["h"], conv=raw["conv"])
    if kind == "mlstm":
        return MLSTMState(C=raw["C"], n=raw["n"], m=raw["m"], conv=raw["conv"])
    if kind == "slstm":
        return SLSTMState(c=raw["c"], n=raw["n"], h=raw["h"], m=raw["m"])
    if kind == "cross_attn_mlp":
        return {
            "self": KVCache(k=raw["self"]["k"], v=raw["self"]["v"]),
            "cross": KVCache(k=raw["cross"]["k"], v=raw["cross"]["v"]),
        }
    raise ValueError(kind)


def _state_to_raw(kind: str, cfg: ModelConfig, st) -> dict[str, Any] | None:
    if st is None:
        return None
    if isinstance(st, KVCache):
        return {"k": st.k, "v": st.v}
    if isinstance(st, MLACache):
        return {"ckv": st.ckv, "krope": st.krope}
    if isinstance(st, RGLRUState):
        return {"h": st.h, "conv": st.conv}
    if isinstance(st, MLSTMState):
        return {"C": st.C, "n": st.n, "m": st.m, "conv": st.conv}
    if isinstance(st, SLSTMState):
        return {"c": st.c, "n": st.n, "h": st.h, "m": st.m}
    if isinstance(st, dict) and "self" in st:
        return {
            "self": {"k": st["self"].k, "v": st["self"].v},
            "cross": {"k": st["cross"].k, "v": st["cross"].v},
        }
    raise ValueError(f"unexpected state {type(st)}")


# ==========================================================================
# Block application
# ==========================================================================
class BlockIO(NamedTuple):
    x: jax.Array
    aux: jax.Array  # accumulated aux loss (MoE load balance)


def apply_block(
    p: dict[str, Any],
    cfg: ModelConfig,
    kind: str,
    io: BlockIO,
    *,
    mode: str,
    positions: jax.Array,
    cur_pos: jax.Array | None,
    state_raw: dict[str, Any] | None,
    mask_kind: str,
    sctx: ShardingCtx,
    enc_out: jax.Array | None = None,
    page_table: jax.Array | None = None,
    chunk_len: jax.Array | None = None,
) -> tuple[BlockIO, dict[str, Any] | None]:
    x, aux = io
    st = _state_to_struct(kind, cfg, state_raw)
    if page_table is not None and kind not in paged_kv_kinds(cfg):
        page_table = None
    eps = cfg.norm_eps
    new_st = None

    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        window = cfg.window_size if kind == "local_attn" else 0
        h = rmsnorm(p["ln1"], x, eps)
        if cfg.attn_kind == "mla" and kind != "local_attn":
            a, new_st = attn_mod.mla_attention(
                p["attn"], cfg, h, mode=mode, positions=positions,
                cache=st, cur_pos=cur_pos, chunk_len=chunk_len, sctx=sctx,
            )
        else:
            a, new_st = attn_mod.gqa_attention(
                p["attn"], cfg, h, mode=mode, positions=positions,
                mask_kind=mask_kind, window=window,
                prefix_len=cfg.prefix_len if cfg.prefix_lm else 0,
                cache=st, cur_pos=cur_pos, page_table=page_table,
                chunk_len=chunk_len, sctx=sctx,
            )
        x = x + a
        h = rmsnorm(p["ln2"], x, eps)
        if kind == "attn_moe":
            f, moe_aux = moe_mod.moe_ffn(p["moe"], cfg, h, sctx)
            aux = aux + moe_aux
        else:
            f = mlp(p["mlp"], cfg, h, sctx)
        x = x + f

    elif kind == "rglru":
        h = rmsnorm(p["ln1"], x, eps)
        r, new_st = rec_mod.rglru_block(
            p["rec"], cfg, h, mode=mode, state=st, chunk_len=chunk_len, sctx=sctx
        )
        x = x + r
        h = rmsnorm(p["ln2"], x, eps)
        x = x + mlp(p["mlp"], cfg, h, sctx)

    elif kind == "mlstm":
        h = rmsnorm(p["ln"], x, eps)
        r, new_st = rec_mod.mlstm_block(
            p["core"], cfg, h, mode=mode, state=st, chunk_len=chunk_len, sctx=sctx
        )
        x = x + r

    elif kind == "slstm":
        h = rmsnorm(p["ln"], x, eps)
        r, new_st = rec_mod.slstm_block(
            p["core"], cfg, h, mode=mode, state=st, chunk_len=chunk_len, sctx=sctx
        )
        x = x + r

    elif kind == "cross_attn_mlp":
        if mode == "chunk":
            raise NotImplementedError(
                "chunked prefill does not support enc-dec blocks; the "
                "scheduler streams such requests through whole-prompt prefill"
            )
        h = rmsnorm(p["ln1"], x, eps)
        a, new_self = attn_mod.gqa_attention(
            p["attn"], cfg, h, mode=mode, positions=positions, mask_kind="causal",
            cache=st["self"] if st else None,
            cur_pos=cur_pos, sctx=sctx,
        )
        x = x + a
        h = rmsnorm(p["ln_x"], x, eps)
        if mode == "decode":
            assert st is not None and "cross" in st, "decode needs a prefilled encoder cache"
            cross_kv = st["cross"]
        else:
            assert enc_out is not None, "enc-dec train/prefill needs encoder output"
            cross_kv = attn_mod.encoder_kv(p["xattn"], cfg, enc_out)
        x = x + attn_mod.cross_attention(p["xattn"], cfg, h, cross_kv, sctx)
        h = rmsnorm(p["ln2"], x, eps)
        x = x + mlp(p["mlp"], cfg, h, sctx)
        if mode in ("prefill", "decode"):
            new_st = {
                "self": new_self if new_self is not None else (st["self"] if st else None),
                "cross": cross_kv,
            }
        else:
            new_st = None

    else:
        raise ValueError(kind)

    return BlockIO(x=x, aux=aux), _state_to_raw(kind, cfg, new_st)


# ==========================================================================
# Stacks: first blocks (unscanned) + pattern groups (scanned)
# ==========================================================================
def stack_schema(cfg: ModelConfig) -> dict[str, Any]:
    sch: dict[str, Any] = {}
    if cfg.first_blocks:
        sch["first"] = {
            f"b{i}": block_schema(cfg, k) for i, k in enumerate(cfg.first_blocks)
        }
    n_groups = cfg.n_pattern_groups
    sch["groups"] = {
        f"g{i}": stack_specs(block_schema(cfg, k), n_groups)
        for i, k in enumerate(cfg.block_pattern)
    }
    return sch


def stack_state_schema(
    cfg: ModelConfig, batch: int, s_max: int, pages=None
) -> dict[str, Any]:
    sch: dict[str, Any] = {}
    if cfg.first_blocks:
        sch["first"] = {
            f"b{i}": block_state_schema(cfg, k, batch, s_max, pages=pages)
            for i, k in enumerate(cfg.first_blocks)
        }
    n_groups = cfg.n_pattern_groups
    sch["groups"] = {
        f"g{i}": stack_specs(block_state_schema(cfg, k, batch, s_max, pages=pages), n_groups)
        for i, k in enumerate(cfg.block_pattern)
    }
    return sch


# Per-kind overrides turning a zeroed state into the *empty-recurrence*
# state: the log-space stabilisers must start at their identity values or a
# chunked prefill resuming from a freshly reset slot diverges from a
# from-scratch prefill (which initialises these internally).
_FRESH_STATE_OVERRIDES: dict[str, dict[str, float]] = {
    "mlstm": {"m": -1e30},
    "slstm": {"n": 1e-6, "m": -1e30},
}


def fresh_stack_states(cfg: ModelConfig, states: dict[str, Any]) -> dict[str, Any]:
    """Rewrite a zero-initialised stack state pytree into the state a
    chunked prefill starts from at position 0 (see overrides above).
    Works on both per-slot (batch-1) and stacked-group layouts."""

    def patch(kind: str, st):
        ov = _FRESH_STATE_OVERRIDES.get(kind)
        if st is None or ov is None:
            return st
        return {
            k: (jnp.full_like(v, ov[k]) if k in ov else v) for k, v in st.items()
        }

    out: dict[str, Any] = {}
    if "first" in states:
        out["first"] = {
            f"b{i}": patch(kind, states["first"][f"b{i}"])
            for i, kind in enumerate(cfg.first_blocks)
        }
    out["groups"] = {
        f"g{i}": patch(kind, states["groups"][f"g{i}"])
        for i, kind in enumerate(cfg.block_pattern)
    }
    return out


def _block_layouts(
    cfg: ModelConfig, kind: str, s_max: int, paged: bool, stacked: bool
) -> dict[str, Any] | None:
    """Per-leaf :class:`LeafLayout` metadata for one block's decode state.

    Pool leaves are tagged ``paged`` with their logical token capacity;
    everything else derives its layout from the ParamSpec axis *names*
    (``window`` -> ring, ``kv_seq``/``frames`` -> dense, neither -> copy),
    so leaves with coinciding shapes can never be confused. ``stacked``
    group leaves carry their leading "layer" axis in the stacked spec,
    which shifts the derived axis indices automatically.
    """
    if paged and kind in paged_kv_kinds(cfg):
        cap = cfg.window_size if kind == "local_attn" else s_max
        lay = LeafLayout("paged", cap=cap)
        return {"k": lay, "v": lay}
    raw = block_state_schema(cfg, kind, 1, s_max)
    if stacked:
        raw = stack_specs(raw, 1)  # layer axis name only; count is irrelevant
    return jax.tree.map(layout_for_spec, raw, is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_layouts(cfg: ModelConfig, s_max: int, paged: bool = True) -> dict[str, Any]:
    """A pytree congruent with ``stack_state_schema`` whose leaves are
    :class:`LeafLayout` records. Stacking adds a leading layer axis but not
    tree structure, so the per-block layouts line up with stacked group
    states (their axis indices account for the layer axis)."""
    sch: dict[str, Any] = {}
    if cfg.first_blocks:
        sch["first"] = {
            f"b{i}": _block_layouts(cfg, k, s_max, paged, stacked=False)
            for i, k in enumerate(cfg.first_blocks)
        }
    sch["groups"] = {
        f"g{i}": _block_layouts(cfg, k, s_max, paged, stacked=True)
        for i, k in enumerate(cfg.block_pattern)
    }
    return sch


def stack_paged_caps(cfg: ModelConfig, s_max: int) -> dict[str, Any]:
    """Int view of :func:`stack_layouts`: each leaf's logical capacity when
    paged (0 = per-slot contiguous)."""
    return jax.tree.map(
        lambda lay: lay.cap if lay.kind == "paged" else 0,
        stack_layouts(cfg, s_max, paged=True),
        is_leaf=lambda x: isinstance(x, LeafLayout),
    )


def apply_stack(
    params: dict[str, Any],
    cfg: ModelConfig,
    x: jax.Array,
    *,
    mode: str,
    positions: jax.Array,
    cur_pos: jax.Array | None = None,
    states: dict[str, Any] | None = None,
    mask_kind: str = "causal",
    sctx: ShardingCtx,
    enc_out: jax.Array | None = None,
    page_table: jax.Array | None = None,
    chunk_len: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, dict[str, Any] | None]:
    """Run the whole layer stack. Returns (x, aux_loss, new_states)."""
    io = BlockIO(x=x, aux=jnp.zeros((), F32))
    new_states: dict[str, Any] = {"first": {}, "groups": {}}
    want_states = mode in ("prefill", "decode", "chunk")

    # -- unscanned prefix blocks ------------------------------------------
    for i, kind in enumerate(cfg.first_blocks):
        key = f"b{i}"
        st = states["first"][key] if states is not None else None
        io, new_st = apply_block(
            params["first"][key], cfg, kind, io, mode=mode, positions=positions,
            cur_pos=cur_pos, state_raw=st,
            mask_kind=mask_kind, sctx=sctx, enc_out=enc_out, page_table=page_table,
            chunk_len=chunk_len,
        )
        if want_states:
            new_states["first"][key] = new_st

    # -- scanned pattern groups -------------------------------------------
    def group_body(carry: BlockIO, per_layer):
        g_params, g_states = per_layer
        new_group_states = {}
        for i, kind in enumerate(cfg.block_pattern):
            key = f"g{i}"
            st = g_states[key] if g_states is not None else None
            carry, new_st = apply_block(
                g_params[key], cfg, kind, carry, mode=mode, positions=positions,
                cur_pos=cur_pos, state_raw=st,
                mask_kind=mask_kind, sctx=sctx, enc_out=enc_out,
                page_table=page_table, chunk_len=chunk_len,
            )
            new_group_states[key] = new_st
        return carry, (new_group_states if want_states else None)

    body = group_body
    if cfg.remat == "full" and mode == "train":
        body = jax.checkpoint(group_body, prevent_cse=False)

    g_states_in = states["groups"] if states is not None else None
    # REPRO_UNROLL_SCANS=1: fully unroll so XLA cost_analysis (which counts
    # while bodies once) sees every layer — used to validate the analytic
    # cost model on small cells (EXPERIMENTS.md SS Dry-run validation).
    unroll = bool(int(os.environ.get("REPRO_UNROLL_SCANS", "0")))
    io, scanned_states = jax.lax.scan(
        body, io, (params["groups"], g_states_in), unroll=True if unroll else 1
    )
    if want_states:
        new_states["groups"] = scanned_states
    if not cfg.first_blocks:
        new_states.pop("first", None)
    return io.x, io.aux, (new_states if want_states else None)
