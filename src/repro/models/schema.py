"""Declarative parameter schemas.

A model's parameters are described once as a pytree of :class:`ParamSpec`
(shape + dtype + logical sharding axes + initializer). From that single
source of truth we derive:

  * ``init_params``      — real arrays for CPU smoke tests / small trainings
  * ``abstract_params``  — ShapeDtypeStructs with NamedShardings for the
                           multi-pod dry-run (no allocation, ever)
  * ``pspec_tree``       — in/out shardings for pjit
  * ``count_params``     — exact parameter counts (roofline MODEL_FLOPS)

Keeping shapes and logical axes in one record is what guarantees the dry-run
shardings can never drift from what the training code actually does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import ShardingCtx, ShardingProfile, pspec_for


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float | None = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"ParamSpec shape {self.shape} / axes {self.axes} mismatch")

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass(frozen=True)
class LeafLayout:
    """Explicit cache-layout metadata for one decode-state leaf.

    Serving-side state surgery (serve/cache.py) used to locate a leaf's
    cache-sequence / batch axis by diffing source and target shapes —
    which silently mis-grafts when a windowed, MLA, or paged leaf happens
    to coincide in shape with a different layout. A ``LeafLayout`` is
    derived once from the leaf's :class:`ParamSpec` axis *names* (the
    same single source of truth the shardings come from) and dispatches
    the graft explicitly:

      * ``paged``  — lives in the shared page pool; ``cap`` is the leaf's
        logical token capacity (cache_len dense / window_size ring),
      * ``dense``  — contiguous KV rows, left-aligned grafts along
        ``seq_axis`` (source must fit the target: a longer source is a
        loud error, never a silent ring-fold),
      * ``ring``   — windowed ring buffer along ``seq_axis``; position p
        lands at slot ``p % W``,
      * ``copy``   — sequence-length-independent state (recurrent h/conv,
        cross-encoder KV): shapes must match exactly.

    Axis indices are measured on the actual serving arrays — scan-stacked
    group leaves carry their leading "layer" axis in the spec, so no
    offset bookkeeping is needed.
    """

    kind: str  # "paged" | "dense" | "ring" | "copy"
    seq_axis: int = -1  # cache-sequence axis (dense/ring)
    batch_axis: int = -1  # slot/batch axis (absent on pool leaves)
    cap: int = 0  # paged: logical token capacity


def layout_for_spec(spec: "ParamSpec") -> LeafLayout:
    """Derive a non-pool leaf's layout from its axis names."""
    axes = spec.axes
    batch = axes.index("batch") if "batch" in axes else -1
    if "window" in axes:
        return LeafLayout("ring", seq_axis=axes.index("window"), batch_axis=batch)
    for name in ("kv_seq", "frames"):
        if name in axes:
            return LeafLayout("dense", seq_axis=axes.index(name), batch_axis=batch)
    return LeafLayout("copy", batch_axis=batch)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(tree: Any, fn: Callable[[ParamSpec], Any]) -> Any:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def _fan_in(shape: tuple[int, ...]) -> int:
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1]))


def init_params(schema: Any, key: jax.Array, dtype: Any = None) -> Any:
    """Materialise real arrays (smoke tests / real small trainings)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(spec: ParamSpec, k: jax.Array) -> jax.Array:
        dt = dtype or spec.dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init == "embed":
            scale = spec.scale if spec.scale is not None else 1.0
            return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)
        scale = spec.scale
        if scale is None:
            scale = 1.0 / np.sqrt(max(_fan_in(spec.shape), 1))
        if spec.init == "small":
            scale = scale * 0.1
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)

    out = [one(spec, k) for spec, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def pspec_tree(schema: Any, ctx: ShardingCtx, extra_leading: tuple[str | None, ...] = ()) -> Any:
    """PartitionSpecs for every param (optionally with stacked leading axes)."""

    def one(spec: ParamSpec) -> P:
        if ctx.mesh is None:
            return P()
        return pspec_for(spec.shape, spec.axes, ctx.profile, ctx.mesh)

    return _map_specs(schema, one)


def sharding_tree(schema: Any, ctx: ShardingCtx) -> Any:
    """Per-leaf NamedShardings resolved from the schema's logical axes.

    Returns None without a mesh — callers branch on that instead of
    carrying a tree of placeholder leaves. This is the single resolution
    point the serving scheduler uses to place its batched decode state
    (and the page-pool leaves) and to pin every step program's output
    layout, so state never silently drifts off its profile-resolved
    sharding between steps.
    """
    if ctx.mesh is None:
        return None
    return _map_specs(
        schema,
        lambda spec: NamedSharding(
            ctx.mesh, pspec_for(spec.shape, spec.axes, ctx.profile, ctx.mesh)
        ),
    )


def shard_tree(tree: Any, schema: Any, ctx: ShardingCtx) -> Any:
    """device_put materialised leaves at their schema-resolved shardings
    (identity without a mesh). ``tree`` must be congruent with ``schema``."""
    shardings = sharding_tree(schema, ctx)
    if shardings is None:
        return tree
    return jax.tree.map(jax.device_put, tree, shardings)


def abstract_params(schema: Any, ctx: ShardingCtx, dtype: Any = None) -> Any:
    """ShapeDtypeStructs with shardings attached — the dry-run's 'weights'."""

    def one(spec: ParamSpec) -> jax.ShapeDtypeStruct:
        dt = dtype or spec.dtype
        if ctx.mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, dt)
        sharding = NamedSharding(
            ctx.mesh, pspec_for(spec.shape, spec.axes, ctx.profile, ctx.mesh)
        )
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=sharding)

    return _map_specs(schema, one)


def count_params(schema: Any) -> int:
    leaves = jax.tree.leaves(schema, is_leaf=is_spec)
    return int(sum(l.size for l in leaves))


def stack_specs(schema: Any, n: int, axis_name: str | None = "layer") -> Any:
    """Stack a per-layer schema ``n`` times along a new leading 'layer' dim.

    Used for scanned blocks: params live as (n_layers, ...) arrays so the
    layer loop is a single ``lax.scan`` over the leading axis.
    """

    def one(spec: ParamSpec) -> ParamSpec:
        return ParamSpec(
            shape=(n,) + spec.shape,
            axes=(axis_name,) + spec.axes,
            dtype=spec.dtype,
            init=spec.init,
            scale=spec.scale,
        )

    return _map_specs(schema, one)


def cast_tree(tree: Any, dtype: Any) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)


def tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total
