"""Unified model API over every assigned architecture.

  * ``model_schema(cfg)``            — full parameter schema
  * ``decode_state_schema(cfg, B, S)`` — per-layer decode states + step counter
  * ``forward_train(params, cfg, batch, sctx)`` — (loss, metrics)
  * ``prefill(params, cfg, batch, sctx)``       — (last_logits, states)
  * ``decode_step(params, cfg, states, token, sctx)`` — (logits, new states)

``batch`` dict keys by family:
  lm:    tokens (B,S) int32, labels (B,S) int32
  vlm:   + prefix_embeds (B, P, d)   (SigLIP stub output)
  audio: + enc_embeds (B, T_enc, d)  (conv-frontend stub output)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.layers import (
    F32,
    cdt,
    chunked_softmax_xent,
    embed_tokens,
    embedding_schema,
    logits_for_positions,
    rmsnorm,
    rmsnorm_schema,
    unembed_weight,
)
from repro.models.schema import ParamSpec
from repro.sharding.rules import ShardingCtx, constrain


# ==========================================================================
# Schemas
# ==========================================================================
def model_schema(cfg: ModelConfig) -> dict[str, Any]:
    cfg.validate()
    sch: dict[str, Any] = {
        "embed": embedding_schema(cfg),
        "stack": blk.stack_schema(cfg),
        "final_norm": rmsnorm_schema(cfg.d_model),
    }
    if cfg.enc_dec:
        enc_cfg = _encoder_cfg(cfg)
        sch["encoder"] = {
            "stack": blk.stack_schema(enc_cfg),
            "final_norm": rmsnorm_schema(cfg.d_model),
        }
    if cfg.prefix_len:
        # Projection applied to the (stubbed) modality embeddings.
        sch["prefix_proj"] = ParamSpec((cfg.d_model, cfg.d_model), ("embed", None))
    return sch


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    from dataclasses import replace

    return replace(
        cfg,
        name=cfg.name + "-enc",
        n_layers=cfg.n_enc_layers,
        block_pattern=("attn_mlp",),
        first_blocks=(),
        enc_dec=False,
        moe=None if cfg.moe is None else cfg.moe,
    )


def decode_state_schema(
    cfg: ModelConfig, batch: int, s_max: int, pages=None
) -> dict[str, Any]:
    return {
        "layers": blk.stack_state_schema(cfg, batch, s_max, pages=pages),
        "pos": ParamSpec((), (), dtype=jnp.int32, init="zeros"),
    }


# ==========================================================================
# Shared forward trunk
# ==========================================================================
def _embed_inputs(
    params: dict[str, Any], cfg: ModelConfig, batch: dict[str, Any], sctx: ShardingCtx
) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """Returns (x (B,S,d), positions (S,), enc_out or None)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], cfg, tokens, sctx)
    x = x * jnp.asarray(cfg.d_model**0.5, cdt(cfg))

    if cfg.prefix_len:
        pe = batch["prefix_embeds"].astype(cdt(cfg))
        pe = jnp.einsum("bpd,de->bpe", pe, params["prefix_proj"].astype(cdt(cfg)))
        x = jnp.concatenate([pe, x], axis=1)

    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    enc_out = None
    if cfg.enc_dec:
        enc_cfg = _encoder_cfg(cfg)
        e = batch["enc_embeds"].astype(cdt(cfg))
        e, _, _ = blk.apply_stack(
            params["encoder"]["stack"], enc_cfg, e, mode="train",
            positions=jnp.arange(e.shape[1], dtype=jnp.int32),
            mask_kind="bidir", sctx=sctx,
        )
        enc_out = rmsnorm(params["encoder"]["final_norm"], e, cfg.norm_eps)
    return x, positions, enc_out


def _mask_kind(cfg: ModelConfig) -> str:
    return "prefix" if cfg.prefix_lm else "causal"


# ==========================================================================
# Training
# ==========================================================================
def forward_train(
    params: dict[str, Any], cfg: ModelConfig, batch: dict[str, Any], sctx: ShardingCtx
) -> tuple[jax.Array, dict[str, jax.Array]]:
    x, positions, enc_out = _embed_inputs(params, cfg, batch, sctx)
    x, aux, _ = blk.apply_stack(
        params["stack"], cfg, x, mode="train", positions=positions,
        mask_kind=_mask_kind(cfg), sctx=sctx, enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    labels = batch["labels"]
    if cfg.prefix_len:
        # Image/prefix positions carry no LM loss.
        pad = jnp.full((labels.shape[0], cfg.prefix_len), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    w = unembed_weight(params["embed"], cfg)
    loss_sum, n_tok = chunked_softmax_xent(x, w, labels, cfg, sctx)
    xent = loss_sum / jnp.maximum(n_tok, 1.0)
    loss = xent + aux
    return loss, {"loss": loss, "xent": xent, "aux": aux, "tokens": n_tok}


# ==========================================================================
# Prefill / decode
# ==========================================================================
def prefill(
    params: dict[str, Any], cfg: ModelConfig, batch: dict[str, Any], sctx: ShardingCtx
) -> tuple[jax.Array, dict[str, Any]]:
    x, positions, enc_out = _embed_inputs(params, cfg, batch, sctx)
    S = x.shape[1]
    x, _, states = blk.apply_stack(
        params["stack"], cfg, x, mode="prefill", positions=positions,
        mask_kind=_mask_kind(cfg), sctx=sctx, enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    # Bucketed prefill right-pads prompts to a shared length; the logits of
    # record are then at batch["logit_pos"] (the true last position — a
    # traced scalar, so every prompt length in a bucket shares one program),
    # not at the padded tail.
    logit_pos = batch.get("logit_pos")
    if logit_pos is None:
        x_last = x[:, -1:, :]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(x, jnp.asarray(logit_pos), 1, axis=1)
    logits = logits_for_positions(
        x_last, unembed_weight(params["embed"], cfg), cfg, sctx
    )
    states = {"layers": states, "pos": jnp.asarray(S, jnp.int32)}
    return logits, states


def chunk_step(
    params: dict[str, Any],
    cfg: ModelConfig,
    states: dict[str, Any],
    tokens: jax.Array,  # (1, C) int32: one slot's prompt chunk (maybe padded)
    chunk_len: jax.Array,  # scalar int32: number of real tokens (<= C)
    sctx: ShardingCtx,
    all_logits: bool = False,
) -> tuple[jax.Array, dict[str, Any]]:
    """Streamed (chunked) prefill for one slot.

    Runs ``tokens`` at absolute positions ``pos .. pos + C - 1`` against the
    slot's existing caches — attention layers read the already-cached prefix
    plus the chunk and write the chunk's K/V in place (through the page
    table when ``states`` carries one); recurrent layers advance their
    carried state. Positions beyond ``chunk_len`` are bucket padding: their
    cache writes are dropped/trash-routed and recurrence updates masked, so
    every true length in a chunk bucket shares one compiled program. Returns
    the logits at position ``chunk_len - 1`` (the sampling point when the
    chunk completes the prompt) and the updated states with
    ``pos + chunk_len`` tokens cached.

    With ``all_logits`` the returned logits cover every chunk position
    ``(1, C, V)`` — the **verify mode** speculative decoding rides: the
    logits at chunk index ``i`` are exactly what a sequential decode step
    would produce after consuming ``tokens[:, : i + 1]``, so one chunk call
    scores a whole drafted run at once (positions past ``chunk_len`` are
    pad garbage; callers slice them off)."""
    cur_pos = jnp.asarray(states["pos"])  # scalar: tokens already cached
    page_table = states.get("page_table")
    x = embed_tokens(params["embed"], cfg, tokens, sctx)
    x = x * jnp.asarray(cfg.d_model**0.5, cdt(cfg))
    C = tokens.shape[1]
    positions = cur_pos + jnp.arange(C, dtype=jnp.int32)

    x, _, new_states = blk.apply_stack(
        params["stack"], cfg, x, mode="chunk", positions=positions,
        cur_pos=cur_pos, states=states["layers"], mask_kind=_mask_kind(cfg),
        sctx=sctx, page_table=page_table, chunk_len=chunk_len,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if not all_logits:
        x = jax.lax.dynamic_slice_in_dim(x, chunk_len - 1, 1, axis=1)
    logits = logits_for_positions(
        x, unembed_weight(params["embed"], cfg), cfg, sctx
    )
    out = {"layers": new_states, "pos": cur_pos + chunk_len}
    if page_table is not None:
        out["page_table"] = page_table
    return logits, out


def decode_step(
    params: dict[str, Any],
    cfg: ModelConfig,
    states: dict[str, Any],
    token: jax.Array,  # (B, 1) int32
    sctx: ShardingCtx,
    prefix_embeds: jax.Array | None = None,
) -> tuple[jax.Array, dict[str, Any]]:
    """One decode step. ``states["pos"]`` is either a scalar (static batch:
    every sequence at the same position) or (B,) (continuous batching: each
    slot at its own position). The output pos mirrors the input structure, so
    the jitted step keeps a stable pytree either way.

    When ``states`` carries ``"page_table"`` (paged serving), dense/windowed
    KV layers treat their cache leaves as shared page pools and route reads
    and writes through the table; it passes through to the output unchanged
    (the scheduler owns its values)."""
    cur_pos = jnp.asarray(states["pos"])
    page_table = states.get("page_table")
    x = embed_tokens(params["embed"], cfg, token, sctx)
    x = x * jnp.asarray(cfg.d_model**0.5, cdt(cfg))
    if cur_pos.ndim == 0:
        positions = cur_pos[None].astype(jnp.int32)  # (1,) shared
    else:
        positions = cur_pos[:, None].astype(jnp.int32)  # (B, 1) per slot

    x, _, new_states = blk.apply_stack(
        params["stack"], cfg, x, mode="decode", positions=positions,
        cur_pos=cur_pos,
        states=states["layers"], mask_kind=_mask_kind(cfg), sctx=sctx,
        page_table=page_table,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_for_positions(x, unembed_weight(params["embed"], cfg), cfg, sctx)
    out = {"layers": new_states, "pos": cur_pos + 1}
    if page_table is not None:
        out["page_table"] = page_table
    return logits, out
