"""repro — Memento-orchestrated multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
