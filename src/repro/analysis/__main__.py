"""``python -m repro.analysis`` — tables, trajectories, regressions, dash.

Subcommands:

* ``table``       render a grouped comparison table from a results CSV
                  (``ResultSet.to_csv``) or the latest benchmark record;
                  ``--diff R1 R2 ...`` diffs runs with ratio/delta columns
* ``trajectory``  list benchmark records, or one metric's series across them
* ``regressions`` diff a benchmark record against its lineage baseline;
                  ``--strict`` exits nonzero when regressions exist (CI)
* ``dash``        serve the live dashboard over an event journal

Output is plain text/markdown/CSV on stdout — the table renderers are the
same code the Python API uses, so CLI output and ``compare(...)`` output are
identical token for token.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Any

from .metrics import MetricFrame, _as_float
from .tables import AGGREGATORS, compare, compare_frames
from .trajectory import (
    DEFAULT_RECORDS_DIR,
    RegressionPolicy,
    Trajectory,
    diff_latest,
    load_policies,
)


def _coerce(label: str) -> Any:
    """CLI strings match numeric column labels by value (2 == "2")."""
    num = _as_float(label)
    if num is None:
        return label
    return int(num) if num == int(num) else num


def _resolve_baseline(baseline: str | None, col_labels: list[Any]) -> Any:
    if baseline is None:
        return None
    for cand in (baseline, _coerce(baseline)):
        if cand in col_labels:
            return cand
    raise SystemExit(
        f"error: baseline {baseline!r} is not a column: {col_labels}"
    )


def _render(table: Any, fmt: str) -> str:
    if fmt == "md":
        return table.to_markdown()
    if fmt == "csv":
        return table.to_csv()
    return str(table)


def _diff_frames(args: argparse.Namespace, metric: str):
    """Resolve ``--diff`` run tokens into labeled frames.

    A token of digits names a benchmark record in ``--records-dir``;
    anything else is read as a ``ResultSet.to_csv`` file. Record frames
    carry only ``metric``; CSV frames carry every metric in the file.
    """
    from .trajectory import Trajectory

    traj = None
    pairs: list[tuple[str, MetricFrame]] = []
    all_records = True
    for tok in args.diff:
        if re.fullmatch(r"\d+", tok):
            if traj is None:
                traj = Trajectory.load(args.records_dir)
            rec = traj.get(int(tok))
            if rec is None:
                raise SystemExit(
                    f"error: no record {tok} in {args.records_dir}"
                )
            frame = Trajectory([rec]).to_frame(metrics=(metric,))
            pairs.append((f"record {rec.record}", frame))
        else:
            all_records = False
            pairs.append((tok, MetricFrame.from_results_csv(tok)))
    return pairs, all_records


def cmd_table_diff(args: argparse.Namespace) -> int:
    if args.csv or args.latest:
        raise SystemExit("error: --diff is exclusive with --csv/--latest")
    if len(args.diff) < 2:
        raise SystemExit("error: --diff needs at least two runs")
    if args.metric and len(args.metric) > 1:
        raise SystemExit("error: --diff compares exactly one metric")
    metric = args.metric[0] if args.metric else "tok_s"
    pairs, all_records = _diff_frames(args, metric)
    rows = args.rows or (["benchmark"] if all_records else None)
    if not rows:
        raise SystemExit("error: --rows is required when --diff includes CSVs")
    table = compare_frames(
        pairs, rows=rows, metric=metric, agg=args.agg,
        title=args.title or f"{metric}: " + " vs ".join(lb for lb, _ in pairs),
    )
    if args.baseline:
        table.baseline = _resolve_baseline(args.baseline, table.col_labels)
    print(_render(table, args.format))
    return 0


def cmd_table(args: argparse.Namespace) -> int:
    if args.diff:
        return cmd_table_diff(args)
    if bool(args.csv) == bool(args.latest):
        raise SystemExit("error: pass exactly one of --csv PATH or --latest")
    if args.csv:
        frame = MetricFrame.from_results_csv(args.csv)
        default_rows = None
    else:
        traj = Trajectory.load(args.records_dir)
        latest = traj.latest(args.mode or None)
        if latest is None:
            raise SystemExit(f"error: no records in {args.records_dir}")
        frame = Trajectory([latest]).to_frame(
            metrics=tuple(args.metric) if args.metric else ("tok_s", "wall_s")
        )
        default_rows = ["benchmark"]
        if not args.title:
            args.title = (
                f"Benchmark record {latest.record} "
                f"({latest.mode}, {latest.commit[:12]})"
            )
    rows = args.rows or default_rows
    if not rows:
        raise SystemExit("error: --rows is required with --csv")
    metric = None
    if args.metric and (args.cols or len(args.metric) == 1):
        metric = args.metric[0]
    table = compare(
        frame,
        rows=rows,
        cols=args.cols or None,
        metric=metric,
        agg=args.agg,
        title=args.title,
    )
    table.baseline = _resolve_baseline(args.baseline, table.col_labels)
    print(_render(table, args.format))
    return 0


def cmd_trajectory(args: argparse.Namespace) -> int:
    traj = Trajectory.load(args.records_dir).filter(
        mode=args.mode or None, benchmark=args.benchmark or None
    )
    if not len(traj):
        print(f"no records in {args.records_dir}", file=sys.stderr)
        return 1
    if args.series:
        pts = traj.series(args.series, metric=args.metric_name)
        if args.json:
            print(json.dumps(
                {"name": args.series, "metric": args.metric_name,
                 "series": [{"record": n, "value": v} for n, v in pts]}
            ))
        else:
            print(f"{args.series} {args.metric_name}:")
            for n, v in pts:
                print(f"  record {n}: {v:g}")
        return 0
    if args.json:
        print(json.dumps([
            {"record": r.record, "mode": r.mode, "commit": r.commit,
             "timestamp": r.timestamp, "rows": len(r.rows)}
            for r in traj
        ]))
    else:
        for r in traj:
            print(
                f"record {r.record}  mode={r.mode}  commit={r.commit[:12]}  "
                f"rows={len(r.rows)}  {r.timestamp}"
            )
    return 0


def _policies(args: argparse.Namespace) -> tuple[RegressionPolicy, ...]:
    if not args.policy:
        # No CLI overrides: thresholds come from the checked-in policy
        # file (benchmarks/policy.json by default), falling back to the
        # built-in >30% tok/s rule when no file exists.
        return load_policies(getattr(args, "policy_file", None))
    out = []
    for p in args.policy:
        # metric[:max_drop[:lower_is_better]] e.g. tok_s:0.3 or itl_p50_s:0.5:lower
        parts = p.split(":")
        out.append(
            RegressionPolicy(
                metric=parts[0],
                max_drop=float(parts[1]) if len(parts) > 1 else 0.30,
                higher_is_better=not (len(parts) > 2 and parts[2] == "lower"),
            )
        )
    return tuple(out)


def cmd_regressions(args: argparse.Namespace) -> int:
    new, base, regs = diff_latest(
        args.records_dir, record=args.record, policies=_policies(args)
    )
    if new is None:
        print(f"no records in {args.records_dir}", file=sys.stderr)
        return 1
    if base is None:
        print(f"record {new.record}: no comparable baseline (first of its "
              f"mode, or every earlier record is from a diverged branch)")
        return 0
    for r in regs:
        print(r.warn_line())
    if not regs:
        print(f"record {new.record} vs record {base.record}: no regressions")
    return 1 if (regs and args.strict) else 0


def cmd_dash(args: argparse.Namespace) -> int:
    from .dash import serve_journal

    dash, prov = serve_journal(
        args.journal, host=args.host, port=args.port,
        follow=not args.no_follow, total=args.total,
        records_dir=args.records_dir,
    )
    print(f"dashboard: {dash.url}  (journal: {args.journal})")
    try:
        import time

        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        dash.stop()
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Analysis over Memento results and benchmark records.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    t = sub.add_parser("table", help="grouped comparison table")
    t.add_argument("--csv", help="ResultSet.to_csv file to analyze")
    t.add_argument("--latest", action="store_true",
                   help="use the latest benchmark record instead of a CSV")
    t.add_argument("--diff", nargs="+", metavar="RUN",
                   help="diff two or more runs (record numbers from "
                   "--records-dir and/or ResultSet CSV paths): one column "
                   "per run, ratio/delta vs the first")
    t.add_argument("--records-dir", default=DEFAULT_RECORDS_DIR)
    t.add_argument("--mode", default="", help="with --latest: restrict mode")
    t.add_argument("--rows", nargs="+", help="param keys for table rows")
    t.add_argument("--cols", nargs="+", help="param keys for table columns "
                   "(default: one column per metric)")
    t.add_argument("--metric", nargs="+", help="metric name(s) to include")
    t.add_argument("--agg", default="mean", choices=sorted(AGGREGATORS),
                   help="cell aggregator (default: mean)")
    t.add_argument("--baseline", help="column label to diff the others against")
    t.add_argument("--title", default="")
    t.add_argument("--format", default="md", choices=("md", "csv", "text"))
    t.set_defaults(fn=cmd_table)

    tr = sub.add_parser("trajectory", help="query benchmark records")
    tr.add_argument("--records-dir", default=DEFAULT_RECORDS_DIR)
    tr.add_argument("--mode", default="")
    tr.add_argument("--benchmark", default="",
                    help="restrict to rows whose name starts with this")
    tr.add_argument("--series", help="print one benchmark row's series")
    tr.add_argument("--metric", dest="metric_name", default="tok_s")
    tr.add_argument("--json", action="store_true")
    tr.set_defaults(fn=cmd_trajectory)

    rg = sub.add_parser("regressions", help="diff a record vs its baseline")
    rg.add_argument("--records-dir", default=DEFAULT_RECORDS_DIR)
    rg.add_argument("--record", type=int, help="record number (default: latest)")
    rg.add_argument("--policy", nargs="+",
                    help="metric[:max_drop[:lower]] e.g. tok_s:0.3 itl_p50_s:0.5:lower")
    rg.add_argument("--policy-file",
                    help="JSON policy file (default: benchmarks/policy.json "
                    "when present); --policy flags override it")
    rg.add_argument("--strict", action="store_true",
                    help="exit 1 when regressions are found (CI gate)")
    rg.set_defaults(fn=cmd_regressions)

    d = sub.add_parser("dash", help="serve the live dashboard over a journal")
    d.add_argument("--journal", required=True, help="event journal (JSONL)")
    d.add_argument("--host", default="127.0.0.1")
    d.add_argument("--port", type=int, default=8321)
    d.add_argument("--total", type=int, help="expected task total (for ETA)")
    d.add_argument("--records-dir", default=DEFAULT_RECORDS_DIR,
                   help="perf records dir backing /api/trajectory sparklines")
    d.add_argument("--no-follow", action="store_true",
                   help="replay once, don't tail the journal")
    d.set_defaults(fn=cmd_dash)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
