"""Live fleet dashboard: stdlib HTTP server over a structured event journal.

:class:`AnalysisNotificationProvider` is a ``NotificationProvider`` that tees
every engine event — ``task_started``/``task_finished``/``task_failed`` from
``Memento.stream`` / the Runner, plus the distributed driver's periodic
``queue_progress`` snapshots — into

* an append-only JSONL **journal** (optional; on a shared filesystem any
  host can tail it), and
* live in-memory **aggregates**: totals, ETA, per-host throughput and task
  rates, latest serve metrics (accept rate, inter-token latency), queue
  depth, and a failure list carrying the *real* tracebacks the distributed
  runtime propagates.

:class:`Dashboard` serves those aggregates with nothing but ``http.server``:

* ``GET /``               one-page live view (polling JS, no dependencies)
* ``GET /api/state``      the aggregate snapshot as JSON
* ``GET /api/events``     the journal tail (``?since=<cursor>`` to page)
* ``GET /api/stream``     Server-Sent Events: state snapshots pushed ~1/s
* ``GET /api/trajectory`` per-benchmark metric series across the persisted
  ``benchmarks/records/`` perf records (``?metric=&mode=&benchmark=``) —
  rendered as inline sparklines on the fleet view

Pair with ``python -m repro.analysis dash --journal <path>`` to watch a run
owned by another process (or a whole fleet writing to one shared journal).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from pathlib import Path
from typing import Any, Mapping

from repro.core.notifications import Event, NotificationProvider

from .metrics import _as_float

# Serve-sweep metrics worth surfacing verbatim on the fleet view when a
# task's result carries them (see experiments/serve.py SERVE_METRIC_SPECS).
_SERVE_KEYS = (
    "tokens_per_s", "itl_p50_s", "itl_p95_s", "accept_rate",
    "tokens_per_model_step", "ttft_p50_s",
)


class AnalysisNotificationProvider(NotificationProvider):
    """Tee engine events into a JSONL journal + live fleet aggregates.

    Use either as the engine's ``notification_provider`` (events arrive via
    :meth:`notify`) or wrapped around a stream (``for r in prov.track(
    eng.stream_distributed(...))``) — or both; task results surfaced through
    ``track`` are de-duplicated against ones already seen via events.
    """

    def __init__(
        self,
        journal_path: str | Path | None = None,
        total: int | None = None,
        max_events: int = 4096,
    ):
        self.journal_path = Path(journal_path) if journal_path else None
        if self.journal_path is not None:
            self.journal_path.parent.mkdir(parents=True, exist_ok=True)
        self.total = total
        self._events: deque[dict[str, Any]] = deque(maxlen=max_events)
        self._seq = 0  # cursor of the *next* event (monotonic, survives eviction)
        self._t0: float | None = None
        self._done_keys: set[str] = set()
        self._failed = 0
        self._cached = 0
        self._hosts: dict[str, dict[str, Any]] = {}
        self._failures: deque[dict[str, Any]] = deque(maxlen=256)
        self._queue: dict[str, Any] | None = None
        self._serve: dict[str, float] = {}
        self._lock = threading.Lock()

    # -- ingestion ----------------------------------------------------------
    def notify(self, event: Event) -> None:
        self.ingest(event.to_record())

    def ingest(self, rec: Mapping[str, Any]) -> None:
        """One structured event record (from :meth:`notify` or a replayed
        journal line). Journal writes happen only for live events, not
        replays — replay marks records with ``_replayed``."""
        rec = dict(rec)
        replayed = rec.pop("_replayed", False)
        with self._lock:
            self._ingest_locked(rec)
            self._seq += 1
            self._events.append(rec)
        if self.journal_path is not None and not replayed:
            line = json.dumps(rec, default=str)
            with self._lock:
                with open(self.journal_path, "a") as f:
                    f.write(line + "\n")

    def _ingest_locked(self, rec: Mapping[str, Any]) -> None:
        kind = rec.get("kind")
        t = _as_float(rec.get("t")) or time.time()
        if kind == "run_started":
            if self._t0 is None:
                self._t0 = t
            total = rec.get("total")
            if self.total is None and isinstance(total, int):
                self.total = total
            return
        if kind == "queue_progress":
            self._queue = {k: v for k, v in rec.items()
                           if k not in ("kind", "message", "t")}
            return
        if kind not in ("task_finished", "task_failed"):
            return
        key = str(rec.get("key", ""))
        if key and key in self._done_keys:
            return  # track() + notify() double-report the same task
        self._done_keys.add(key or f"@{self._seq}")
        if self._t0 is None:
            self._t0 = t
        host = str(rec.get("host") or "?")
        h = self._hosts.setdefault(
            host,
            {"done": 0, "failed": 0, "cached": 0, "wall_s": 0.0, "tokens": 0.0,
             "first_t": t, "last_t": t, "metrics": {}},
        )
        h["done"] += 1
        h["last_t"] = max(h["last_t"], t)
        h["wall_s"] += _as_float(rec.get("wall_s")) or 0.0
        if rec.get("cached"):
            self._cached += 1
            h["cached"] += 1
        metrics = rec.get("metrics")
        if isinstance(metrics, Mapping):
            h["tokens"] += metrics.get("generated_tokens", 0.0) or 0.0
            latest = {k: metrics[k] for k in _SERVE_KEYS
                      if metrics.get(k) is not None}
            if latest:
                h["metrics"] = latest
                self._serve.update(latest)
        if kind == "task_failed":
            self._failed += 1
            h["failed"] += 1
            self._failures.append(
                {
                    "key": key,
                    "params": rec.get("params") or {},
                    "host": host,
                    "error": rec.get("error"),
                    "traceback": rec.get("traceback"),
                    "attempts": rec.get("attempts"),
                    "t": t,
                }
            )

    def track(self, results: Any) -> Any:
        """Wrap a result stream: every ``TaskResult`` passes through
        unchanged while being folded into the aggregates (cache hits
        included — they bypass execution and therefore events)."""
        for result in results:
            try:
                self.task_finished(result)
            except Exception:
                pass  # providers must never take the run down
            yield result

    def replay_journal(self, path: str | Path | None = None, offset: int = 0) -> int:
        """Feed journal lines (JSONL event records) starting at byte
        ``offset``; returns the new offset — poll it to tail a live run."""
        p = Path(path or self.journal_path or "")
        try:
            with open(p) as f:
                f.seek(offset)
                for line in f:
                    if not line.endswith("\n"):
                        break  # half-written tail; pick it up next poll
                    offset += len(line.encode())
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue
                    rec["_replayed"] = True
                    self.ingest(rec)
        except OSError:
            pass
        return offset

    # -- queries ------------------------------------------------------------
    def eta_s(self) -> float | None:
        with self._lock:
            return self._eta_locked()

    def _eta_locked(self) -> float | None:
        done = len(self._done_keys)
        live = done - self._cached
        if self.total is None or self._t0 is None or live <= 0:
            return None
        remaining = max(self.total - done, 0)
        rate = live / max(time.time() - self._t0, 1e-9)
        return remaining / rate if rate > 0 else None

    def state(self) -> dict[str, Any]:
        """JSON-safe aggregate snapshot — the dashboard's /api/state body."""
        with self._lock:
            now = time.time()
            done = len(self._done_keys)
            hosts = {}
            for name, h in sorted(self._hosts.items()):
                elapsed = max(h["last_t"] - (self._t0 or h["first_t"]), 1e-9)
                hosts[name] = {
                    "done": h["done"],
                    "failed": h["failed"],
                    "cached": h["cached"],
                    "tasks_per_s": round(h["done"] / elapsed, 3),
                    "tokens_per_s": (
                        round(h["tokens"] / h["wall_s"], 2) if h["wall_s"] else None
                    ),
                    "metrics": dict(h["metrics"]),
                }
            queue = dict(self._queue) if self._queue else None
            return {
                "t": now,
                "total": self.total,
                "done": done,
                "failed": self._failed,
                "cached": self._cached,
                "running_s": (round(now - self._t0, 1) if self._t0 else None),
                "eta_s": (lambda e: None if e is None else round(e, 1))(
                    self._eta_locked()
                ),
                "hosts": hosts,
                "queue": queue,
                "serve": dict(self._serve),
                "failures": list(self._failures),
                "events_seen": self._seq,
            }

    def events_since(self, cursor: int = 0) -> tuple[int, list[dict[str, Any]]]:
        """Events with sequence >= cursor (bounded by the ring buffer);
        returns (next_cursor, records)."""
        with self._lock:
            first = self._seq - len(self._events)
            start = max(cursor, first)
            out = [self._events[i - first] for i in range(start, self._seq)]
            return self._seq, out


def trajectory_payload(
    records_dir: str | Path | None = None,
    metric: str = "tok_s",
    mode: str | None = None,
    benchmark: str | None = None,
) -> dict[str, Any]:
    """The ``/api/trajectory`` body: per-benchmark series of ``metric``
    across the persisted perf records, oldest first — what the dashboard
    draws as sparklines. Loaded per call; records dirs are tiny."""
    from .trajectory import DEFAULT_RECORDS_DIR, Trajectory

    d = str(records_dir or DEFAULT_RECORDS_DIR)
    traj = Trajectory.load(d).filter(mode=mode, benchmark=benchmark)
    series = {}
    for name in traj.names(metric):
        pts = traj.series(name, metric=metric)
        if pts:
            series[name] = [{"record": n, "value": v} for n, v in pts]
    return {
        "records_dir": d,
        "metric": metric,
        "modes": traj.modes(),
        "records": [r.record for r in traj],
        "series": series,
    }


_INDEX_HTML = """<!doctype html>
<html lang="en"><head><meta charset="utf-8">
<title>memento fleet</title>
<style>
  :root { color-scheme: dark; }
  body { font: 14px/1.45 ui-monospace, SFMono-Regular, Menlo, monospace;
         background: #16161d; color: #e8e8ec; margin: 2rem; }
  h1 { font-size: 16px; font-weight: 600; color: #e8e8ec; }
  .muted { color: #9a9aa5; }
  .tiles { display: flex; gap: 12px; flex-wrap: wrap; margin: 1rem 0; }
  .tile { background: #1f1f29; border: 1px solid #2e2e3a; border-radius: 6px;
          padding: 10px 16px; min-width: 110px; }
  .tile b { display: block; font-size: 22px; font-weight: 600; }
  .tile span { font-size: 12px; color: #9a9aa5; }
  table { border-collapse: collapse; margin: .6rem 0 1.4rem; }
  th, td { text-align: right; padding: 4px 12px; border-bottom: 1px solid #2e2e3a; }
  th { color: #9a9aa5; font-weight: 500; }
  th:first-child, td:first-child { text-align: left; }
  .bad { color: #ff8a8a; }  /* status: failed — always beside a text label */
  .ok { color: #8fd9a8; }
  details { margin: .4rem 0; }
  pre { background: #1f1f29; border: 1px solid #2e2e3a; border-radius: 6px;
        padding: 8px 12px; overflow-x: auto; font-size: 12px; color: #c9c9d4; }
  #stale { display: none; color: #ffc94d; }
</style></head>
<body>
<h1>memento fleet <span class="muted" id="asof"></span>
  <span id="stale">(stale — no updates)</span></h1>
<div class="tiles" id="tiles"></div>
<h1>hosts</h1>
<table id="hosts"><thead><tr>
  <th>host</th><th>done</th><th>failed</th><th>cached</th>
  <th>tasks/s</th><th>tok/s</th><th>accept</th><th>itl p50</th>
</tr></thead><tbody></tbody></table>
<h1>queue</h1>
<table id="queue"><thead><tr>
  <th>host</th><th>claimed</th><th>done</th>
</tr></thead><tbody></tbody></table>
<h1>perf trajectory <span class="muted" id="trajmeta"></span></h1>
<table id="traj"><thead><tr>
  <th>benchmark</th><th>trend</th><th>latest</th><th>records</th>
</tr></thead><tbody></tbody></table>
<h1>failures <span class="muted">(click to expand traceback)</span></h1>
<div id="failures" class="muted">none</div>
<script>
const fmt = (v, d=2) => v === null || v === undefined ? "-"
  : typeof v === "number" ? (Number.isInteger(v) ? v : v.toFixed(d)) : v;
const esc = s => String(s).replace(/[&<>]/g,
  c => ({"&":"&amp;","<":"&lt;",">":"&gt;"}[c]));
let lastSeen = 0, lastChange = Date.now();
function render(s) {
  document.getElementById("asof").textContent =
    "as of " + new Date(s.t * 1000).toLocaleTimeString();
  if (s.events_seen !== lastSeen) { lastSeen = s.events_seen; lastChange = Date.now(); }
  document.getElementById("stale").style.display =
    Date.now() - lastChange > 30000 ? "inline" : "none";
  const q = s.queue || {};
  const tiles = [
    ["done", `${s.done}${s.total ? "/" + s.total : ""}`],
    ["failed", s.failed, s.failed ? "bad" : ""],
    ["cached", s.cached],
    ["queue depth", q.total !== undefined ? q.total - q.done : "-"],
    ["ETA", s.eta_s !== null && s.eta_s !== undefined ? s.eta_s + "s" : "-"],
    ["running", s.running_s !== null ? s.running_s + "s" : "-"],
  ];
  document.getElementById("tiles").innerHTML = tiles.map(
    ([k, v, cls]) => `<div class="tile"><b class="${cls || ""}">${fmt(v)}</b>` +
      `<span>${k}</span></div>`).join("");
  document.querySelector("#hosts tbody").innerHTML =
    Object.entries(s.hosts).map(([h, v]) => `<tr><td>${esc(h)}</td>` +
      `<td>${v.done}</td><td class="${v.failed ? "bad" : ""}">${v.failed}</td>` +
      `<td>${v.cached}</td><td>${fmt(v.tasks_per_s)}</td>` +
      `<td>${fmt(v.tokens_per_s, 1)}</td>` +
      `<td>${fmt(v.metrics.accept_rate)}</td>` +
      `<td>${v.metrics.itl_p50_s !== undefined ?
             (v.metrics.itl_p50_s * 1000).toFixed(1) + "ms" : "-"}</td></tr>`
    ).join("") || `<tr><td class="muted">no completions yet</td></tr>`;
  const cb = q.claimed_by || {}, db = q.done_by || {};
  const qhosts = [...new Set([...Object.keys(cb), ...Object.keys(db)])].sort();
  document.querySelector("#queue tbody").innerHTML = qhosts.map(h =>
    `<tr><td>${esc(h)}</td><td>${cb[h] || 0}</td><td>${db[h] || 0}</td></tr>`
  ).join("") || `<tr><td class="muted">no queue (local run)</td></tr>`;
  document.getElementById("failures").innerHTML = s.failures.length
    ? s.failures.map(f => `<details><summary class="bad">` +
        `${esc(f.error || "failed")} — ${esc(JSON.stringify(f.params))} ` +
        `on ${esc(f.host)}</summary>` +
        `<pre>${esc(f.traceback || "(no traceback recorded)")}</pre>` +
        `</details>`).join("")
    : "none";
}
function spark(pts, w = 120, h = 24) {
  if (pts.length < 2) return `<span class="muted">-</span>`;
  const vs = pts.map(p => p.value);
  const lo = Math.min(...vs), hi = Math.max(...vs), span = hi - lo || 1;
  const xy = vs.map((v, i) =>
    `${(1 + i / (vs.length - 1) * (w - 2)).toFixed(1)},` +
    `${(h - 2 - (v - lo) / span * (h - 4)).toFixed(1)}`);
  const up = vs[vs.length - 1] >= vs[0];
  return `<svg width="${w}" height="${h}" viewBox="0 0 ${w} ${h}">` +
    `<polyline fill="none" stroke="${up ? "#8fd9a8" : "#ff8a8a"}" ` +
    `stroke-width="1.5" points="${xy.join(" ")}"/></svg>`;
}
async function loadTraj() {
  try {
    const t = await (await fetch("/api/trajectory")).json();
    const names = Object.keys(t.series);
    document.getElementById("trajmeta").textContent =
      `(${t.metric} across ${t.records.length} records)`;
    document.querySelector("#traj tbody").innerHTML = names.map(n => {
      const pts = t.series[n];
      return `<tr><td>${esc(n)}</td><td>${spark(pts)}</td>` +
        `<td>${fmt(pts[pts.length - 1].value)}</td>` +
        `<td>${pts.length}</td></tr>`;
    }).join("") || `<tr><td class="muted">no benchmark records</td></tr>`;
  } catch (e) { /* records dir optional; leave the section empty */ }
}
async function poll() {
  try { render(await (await fetch("/api/state")).json()); }
  catch (e) { document.getElementById("stale").style.display = "inline"; }
}
poll(); setInterval(poll, 1000);
loadTraj(); setInterval(loadTraj, 60000);
</script>
</body></html>
"""


class Dashboard:
    """Serve an :class:`AnalysisNotificationProvider`'s live view over HTTP.

    >>> prov = AnalysisNotificationProvider(journal_path="run.jsonl")
    >>> dash = Dashboard(prov)           # port=0 -> ephemeral
    >>> url = dash.start()               # non-blocking; daemon thread
    >>> for r in prov.track(eng.stream_distributed(matrix, queue_dir=q)): ...
    >>> dash.stop()
    """

    def __init__(
        self,
        provider: AnalysisNotificationProvider,
        host: str = "127.0.0.1",
        port: int = 0,
        records_dir: str | Path | None = None,
    ):
        self.provider = provider
        self.host = host
        self.port = port
        # Perf-records dir backing /api/trajectory (None -> the default
        # benchmarks/records, resolved against cwd at request time).
        self.records_dir = records_dir
        self._server = None
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> str:
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        provider = self.provider
        records_dir = self.records_dir

        class Handler(BaseHTTPRequestHandler):
            daemon_threads = True

            def log_message(self, *args: Any) -> None:
                pass  # dashboards must never spam the run's stderr

            def _send(self, body: bytes, ctype: str, code: int = 200) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.send_header("Cache-Control", "no-store")
                self.end_headers()
                self.wfile.write(body)

            def _json(self, obj: Any, code: int = 200) -> None:
                self._send(
                    json.dumps(obj, default=str).encode(),
                    "application/json", code,
                )

            def do_GET(self) -> None:  # noqa: N802 - http.server API
                from urllib.parse import parse_qs, urlparse

                u = urlparse(self.path)
                if u.path in ("/", "/index.html"):
                    self._send(_INDEX_HTML.encode(), "text/html; charset=utf-8")
                elif u.path == "/api/state":
                    self._json(provider.state())
                elif u.path == "/api/events":
                    q = parse_qs(u.query)
                    since = int(q.get("since", ["0"])[0] or 0)
                    cursor, events = provider.events_since(since)
                    self._json({"next": cursor, "events": events})
                elif u.path == "/api/trajectory":
                    q = parse_qs(u.query)
                    self._json(trajectory_payload(
                        records_dir,
                        metric=q.get("metric", ["tok_s"])[0] or "tok_s",
                        mode=q.get("mode", [""])[0] or None,
                        benchmark=q.get("benchmark", [""])[0] or None,
                    ))
                elif u.path == "/api/stream":
                    self.send_response(200)
                    self.send_header("Content-Type", "text/event-stream")
                    self.send_header("Cache-Control", "no-store")
                    self.end_headers()
                    try:
                        while True:
                            body = json.dumps(provider.state(), default=str)
                            self.wfile.write(f"data: {body}\n\n".encode())
                            self.wfile.flush()
                            time.sleep(1.0)
                    except (BrokenPipeError, ConnectionResetError, OSError):
                        return  # client went away; the thread just ends
                else:
                    self._json({"error": f"no route {u.path}"}, 404)

        self._server = ThreadingHTTPServer((self.host, self.port), Handler)
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="memento-dash", daemon=True
        )
        self._thread.start()
        return self.url

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None


def serve_journal(
    journal: str | Path,
    host: str = "127.0.0.1",
    port: int = 8321,
    follow: bool = True,
    poll_s: float = 0.5,
    total: int | None = None,
    records_dir: str | Path | None = None,
) -> tuple[Dashboard, AnalysisNotificationProvider]:
    """Dashboard over an existing journal file: replay what's there, then
    (with ``follow``) keep tailing it — how you watch a run owned by another
    process, or a whole fleet appending to one shared journal."""
    prov = AnalysisNotificationProvider(total=total)
    offset = prov.replay_journal(journal)
    dash = Dashboard(prov, host=host, port=port, records_dir=records_dir)
    dash.start()
    if follow:
        def tail() -> None:
            off = offset
            while True:
                time.sleep(poll_s)
                off = prov.replay_journal(journal, off)

        threading.Thread(target=tail, name="memento-dash-tail", daemon=True).start()
    return dash, prov
