"""Examiner-style metric extraction into typed, queryable records.

A :class:`MetricSpec` names one metric and says how to pull it out of a raw
source — a capture-group regex for log text, a callable or dict key for
structured rows. :class:`Examiner` applies a set of specs to the three
sources the framework produces:

* ``ResultSet`` / ``TaskResult`` iterables (sweep results; params, host and
  timing ride along from the spec/result),
* file-queue ``done/`` records (who finished what, where, how long),
* raw log/CSV text (benchmark output, training logs).

Everything lands as :class:`MetricRecord` rows inside a :class:`MetricFrame`
— a small, pandas-free frame with ``where``/``group``/``values`` queries
that :mod:`repro.analysis.tables` renders into comparison tables.
"""
from __future__ import annotations

import json
import re
import time
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Callable, Iterable, Mapping, Sequence

_NUMBER = r"[-+]?[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?"


def _as_float(v: Any) -> float | None:
    if isinstance(v, bool) or v is None:
        return None
    if isinstance(v, (int, float)):
        return float(v)
    try:
        return float(str(v))
    except (TypeError, ValueError):
        return None


@dataclass(frozen=True)
class MetricSpec:
    """How to extract one named metric.

    Exactly one extraction route applies per source kind:

    * ``pattern`` — regex with one capture group, run over text (every match
      yields a record). ``{num}`` in the pattern expands to a float regex.
    * ``extract`` — callable over a structured row (a result-value mapping or
      a done-record dict); return a number or ``None`` to skip.
    * neither — the metric name itself (or ``key``) is looked up as a dict
      key in the structured row.
    """

    name: str
    pattern: str | None = None
    extract: Callable[[Mapping[str, Any]], Any] | None = None
    key: str | None = None
    unit: str = ""

    def _regex(self) -> re.Pattern[str]:
        assert self.pattern is not None
        return re.compile(self.pattern.replace("{num}", f"({_NUMBER})"))

    def from_row(self, row: Mapping[str, Any]) -> float | None:
        if self.extract is not None:
            try:
                return _as_float(self.extract(row))
            except (KeyError, IndexError, TypeError, ZeroDivisionError):
                return None
        return _as_float(row.get(self.key or self.name))


def as_specs(
    specs: Sequence[MetricSpec | str] | Mapping[str, Any],
) -> list[MetricSpec]:
    """Normalize the convenience spellings into :class:`MetricSpec` objects.

    A plain string is a dict-key lookup of that name; a mapping maps metric
    name -> regex string (contains a capture group or ``{num}``) or callable.
    """
    out: list[MetricSpec] = []
    if isinstance(specs, Mapping):
        for name, how in specs.items():
            if callable(how):
                out.append(MetricSpec(name, extract=how))
            elif isinstance(how, str):
                out.append(MetricSpec(name, pattern=how))
            else:
                raise TypeError(f"spec for {name!r} must be a regex or callable")
        return out
    for s in specs:
        out.append(MetricSpec(s) if isinstance(s, str) else s)
    return out


@dataclass(frozen=True)
class MetricRecord:
    """One extracted observation: a metric value plus its provenance."""

    metric: str
    value: float
    params: Mapping[str, Any] = field(default_factory=dict)
    unit: str = ""
    host: str = ""
    timestamp: float | None = None
    commit: str = ""
    source: str = ""  # "result" | "done" | "text" | "csv" | "journal"

    def to_dict(self) -> dict[str, Any]:
        return {
            "metric": self.metric,
            "value": self.value,
            "params": dict(self.params),
            "unit": self.unit,
            "host": self.host,
            "timestamp": self.timestamp,
            "commit": self.commit,
            "source": self.source,
        }


class MetricFrame:
    """An ordered collection of :class:`MetricRecord` with small queries.

    Frames concatenate with ``+`` and filter with :meth:`where`; grouping for
    table rendering lives in :meth:`group`.
    """

    def __init__(self, records: Iterable[MetricRecord] = ()):
        self.records: list[MetricRecord] = list(records)

    def __iter__(self):
        return iter(self.records)

    def __len__(self) -> int:
        return len(self.records)

    def __add__(self, other: "MetricFrame") -> "MetricFrame":
        return MetricFrame(self.records + list(other))

    def __repr__(self) -> str:
        return f"MetricFrame({len(self.records)} records, metrics={self.metrics()})"

    # -- queries ------------------------------------------------------------
    def metrics(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.records:
            seen.setdefault(r.metric)
        return list(seen)

    def where(
        self,
        pred: Callable[[MetricRecord], bool] | None = None,
        metric: str | None = None,
        **params: Any,
    ) -> "MetricFrame":
        """Filter records: by metric name, by param equality, and/or by an
        arbitrary predicate — all conditions must hold."""

        def keep(r: MetricRecord) -> bool:
            if metric is not None and r.metric != metric:
                return False
            if any(r.params.get(k) != v for k, v in params.items()):
                return False
            return pred is None or bool(pred(r))

        return MetricFrame(r for r in self.records if keep(r))

    def values(self, metric: str | None = None) -> list[float]:
        return [r.value for r in self.records if metric is None or r.metric == metric]

    def param_values(self, key: str) -> list[Any]:
        """Distinct values of one param key, in first-seen order."""
        seen: dict[Any, None] = {}
        for r in self.records:
            if key in r.params:
                seen.setdefault(r.params[key])
        return list(seen)

    def group(
        self, by: Sequence[str], metric: str | None = None
    ) -> dict[tuple[Any, ...], list[float]]:
        """Group values by a tuple of param keys (``"metric"`` and ``"host"``
        are accepted as pseudo-keys), preserving first-seen group order."""
        out: dict[tuple[Any, ...], list[float]] = {}
        for r in self.records:
            if metric is not None and r.metric != metric:
                continue
            key = tuple(
                r.metric if k == "metric" else r.host if k == "host" else r.params.get(k)
                for k in by
            )
            out.setdefault(key, []).append(r.value)
        return out

    # -- IO -----------------------------------------------------------------
    def to_csv(self, path: str | Path | None = None) -> str:
        import csv
        import io

        pkeys: dict[str, None] = {}
        for r in self.records:
            for k in r.params:
                pkeys.setdefault(k)
        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        w.writerow(["metric", "value", "unit", "host", "timestamp", "commit",
                    "source", *pkeys])
        for r in self.records:
            w.writerow(
                [r.metric, r.value, r.unit, r.host,
                 "" if r.timestamp is None else r.timestamp, r.commit, r.source]
                + [r.params.get(k, "") for k in pkeys]
            )
        text = buf.getvalue()
        if path is not None:
            Path(path).write_text(text)
        return text

    @classmethod
    def from_results_csv(cls, path: str | Path) -> "MetricFrame":
        """Parse a ``ResultSet.to_csv()`` file back into a frame.

        The CSV layout is ``<param cols...>, status, attempts, wall_s,
        <value cols...>``; every numeric value column becomes a metric (plus
        ``wall_s``), keyed by the row's params. Failed rows contribute no
        value metrics but keep their ``wall_s``.
        """
        import csv

        with open(path, newline="") as f:
            reader = csv.reader(f)
            header = next(reader)
            if "status" not in header:
                raise ValueError(f"{path}: not a ResultSet.to_csv file (no status column)")
            split = header.index("status")
            pkeys = header[:split]
            vkeys = header[split + 3:]  # after status, attempts, wall_s
            records: list[MetricRecord] = []
            for row in reader:
                params: dict[str, Any] = {}
                for k, cell in zip(pkeys, row[:split]):
                    num = _as_float(cell)
                    params[k] = cell if num is None else num
                wall = _as_float(row[split + 2])
                if wall is not None:
                    records.append(MetricRecord("wall_s", wall, params=params,
                                                unit="s", source="csv"))
                if row[split] not in ("ok", "cached"):
                    continue
                for k, cell in zip(vkeys, row[split + 3:]):
                    num = _as_float(cell)
                    if num is not None:
                        records.append(
                            MetricRecord(k, num, params=params, source="csv")
                        )
        return cls(records)


class Examiner:
    """Applies a set of :class:`MetricSpec` to results, records, and text.

    >>> ex = Examiner(["tokens_per_s", MetricSpec("itl_p50_ms",
    ...               extract=lambda v: v["itl_p50_s"] * 1e3)])
    >>> frame = ex.examine_results(memento.run(matrix))
    """

    def __init__(self, specs: Sequence[MetricSpec | str] | Mapping[str, Any]):
        self.specs = as_specs(specs)

    def _row_specs(self) -> list[MetricSpec]:
        return [s for s in self.specs if s.pattern is None]

    def _text_specs(self) -> list[MetricSpec]:
        return [s for s in self.specs if s.pattern is not None]

    # -- sources ------------------------------------------------------------
    def examine_results(
        self, results: Iterable[Any], commit: str = ""
    ) -> MetricFrame:
        """Pull metrics out of ``TaskResult`` rows (a ResultSet, a live
        ``Memento.stream``, or any iterable). Failed tasks are skipped;
        params/host/timestamp come from the result."""
        records: list[MetricRecord] = []
        for r in results:
            if not getattr(r, "ok", False):
                continue
            value = r.value
            row = value if isinstance(value, Mapping) else {"value": value}
            for spec in self._row_specs():
                v = spec.from_row(row)
                if v is None:
                    continue
                records.append(
                    MetricRecord(
                        spec.name, v, params=dict(r.spec.params), unit=spec.unit,
                        host=r.host, timestamp=r.started_unix or None,
                        commit=commit, source="result",
                    )
                )
        return MetricFrame(records)

    def examine_rows(
        self,
        rows: Iterable[Mapping[str, Any]],
        params_keys: Sequence[str] = (),
        commit: str = "",
        source: str = "rows",
    ) -> MetricFrame:
        """Plain structured rows (dicts): ``params_keys`` name the entries
        that identify a row rather than measure it."""
        records: list[MetricRecord] = []
        for row in rows:
            params = {k: row.get(k) for k in params_keys if k in row}
            for spec in self._row_specs():
                v = spec.from_row(row)
                if v is not None:
                    records.append(
                        MetricRecord(spec.name, v, params=params, unit=spec.unit,
                                     commit=commit, source=source)
                    )
        return MetricFrame(records)

    def examine_text(
        self,
        text: str,
        params: Mapping[str, Any] | None = None,
        commit: str = "",
        host: str = "",
    ) -> MetricFrame:
        """Run every regex spec over raw log text; each match is a record."""
        records: list[MetricRecord] = []
        for spec in self._text_specs():
            for m in spec._regex().finditer(text):
                group = m.group(1) if m.groups() else m.group(0)
                v = _as_float(group)
                if v is not None:
                    records.append(
                        MetricRecord(spec.name, v, params=dict(params or {}),
                                     unit=spec.unit, host=host, commit=commit,
                                     source="text")
                    )
        return MetricFrame(records)

    def examine_done_dir(self, queue_dir: str | Path) -> MetricFrame:
        """File-queue ``done/`` records: per-task wall time and status by
        owning host — the fleet-level view of who ran what, how long.

        Row specs apply to each record dict (``wall_s`` and ``attempts`` are
        present on normally-finished tasks); a synthetic ``failed`` 0/1
        metric is always emitted so failure rates aggregate per host.
        """
        done = Path(queue_dir) / "done"
        records: list[MetricRecord] = []
        for p in sorted(done.glob("*.json")):
            try:
                rec = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            host = str(rec.get("owner", ""))
            ts = rec.get("finished_unix") or None
            params = {"key": rec.get("key", p.stem)}
            for spec in self._row_specs():
                v = spec.from_row(rec)
                if v is not None:
                    records.append(
                        MetricRecord(spec.name, v, params=params, unit=spec.unit,
                                     host=host, timestamp=ts, source="done")
                    )
            records.append(
                MetricRecord(
                    "failed", 0.0 if rec.get("status") == "ok" else 1.0,
                    params=params, host=host, timestamp=ts, source="done",
                )
            )
        return MetricFrame(records)


def _scalar_metrics(value: Any) -> dict[str, float]:
    """The numeric scalar entries of a result value — what travels in
    structured ``task_finished`` event payloads and the dashboard."""
    if not isinstance(value, Mapping):
        v = _as_float(value)
        return {} if v is None else {"value": v}
    out: dict[str, float] = {}
    for k, v in value.items():
        f = _as_float(v) if not isinstance(v, str) else None
        if f is not None:
            out[k] = f
    return out
