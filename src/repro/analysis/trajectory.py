"""Queryable perf trajectory over ``benchmarks/records/BENCH_<n>.json``.

``benchmarks/run.py`` persists every benchmark run as a versioned record
(rows + extracted metrics + git commit + timestamp + mode). This module is
the query/diff layer over those records:

* :class:`Trajectory` loads a records directory and answers filter/series
  questions ("B13 warm TTFT across the last 10 smoke runs"),
* :func:`find_baseline` picks the record a new run should be diffed
  against — the latest earlier record of the same mode whose git commit is
  an *ancestor* of the new run's commit (same-commit-lineage, so a record
  from a diverged branch is never the comparison point), falling back to
  plain latest-earlier-same-mode when commit lineage is unknowable,
* :func:`detect_regressions` generalizes the benchmark harness's hardcoded
  ">30% tok/s" diff into per-metric :class:`RegressionPolicy` thresholds;
  rows whose baseline has no extracted value for the metric are skipped,
  never compared against ``None``/0.

``benchmarks/run.py`` delegates its post-run diff here, so CLI verdicts
(``python -m repro.analysis regressions``) and the harness's ``WARN,...``
lines are identical by construction.
"""
from __future__ import annotations

import glob
import json
import os
import re
import subprocess
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Iterable, Mapping, Sequence

from .metrics import MetricFrame, MetricRecord

DEFAULT_RECORDS_DIR = os.path.join("benchmarks", "records")


@dataclass(frozen=True)
class BenchRecord:
    """One persisted benchmark run (``BENCH_<n>.json``)."""

    record: int
    mode: str
    commit: str
    timestamp: str
    rows: tuple[Mapping[str, Any], ...]
    path: str = ""

    @classmethod
    def load(cls, path: str | Path) -> "BenchRecord":
        with open(path) as f:
            data = json.load(f)
        return cls(
            record=int(data.get("record", 0)),
            mode=str(data.get("mode", "")),
            commit=str(data.get("git_commit", "unknown")),
            timestamp=str(data.get("timestamp", "")),
            rows=tuple(data.get("rows", ())),
            path=str(path),
        )

    def row(self, name: str) -> Mapping[str, Any] | None:
        for r in self.rows:
            if r.get("name") == name:
                return r
        return None

    def metric(self, name: str, metric: str = "tok_s") -> float | None:
        r = self.row(name)
        v = None if r is None else r.get(metric)
        return float(v) if isinstance(v, (int, float)) and not isinstance(v, bool) else None

    def names(self, metric: str | None = None) -> list[str]:
        return [
            str(r.get("name"))
            for r in self.rows
            if metric is None or isinstance(r.get(metric), (int, float))
        ]


def _git_is_ancestor(old: str, new: str, cwd: str | None = None) -> bool | None:
    """True/False when git can decide whether ``old`` is an ancestor of (or
    equal to) ``new``; None when lineage is unknowable (no git, unknown
    commits, shallow clone missing the objects)."""
    if not old or not new or "unknown" in (old, new):
        return None
    if old == new:
        return True
    try:
        out = subprocess.run(
            ["git", "merge-base", "--is-ancestor", old, new],
            cwd=cwd, capture_output=True, timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    if out.returncode == 0:
        return True
    if out.returncode == 1:
        return False
    return None  # git error: commit unknown to this clone


class Trajectory:
    """The ordered sequence of benchmark records, oldest first."""

    def __init__(self, records: Iterable[BenchRecord]):
        self.records = sorted(records, key=lambda r: r.record)

    @classmethod
    def load(cls, records_dir: str | Path | None = None) -> "Trajectory":
        d = str(records_dir or DEFAULT_RECORDS_DIR)
        records = []
        for p in glob.glob(os.path.join(d, "BENCH_*.json")):
            if re.fullmatch(r"BENCH_\d+\.json", os.path.basename(p)):
                try:
                    records.append(BenchRecord.load(p))
                except (OSError, json.JSONDecodeError, ValueError):
                    continue  # half-written or foreign file; not a record
        return cls(records)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    def modes(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.records:
            seen.setdefault(r.mode)
        return list(seen)

    def filter(
        self, mode: str | None = None, benchmark: str | None = None
    ) -> "Trajectory":
        """Restrict to one mode and/or to rows whose name starts with
        ``benchmark`` (e.g. ``"B13"``); row-filtering keeps record metadata."""
        out = []
        for r in self.records:
            if mode is not None and r.mode != mode:
                continue
            rows = r.rows
            if benchmark is not None:
                rows = tuple(
                    row for row in rows if str(row.get("name", "")).startswith(benchmark)
                )
                if not rows:
                    continue
            out.append(
                r if rows is r.rows else
                BenchRecord(r.record, r.mode, r.commit, r.timestamp, rows, r.path)
            )
        return Trajectory(out)

    def latest(self, mode: str | None = None) -> BenchRecord | None:
        for r in reversed(self.records):
            if mode is None or r.mode == mode:
                return r
        return None

    def get(self, record: int) -> BenchRecord | None:
        for r in self.records:
            if r.record == record:
                return r
        return None

    def series(self, name: str, metric: str = "tok_s") -> list[tuple[int, float]]:
        """(record number, value) for one benchmark row across all records
        that carry the metric."""
        out = []
        for r in self.records:
            v = r.metric(name, metric)
            if v is not None:
                out.append((r.record, v))
        return out

    def names(self, metric: str | None = None) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.records:
            for n in r.names(metric):
                seen.setdefault(n)
        return list(seen)

    def to_frame(self, metrics: Sequence[str] = ("tok_s",)) -> MetricFrame:
        """Flatten into a :class:`MetricFrame`: one record per (benchmark
        row, metric) with params ``{benchmark, mode, record}`` — feeds
        :func:`repro.analysis.tables.compare` directly."""
        records = []
        for rec in self.records:
            for row in rec.rows:
                name = str(row.get("name", ""))
                for m in metrics:
                    v = row.get(m)
                    if isinstance(v, (int, float)) and not isinstance(v, bool):
                        records.append(
                            MetricRecord(
                                m, float(v),
                                params={"benchmark": name, "mode": rec.mode,
                                        "record": rec.record},
                                commit=rec.commit, source="trajectory",
                            )
                        )
        return MetricFrame(records)


def find_baseline(
    trajectory: Trajectory,
    new: BenchRecord,
    is_ancestor: Callable[[str, str], bool | None] | None = None,
) -> BenchRecord | None:
    """The record ``new`` should be compared against.

    Candidates are earlier records of the same mode, newest first. When
    commit lineage is decidable, the first candidate whose commit is an
    ancestor of (or equal to) ``new``'s commit wins — a record produced on a
    diverged branch is skipped rather than used as a false baseline. When
    lineage is unknowable for every candidate (no git, "unknown" commits),
    fall back to the latest earlier same-mode record.
    """
    anc = is_ancestor or (
        lambda old, cnew: _git_is_ancestor(
            old, cnew, cwd=os.path.dirname(new.path) or None
        )
    )
    candidates = [
        r for r in reversed(trajectory.records)
        if r.mode == new.mode and r.record < new.record
    ]
    fallback: BenchRecord | None = None
    for r in candidates:
        verdict = anc(r.commit, new.commit)
        if verdict is True:
            return r
        if verdict is None and fallback is None:
            fallback = r
    # Every candidate decidably diverged (or none exist) -> no honest
    # baseline; better no diff than a diff against another branch's numbers.
    return fallback


@dataclass(frozen=True)
class RegressionPolicy:
    """Per-metric regression threshold.

    ``max_drop=0.3`` flags a >30% move in the bad direction; ``label`` is
    how the metric renders in WARN lines (kept bit-compatible with the
    historical harness output for ``tok_s``).
    """

    metric: str = "tok_s"
    max_drop: float = 0.30
    higher_is_better: bool = True
    label: str = ""

    @property
    def display(self) -> str:
        return self.label or ("tok/s" if self.metric == "tok_s" else self.metric)


DEFAULT_POLICIES: tuple[RegressionPolicy, ...] = (RegressionPolicy(),)

# Checked-in policy file: thresholds live next to the records they gate so
# a tightened bound rides the same PR as the change it protects, instead of
# drifting in CI job definitions. Repo-relative; resolved against cwd.
DEFAULT_POLICY_FILE = os.path.join("benchmarks", "policy.json")


def load_policies(path: str | Path | None = None) -> tuple[RegressionPolicy, ...]:
    """Read RegressionPolicies from a JSON policy file.

    Schema: ``{"policies": [{"metric": "tok_s", "max_drop": 0.30,
    "higher_is_better": true, "label": ""}, ...]}`` — every field optional
    with the dataclass defaults. A missing file (or ``path=None`` with no
    checked-in default) falls back to ``DEFAULT_POLICIES``; a present but
    malformed file raises, so a typo can't silently disable the gate.
    """
    p = Path(path) if path is not None else Path(DEFAULT_POLICY_FILE)
    if not p.exists():
        return DEFAULT_POLICIES
    with open(p) as fh:
        doc = json.load(fh)
    entries = doc["policies"]
    out = []
    for e in entries:
        unknown = set(e) - {"metric", "max_drop", "higher_is_better", "label"}
        if unknown:
            raise ValueError(f"{p}: unknown policy fields {sorted(unknown)}")
        out.append(RegressionPolicy(**e))
    return tuple(out) or DEFAULT_POLICIES


@dataclass(frozen=True)
class Regression:
    """One flagged row: the metric moved past the policy threshold."""

    name: str
    metric: str
    old: float
    new: float
    ratio: float
    baseline_record: int
    policy: RegressionPolicy = field(default_factory=RegressionPolicy)

    def warn_line(self) -> str:
        return (
            f"WARN,{self.name},{self.policy.display} "
            f"{self.old:.1f} -> {self.new:.1f} "
            f"({self.ratio:.2f}x vs record {self.baseline_record}, "
            f">{self.policy.max_drop * 100:.0f}% regression)"
        )


def detect_regressions(
    new: BenchRecord,
    baseline: BenchRecord | None,
    policies: Sequence[RegressionPolicy] = DEFAULT_POLICIES,
) -> list[Regression]:
    """Rows of ``new`` that regressed vs ``baseline`` under any policy.

    Rows are matched by name. A row is only comparable when *both* records
    carry an extracted value for the policy's metric and the baseline value
    is nonzero — a baseline row without the metric is skipped (no silent
    None/0 comparisons).
    """
    if baseline is None:
        return []
    out: list[Regression] = []
    for pol in policies:
        for row in new.rows:
            name = str(row.get("name", ""))
            v_new = new.metric(name, pol.metric)
            v_old = baseline.metric(name, pol.metric)
            if v_new is None or v_old is None or v_old == 0:
                continue
            ratio = v_new / v_old
            bad = ratio < (1.0 - pol.max_drop) if pol.higher_is_better \
                else ratio > (1.0 + pol.max_drop)
            if bad:
                out.append(
                    Regression(
                        name=name, metric=pol.metric, old=v_old, new=v_new,
                        ratio=ratio, baseline_record=baseline.record, policy=pol,
                    )
                )
    return out


def diff_latest(
    records_dir: str | Path | None = None,
    record: int | None = None,
    policies: Sequence[RegressionPolicy] = DEFAULT_POLICIES,
    is_ancestor: Callable[[str, str], bool | None] | None = None,
) -> tuple[BenchRecord | None, BenchRecord | None, list[Regression]]:
    """Load a records dir and diff one record (default: the latest) against
    its lineage baseline. Returns (record, baseline, regressions)."""
    traj = Trajectory.load(records_dir)
    new = traj.latest() if record is None else traj.get(record)
    if new is None:
        return None, None, []
    base = find_baseline(traj, new, is_ancestor=is_ancestor)
    return new, base, detect_regressions(new, base, policies)
