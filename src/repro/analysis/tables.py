"""Grouped comparison tables over sweep results.

``compare(frame, rows=..., cols=..., agg=..., baseline=...)`` replaces the
ad-hoc ``ResultSet.pivot`` dance for benchmark and sweep analysis: group a
:class:`~repro.analysis.metrics.MetricFrame` by param axes, aggregate each
cell explicitly (mean/median/p95/...), and render markdown or CSV — with
delta/ratio columns against a named baseline column for A/B sweeps.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from dataclasses import replace as _dc_replace
from typing import Any, Callable, Mapping, Sequence

from .metrics import MetricFrame


def _percentile(values: list[float], q: float) -> float:
    if not values:
        raise ValueError("no values")
    vs = sorted(values)
    idx = (len(vs) - 1) * q
    lo, hi = int(idx), min(int(idx) + 1, len(vs) - 1)
    return vs[lo] + (vs[hi] - vs[lo]) * (idx - lo)


AGGREGATORS: dict[str, Callable[[list[float]], float]] = {
    "mean": statistics.fmean,
    "median": statistics.median,
    "min": min,
    "max": max,
    "sum": sum,
    "count": len,
    "first": lambda vs: vs[0],
    "last": lambda vs: vs[-1],
    "p50": lambda vs: _percentile(vs, 0.50),
    "p90": lambda vs: _percentile(vs, 0.90),
    "p95": lambda vs: _percentile(vs, 0.95),
    "p99": lambda vs: _percentile(vs, 0.99),
}


def resolve_agg(agg: str | Callable[[list[float]], float]) -> Callable[[list[float]], float]:
    if callable(agg):
        return agg
    try:
        return AGGREGATORS[agg]
    except KeyError:
        raise ValueError(
            f"unknown agg {agg!r}; one of {sorted(AGGREGATORS)} or a callable"
        ) from None


def _fmt_value(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _label(v: Any) -> str:
    return getattr(v, "__name__", None) or str(v)


@dataclass
class Table:
    """A rendered-agnostic grid: row label tuples x column labels.

    ``cells[i][j]`` is the aggregated value (None for empty cells). When a
    ``baseline`` column is set, the non-baseline columns carry
    ``delta/ratio`` annotations against it in every renderer.
    """

    row_keys: list[str]
    col_labels: list[Any]
    row_labels: list[tuple[Any, ...]]
    cells: list[list[float | None]]
    baseline: Any = None
    title: str = ""
    fmt: Callable[[Any], str] = field(default=_fmt_value)

    def _baseline_index(self) -> int | None:
        if self.baseline is None:
            return None
        for j, c in enumerate(self.col_labels):
            if c == self.baseline:
                return j
        raise ValueError(
            f"baseline {self.baseline!r} is not a column: {self.col_labels}"
        )

    def _annotate(self, v: float | None, base: float | None) -> str:
        cell = self.fmt(v)
        if v is None or base is None or base == 0:
            return cell
        ratio = v / base
        delta = (ratio - 1.0) * 100.0
        return f"{cell} ({ratio:.2f}x, {delta:+.1f}%)"

    def _grid(self) -> tuple[list[str], list[list[str]]]:
        """Headers + stringified body shared by every renderer."""
        bj = self._baseline_index()
        headers = list(self.row_keys)
        for j, c in enumerate(self.col_labels):
            name = _label(c)
            if bj is not None and j != bj:
                name += f" (vs {_label(self.col_labels[bj])})"
            headers.append(name)
        body: list[list[str]] = []
        for labels, row in zip(self.row_labels, self.cells):
            line = [_label(v) for v in labels]
            for j, v in enumerate(row):
                if bj is None or j == bj:
                    line.append(self.fmt(v))
                else:
                    line.append(self._annotate(v, row[bj]))
            body.append(line)
        return headers, body

    def to_markdown(self) -> str:
        headers, body = self._grid()
        lines = []
        if self.title:
            lines.append(f"### {self.title}")
            lines.append("")
        lines.append("| " + " | ".join(headers) + " |")
        lines.append("|" + "|".join(" --- " for _ in headers) + "|")
        for line in body:
            lines.append("| " + " | ".join(line) + " |")
        return "\n".join(lines)

    def to_csv(self) -> str:
        import csv
        import io

        headers, body = self._grid()
        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        w.writerow(headers)
        w.writerows(body)
        return buf.getvalue()

    def __str__(self) -> str:
        headers, body = self._grid()
        widths = [
            max(len(line[i]) for line in [headers] + body)
            for i in range(len(headers))
        ]
        out = []
        if self.title:
            out.append(self.title)
        out.append("  ".join(h.rjust(w) for h, w in zip(headers, widths)))
        for line in body:
            out.append("  ".join(c.rjust(w) for c, w in zip(line, widths)))
        return "\n".join(out)


def compare(
    frame: MetricFrame,
    rows: str | Sequence[str],
    cols: str | Sequence[str] | None = None,
    metric: str | None = None,
    agg: str | Callable[[list[float]], float] = "mean",
    baseline: Any = None,
    title: str = "",
    fmt: Callable[[Any], str] | None = None,
) -> Table:
    """Build a grouped comparison table from a metric frame.

    ``rows``/``cols`` are param keys (``"metric"`` and ``"host"`` work as
    pseudo-keys); with ``cols=None`` the columns are the frame's metric
    names. Every cell aggregates all records landing in it with ``agg``
    (explicit — no silent last-wins). ``baseline`` names one column label;
    the other columns then render as ``value (ratio x, delta %)`` against it.

    >>> compare(frame, rows="arch", cols="n_slots", metric="tokens_per_s",
    ...         agg="median", baseline=2)
    """
    row_keys = [rows] if isinstance(rows, str) else list(rows)
    if not row_keys:
        raise ValueError("rows must name at least one key")
    agg_fn = resolve_agg(agg)

    if cols is None:
        metric_names = frame.metrics() if metric is None else [metric]
        col_of = lambda r: r.metric  # noqa: E731
        col_labels_all = metric_names
        sel = frame.where(metric=metric) if metric is not None else frame
    else:
        col_keys = [cols] if isinstance(cols, str) else list(cols)
        if metric is None:
            names = frame.metrics()
            if len(names) != 1:
                raise ValueError(
                    f"frame has metrics {names}; pass metric=... to pick one"
                )
            metric = names[0]
        sel = frame.where(metric=metric)

        def col_of(r):
            vals = tuple(
                r.host if k == "host" else r.params.get(k) for k in col_keys
            )
            return vals[0] if len(vals) == 1 else vals

        col_labels_all = None  # discovered in frame order

    def row_of(r):
        return tuple(
            r.metric if k == "metric" else r.host if k == "host" else r.params.get(k)
            for k in row_keys
        )

    row_labels: list[tuple[Any, ...]] = []
    col_labels: list[Any] = list(col_labels_all or [])
    cells: dict[tuple[int, int], list[float]] = {}

    def index(labels: list[Any], v: Any) -> int:
        for i, existing in enumerate(labels):
            if existing is v or existing == v:
                return i
        labels.append(v)
        return len(labels) - 1

    for r in sel:
        i = index(row_labels, row_of(r))
        c = col_of(r)
        if col_labels_all is not None and c not in col_labels:
            continue
        j = index(col_labels, c)
        cells.setdefault((i, j), []).append(r.value)

    grid: list[list[float | None]] = [
        [agg_fn(cells[i, j]) if (i, j) in cells else None
         for j in range(len(col_labels))]
        for i in range(len(row_labels))
    ]
    return Table(
        row_keys=row_keys,
        col_labels=col_labels,
        row_labels=row_labels,
        cells=grid,
        baseline=baseline,
        title=title,
        fmt=fmt or _fmt_value,
    )


_FIRST = object()  # compare_frames default: baseline is the first frame


def compare_frames(
    frames: Mapping[Any, MetricFrame] | Sequence[tuple[Any, MetricFrame]],
    rows: str | Sequence[str],
    metric: str | None = None,
    agg: str | Callable[[list[float]], float] = "mean",
    baseline: Any = _FIRST,
    title: str = "",
    fmt: Callable[[Any], str] | None = None,
) -> Table:
    """Diff two or more frames: one column per run, annotated vs the first.

    The cross-run counterpart of :func:`compare`: each frame (a benchmark
    record, a sweep re-run, an A/B candidate) becomes one column, cells are
    matched row-wise by the ``rows`` keys, and every non-baseline column
    renders as ``value (ratio x, delta %)`` against the baseline run — the
    first frame unless ``baseline`` names another label. A run with no
    records landing in a row renders ``-`` there rather than dropping the
    column, so a benchmark missing from one run stays visible.

    Records are tagged with a ``run`` pseudo-param carrying the frame's
    label (shadowing any pre-existing ``run`` param).

    >>> compare_frames({"record 12": old, "record 13": new},
    ...                rows="benchmark", metric="tok_s")
    """
    pairs = list(frames.items()) if isinstance(frames, Mapping) else list(frames)
    if len(pairs) < 2:
        raise ValueError("compare_frames needs at least two frames")
    labels = [label for label, _ in pairs]
    if len({str(lb) for lb in labels}) != len(labels):
        raise ValueError(f"frame labels must be distinct: {labels}")
    combined = MetricFrame(
        _dc_replace(r, params={**r.params, "run": label})
        for label, f in pairs
        for r in f
    )
    table = compare(
        combined, rows=rows, cols="run", metric=metric, agg=agg,
        baseline=labels[0] if baseline is _FIRST else baseline,
        title=title, fmt=fmt,
    )
    # A run whose frame carried no matching records still gets its (empty)
    # column: "this run didn't measure that" must not read as "all equal".
    for label in labels:
        if label not in table.col_labels:
            table.col_labels.append(label)
            for row in table.cells:
                row.append(None)
    return table
