"""repro.analysis — the Examiner-style analytics layer over Memento results.

Results used to dead-end at ``ResultSet.pivot()/to_csv()``; this package is
the insight layer the related work converges on (mlrunner's Examiner,
MLXP's result queries, NSML's live monitoring):

* :mod:`repro.analysis.metrics` — declarative metric extraction: pull named
  metrics out of ``ResultSet`` rows, file-queue ``done/`` records, and raw
  log text via regex/callable :class:`MetricSpec`\\ s, normalized into typed
  :class:`MetricFrame` records (metric, value, params, host, timestamp,
  commit).
* :mod:`repro.analysis.tables` — grouped comparison tables over sweep
  results: ``compare(frame, rows=..., cols=..., agg=..., baseline=...)``
  with delta/ratio columns and markdown/CSV renderers, plus
  ``compare_frames`` for cross-run A/B diffs (one column per run).
* :mod:`repro.analysis.trajectory` — a queryable store over the versioned
  ``benchmarks/records/BENCH_<n>.json`` perf records: filter by
  mode/benchmark, extract series across records, and detect regressions
  against the same-commit-lineage baseline with per-metric thresholds.
* :mod:`repro.analysis.dash` — a stdlib-only live dashboard
  (:class:`Dashboard`, ``http.server`` + JSON/SSE endpoints) fed by
  :class:`AnalysisNotificationProvider`, which tees ``Memento.stream`` /
  ``queue_progress`` events into a JSONL journal and live aggregates
  (per-host throughput, queue depth, ETA, failure drill-down with real
  tracebacks).

CLI: ``python -m repro.analysis {table,trajectory,regressions,dash}``.
"""
from .dash import AnalysisNotificationProvider, Dashboard
from .metrics import Examiner, MetricFrame, MetricRecord, MetricSpec
from .tables import Table, compare, compare_frames
from .trajectory import (
    BenchRecord,
    Regression,
    RegressionPolicy,
    Trajectory,
    detect_regressions,
)

__all__ = [
    "AnalysisNotificationProvider",
    "BenchRecord",
    "Dashboard",
    "Examiner",
    "MetricFrame",
    "MetricRecord",
    "MetricSpec",
    "Regression",
    "RegressionPolicy",
    "Table",
    "Trajectory",
    "compare",
    "compare_frames",
    "detect_regressions",
]
