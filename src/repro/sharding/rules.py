"""Logical-axis sharding rules.

Params and activations carry *logical* axis names ("embed", "heads",
"batch", ...). A :class:`ShardingProfile` maps each logical axis to an
ordered tuple of *candidate* mesh axes. At resolution time we take, per
tensor dimension, the longest prefix of candidate axes that (a) exist in the
mesh, (b) are not already used by another dimension of the same tensor, and
(c) whose combined size divides the dimension — so a 24-head attention simply
falls back to replicated heads instead of producing an invalid or padded
sharding. This divisibility-driven fallback is what lets one rule set cover
all ten assigned architectures.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Mapping[str, tuple[str, ...]]


@dataclass(frozen=True)
class ShardingProfile:
    name: str
    rules: dict[str, tuple[str, ...]]
    zero1: bool = True  # shard grad-accum + optimizer/master state over unused axes
    fsdp_params: bool = False  # keep compute weights master-sharded; XLA
    #                            all-gathers them layer-by-layer inside the scan
    description: str = ""

    def candidates(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return tuple(self.rules.get(logical, ()))


def _norm(axes: Any) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


# --------------------------------------------------------------------------
# Profile registry
# --------------------------------------------------------------------------
_BATCH = ("pod", "data")
_MODEL = ("model",)

PROFILES: dict[str, ShardingProfile] = {}


def register_profile(p: ShardingProfile) -> ShardingProfile:
    PROFILES[p.name] = p
    return p


register_profile(
    ShardingProfile(
        name="dp_tp",
        description=(
            "Paper-faithful baseline: data parallel over (pod, data), Megatron "
            "tensor parallel over model, ZeRO-1 optimizer sharding. Params "
            "replicated across the data axis."
        ),
        rules={
            "batch": _BATCH,
            "seq": (),
            "embed": (),  # weights replicated over data (pure DP)
            "embed_act": (),
            "vocab": _MODEL,
            "heads": _MODEL,
            "kv_heads": _MODEL,
            "head_dim": (),
            "qkv": _MODEL,
            "mlp": _MODEL,
            "expert": _MODEL,
            "expert_mlp": (),
            "q_lora": _MODEL,
            "kv_lora": (),
            "rnn": _MODEL,
            "conv": (),
            "state_row": (),
            "state_col": _MODEL,
            "kv_seq": _MODEL,  # decode KV cache seq dim when kv_heads can't split
            "window": (),
            "layer": (),
            "frames": (),
            "pages": (),  # paged-KV pool page axis (training: replicated)
        },
        zero1=True,
    )
)

register_profile(
    ShardingProfile(
        name="dp_tp_sp",
        description=(
            "dp_tp + Megatron sequence parallelism: residual-stream "
            "activations seq-sharded over model, so per-layer TP all-reduces "
            "legalise into reduce-scatter + all-gather (half the ICI bytes) "
            "and norms/elementwise run 1/16th-sized."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "seq": ("model",),
        },
        zero1=True,
    )
)

register_profile(
    ShardingProfile(
        name="dp_wide",
        description=(
            "Small models: batch sharded over (data, model) so every chip has "
            "work without tensor parallelism; weights replicated; optimizer "
            "state ZeRO-sharded. seq over pod when multi-pod."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "batch": ("data", "model"),
            "seq": ("pod",),
            "vocab": (),
            "heads": (),
            "kv_heads": (),
            "mlp": (),
            "expert": ("model",),
            "rnn": (),
            "state_col": (),
            "q_lora": (),
        },
        zero1=True,
    )
)

register_profile(
    ShardingProfile(
        name="fsdp_tp",
        description=(
            "Optimized: ZeRO-3 weight sharding over the data axis on the embed "
            "dim + tensor parallel over model. XLA all-gathers weights "
            "layer-by-layer (overlapped with the layer scan)."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "embed": ("data",),
            "kv_lora": ("data",),
            "expert_mlp": (),
        },
        zero1=True,
        fsdp_params=True,
    )
)

register_profile(
    ShardingProfile(
        name="fsdp_wide",
        description=(
            "For >=100B dense models: batch sharded over (data, model) so "
            "per-chip activations stay small; weights ZeRO-3 sharded over "
            "(data,) and (model,) on separate dims; seq over pod when multi-pod."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "batch": ("data", "model"),
            "seq": ("pod",),
            "embed": ("data",),
            "vocab": _MODEL,
            "heads": (),  # attention runs data-parallel; weights gathered
            "kv_heads": (),
            "mlp": _MODEL,
            "expert": _MODEL,
            "q_lora": (),
            "kv_seq": (),
        },
        zero1=True,
        fsdp_params=True,
    )
)

register_profile(
    ShardingProfile(
        name="fsdp_pure",
        description=(
            "Mid-size models (8-20B): NO tensor parallelism — batch sharded "
            "over (data x model) 256-way, weights/optimizer ZeRO-sharded over "
            "data with per-layer bf16 gathers. Eliminates the per-layer "
            "Megatron activation all-reduces entirely; per-step collective "
            "volume = one weight gather + one gradient reduction."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "batch": ("data", "model"),
            "seq": ("pod",),
            "embed": ("data",),
            "vocab": ("model",),
            "heads": (),
            "kv_heads": (),
            "mlp": (),
            "expert": ("model",),
            "q_lora": (),
            "rnn": (),
            "state_col": (),
        },
        zero1=True,
        fsdp_params=True,
    )
)

register_profile(
    ShardingProfile(
        name="decode_default",
        description="Decode: batch over (pod,data); KV seq or kv_heads over model.",
        rules={
            **PROFILES["dp_tp"].rules,
            "batch": _BATCH,
            "kv_seq": _MODEL,
            "state_col": _MODEL,
            "window": (),
            # Paged-KV pool pages partition over data when divisible
            # (PageLayout sizes one trash page per shard); pspec_for's
            # divisibility fallback replicates otherwise.
            "pages": ("data",),
        },
        zero1=False,
    )
)

register_profile(
    ShardingProfile(
        name="decode_big",
        description=(
            ">=100B serving: weights additionally sharded over data on the "
            "embed dim (gathered layer-by-layer), batch over (pod, data), "
            "KV seq over model."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "batch": _BATCH,
            "embed": ("data",),
            "kv_lora": ("data",),
            "kv_seq": _MODEL,
            "state_col": _MODEL,
            "pages": ("data",),
        },
        zero1=False,
        fsdp_params=True,
    )
)

register_profile(
    ShardingProfile(
        name="decode_long",
        description=(
            "batch=1 long-context decode: shard recurrent state matrices and "
            "window caches over (data, model) instead of batch."
        ),
        rules={
            **PROFILES["dp_tp"].rules,
            "batch": (),
            "embed": ("data",),
            "rnn": _MODEL,
            "state_row": ("data",),
            "state_col": _MODEL,
            "window": ("data",),
            "kv_seq": _MODEL,
        },
        zero1=False,
    )
)


def get_profile(name: str) -> ShardingProfile:
    if name not in PROFILES:
        raise KeyError(f"unknown sharding profile {name!r}; have {sorted(PROFILES)}")
    return PROFILES[name]


# --------------------------------------------------------------------------
# Resolution
# --------------------------------------------------------------------------
def pspec_for(
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    profile: ShardingProfile,
    mesh: Mesh,
) -> P:
    """Resolve logical axes -> PartitionSpec with divisibility fallbacks."""
    if len(shape) != len(logical_axes):
        raise ValueError(f"shape {shape} vs logical axes {logical_axes} length mismatch")
    used: set[str] = set()
    out: list[Any] = []
    for dim, logical in zip(shape, logical_axes):
        assigned: list[str] = []
        size = 1
        for axis in profile.candidates(logical):
            if axis not in mesh.shape or axis in used or mesh.shape[axis] == 1:
                continue
            nxt = size * mesh.shape[axis]
            if dim % nxt != 0:
                continue
            assigned.append(axis)
            size = nxt
        used.update(assigned)
        if not assigned:
            out.append(None)
        elif len(assigned) == 1:
            out.append(assigned[0])
        else:
            out.append(tuple(assigned))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    shape: Sequence[int],
    logical_axes: Sequence[str | None],
    profile: ShardingProfile,
    mesh: Mesh,
) -> NamedSharding:
    return NamedSharding(mesh, pspec_for(shape, logical_axes, profile, mesh))


def constrain(x: jax.Array, logical_axes: Sequence[str | None], ctx: "ShardingCtx") -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if ctx is None or ctx.mesh is None:
        return x
    spec = pspec_for(x.shape, logical_axes, ctx.profile, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


@dataclass
class ShardingCtx:
    """Everything the model code needs to place tensors: mesh + profile.

    ``pool_data_shards`` is serving-only metadata: the number of data
    shards the paged-KV pool is *actually* partitioned into (set by the
    scheduler when the data axis divides both n_slots and n_pages, 1
    otherwise). Divisibility of the pool leaf alone cannot distinguish a
    truly partitioned pool (per-shard sub-pools with shard-local page
    ids) from a replicated one that happens to divide, and shard_map'd
    kernels must localize page ids only in the former case.
    """

    mesh: Mesh | None
    profile: ShardingProfile
    pool_data_shards: int = 1

    @classmethod
    def null(cls) -> "ShardingCtx":
        return cls(mesh=None, profile=get_profile("dp_tp"))

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.shape:
            return 1
        return self.mesh.shape[name]

    def spec(self, shape: Sequence[int], logical_axes: Sequence[str | None]) -> P:
        if self.mesh is None:
            return P()
        return pspec_for(shape, logical_axes, self.profile, self.mesh)

    def named(
        self, shape: Sequence[int], logical_axes: Sequence[str | None]
    ) -> NamedSharding | None:
        """Resolved NamedSharding for one leaf (None without a mesh)."""
        if self.mesh is None:
            return None
        return named_sharding(shape, logical_axes, self.profile, self.mesh)

    def replicated(self) -> NamedSharding | None:
        """Fully-replicated placement for host-produced scalars/tables
        (page tables, token columns, masks) so every device sees the same
        values without per-call resharding. None without a mesh."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P())

    def device_count(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for s in self.mesh.shape.values():
            n *= int(s)
        return n

    def local_size(self, n: int, logical: str) -> int:
        """Per-shard extent of a dim of size ``n`` carrying ``logical`` axes
        (with the same divisibility fallbacks as pspec_for)."""
        if self.mesh is None:
            return n
        size = 1
        for axis in self.profile.candidates(logical):
            if axis not in self.mesh.shape:
                continue
            nxt = size * self.mesh.shape[axis]
            if n % nxt != 0:
                break
            size = nxt
        return n // size
