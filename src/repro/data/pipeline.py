"""Deterministic, shardable synthetic LM data pipeline with prefetch.

At cluster scale the pipeline contract matters more than the source: every
(step, host) pair must map to a unique, reproducible slice of the stream so
restarts resume exactly and no two data shards overlap. The synthetic source
here (a seeded markov-ish token stream) honours that contract; swapping in a
real tokenized corpus only replaces ``_tokens_for_block``.

Key properties:
  * stateless indexing: batch ``i`` is a pure function of (seed, i) — the
    checkpointed step counter is the only data-state to persist;
  * host sharding: each data-parallel host materialises only its rows;
  * background prefetch: a daemon thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32768
    # structured-synthetic knobs: repetition makes the LM loss actually fall,
    # which the train-loop tests assert.
    period: int = 31
    noise: float = 0.1


class SyntheticLM:
    """Deterministic synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _tokens_for_block(self, block_idx: np.ndarray, length: int) -> np.ndarray:
        """(N,) block indices -> (N, length+1) token rows, deterministic.

        Structure: one GLOBAL stride (so the bigram next = cur + stride is
        learnable — the train-loop tests assert the loss falls) with per-row
        offsets and per-row noise keyed by block index => stateless."""
        cfg = self.cfg
        n = block_idx.shape[0]
        g0 = np.random.Generator(np.random.Philox(key=cfg.seed, counter=0))
        stride = int(g0.integers(1, cfg.period))
        out = np.empty((n, length + 1), dtype=np.int32)
        for r, b in enumerate(block_idx):
            g = np.random.Generator(np.random.Philox(key=cfg.seed + 1, counter=int(b)))
            base = (np.arange(length + 1) * stride + int(g.integers(0, cfg.vocab_size))) % cfg.vocab_size
            noise_mask = g.random(length + 1) < cfg.noise
            noise = g.integers(0, cfg.vocab_size, size=length + 1)
            out[r] = np.where(noise_mask, noise, base)
        return out

    def batch(
        self, step: int, global_batch: int, seq_len: int,
        host_index: int = 0, host_count: int = 1,
    ) -> dict[str, np.ndarray]:
        """The host-local slice of global batch ``step``."""
        assert global_batch % host_count == 0
        rows_per_host = global_batch // host_count
        row0 = step * global_batch + host_index * rows_per_host
        blocks = np.arange(row0, row0 + rows_per_host, dtype=np.int64)
        toks = self._tokens_for_block(blocks, seq_len)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class Prefetcher:
    """Backgrounds ``pipeline.batch`` calls; yields in step order."""

    def __init__(self, fetch, start_step: int = 0, prefetch: int = 2):
        self._fetch = fetch
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            try:
                item = self._fetch(step)
            except Exception as e:  # surface in the consumer
                self._q.put(("error", e))
                return
            self._q.put(("ok", (step, item)))
            step += 1

    def __iter__(self) -> Iterator[tuple[int, Any]]:
        return self

    def __next__(self) -> tuple[int, Any]:
        kind, payload = self._q.get()
        if kind == "error":
            raise payload
        return payload

    def close(self) -> None:
        self._stop.set()
        # drain so the producer unblocks
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_batch_fn(
    cfg: ModelConfig, shape: ShapeConfig, data_cfg: DataConfig | None = None
):
    """Step -> full model batch dict (incl. stub modality inputs)."""
    data_cfg = data_cfg or DataConfig(vocab_size=cfg.vocab_size)
    src = SyntheticLM(data_cfg)
    tok_len = shape.seq_len - cfg.prefix_len if cfg.prefix_len else shape.seq_len

    def fetch(step: int) -> dict[str, jnp.ndarray]:
        raw = src.batch(step, shape.global_batch, tok_len)
        batch: dict[str, Any] = {
            "tokens": jnp.asarray(raw["tokens"]),
            "labels": jnp.asarray(raw["labels"]),
        }
        if cfg.prefix_len:
            key = jax.random.PRNGKey(data_cfg.seed * 1000003 + step)
            batch["prefix_embeds"] = jax.random.normal(
                key, (shape.global_batch, cfg.prefix_len, cfg.d_model), jnp.float32
            ) * 0.02
        if cfg.enc_dec:
            key = jax.random.PRNGKey(data_cfg.seed * 2000003 + step)
            batch["enc_embeds"] = jax.random.normal(
                key, (shape.global_batch, cfg.enc_seq, cfg.d_model), jnp.float32
            ) * 0.02
        return batch

    return fetch
