"""Model / shape / run configuration dataclasses.

Every assigned architecture is a ``ModelConfig`` instance in its own module
under ``repro/configs``; shapes are the four assignment-wide ``ShapeConfig``s.
Configs are hashable by Memento (dataclasses canonicalise), so a (arch x
shape x mesh x profile) cell is a well-defined task identity.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any

# Block kinds understood by the model assembler (repro/models/blocks.py).
BLOCK_KINDS = (
    "attn_mlp",  # global attention + dense FFN
    "attn_moe",  # global attention + mixture-of-experts FFN
    "local_attn",  # sliding-window attention + dense FFN
    "rglru",  # RG-LRU recurrent block + dense FFN (Griffin / RecurrentGemma)
    "mlstm",  # xLSTM matrix-memory block (self-contained, no extra FFN)
    "slstm",  # xLSTM scalar-memory block (self-contained GLU FFN inside)
    "cross_attn_mlp",  # decoder block with self-attn + cross-attn + FFN (enc-dec)
)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 2.0
    aux_coef: float = 1e-3


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention dims."""

    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    block_pattern: tuple[str, ...] = ("attn_mlp",)
    first_blocks: tuple[str, ...] = ()  # unscanned prefix blocks (e.g. DSv2 dense layer 0)
    attn_kind: str = "gqa"  # gqa | mla
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    tie_embeddings: bool = False
    window_size: int = 0  # sliding window for local_attn blocks
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500  # post-conv-stub frame count
    # vlm / prefix-lm (paligemma)
    prefix_len: int = 0
    prefix_lm: bool = False
    # recurrent dims
    d_rnn: int = 0
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 128
    # distribution defaults (overridable per run)
    sharding_profile: str = "dp_tp"
    train_profile: str = ""  # optional override for train/prefill shapes
    decode_profile: str = ""  # optional override for decode shapes
    train_microbatches: int = 8
    remat: str = "full"  # full | none
    attn_backend: str = "xla"  # xla (chunked-softmax) | pallas (flash kernel)
    attn_q_chunk: int = 512  # query-block size for XLA chunked attention
    xent_chunk: int = 1024  # seq-block size for chunked cross-entropy
    # provenance
    source: str = ""

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return int(math.ceil(self.vocab_size / m) * m)

    @property
    def n_pattern_groups(self) -> int:
        body = self.n_layers - len(self.first_blocks)
        if body % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: {body} body layers not divisible by pattern "
                f"{self.block_pattern}"
            )
        return body // len(self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True when decode state does not grow linearly with an unbounded
        full-attention KV cache (SSM / hybrid with windowed attention)."""
        kinds = set(self.block_pattern) | set(self.first_blocks)
        return not (kinds & {"attn_mlp", "attn_moe", "cross_attn_mlp"})

    def validate(self) -> "ModelConfig":
        for k in self.block_pattern + self.first_blocks:
            if k not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {k!r}")
        _ = self.n_pattern_groups
        if any(k == "attn_moe" for k in self.block_pattern) and self.moe is None:
            raise ValueError(f"{self.name}: MoE blocks but no MoEConfig")
        if self.attn_kind == "mla" and self.mla is None:
            raise ValueError(f"{self.name}: MLA attention but no MLAConfig")
        if "local_attn" in self.block_pattern and self.window_size <= 0:
            raise ValueError(f"{self.name}: local_attn blocks need window_size")
        return self

    # -- smoke-scale copy ----------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        pat_len = len(self.block_pattern)
        n_first = len(self.first_blocks)
        moe = (
            replace(self.moe, n_experts=min(self.moe.n_experts, 4), top_k=min(self.moe.top_k, 2), d_ff_expert=64)
            if self.moe
            else None
        )
        mla = (
            MLAConfig(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16, v_dim=16)
            if self.mla
            else None
        )
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_first + 2 * pat_len,
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            d_rnn=64 if self.d_rnn else 0,
            vocab_size=512,
            vocab_pad_multiple=8,
            window_size=min(self.window_size, 32) if self.window_size else 0,
            enc_seq=16 if self.enc_dec else self.enc_seq,
            prefix_len=8 if self.prefix_len else 0,
            moe=moe,
            mla=mla,
            train_microbatches=1,
            attn_q_chunk=16,
            xent_chunk=32,
            max_activated_params=0,
            # CPU smoke tests execute for real; this container's CPU backend
            # cannot dispatch bf16xbf16->f32 batched dots, so smoke configs
            # compute in f32. Full configs stay bf16 (TPU target; dry-run
            # only lowers/compiles, never dispatches).
            compute_dtype="float32",
        )

    # Rough parameter count for roofline MODEL_FLOPS = 6 N D.
    max_activated_params: int = 0  # optional explicit override (MoE active params)


@dataclass(frozen=True)
class ShapeConfig:
    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment rules: which (arch x shape) cells are lowered."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k dense KV decode skipped per assignment"
    return True, ""
