"""qwen3-8b — 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.

[hf:Qwen/Qwen3-8B; hf] Distinctives: per-head q/k RMSNorm (qk_norm),
no QKV bias (Qwen3 dropped it), RoPE theta 1M.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    act="silu",
    sharding_profile="dp_tp",  # paper-faithful baseline profile
    train_profile="fsdp_pure",  # SSPerf hillclimb: 110.5s -> 5.0s t_coll
    train_microbatches=1,
    source="hf:Qwen/Qwen3-8B",
)
