"""xlstm-1.3b — 48 blocks d_model=2048 4H, sLSTM + mLSTM mix, d_ff=0,
vocab 50304.

[arXiv:2405.04517; unverified] xLSTM[7:1]: each scanned group is 7 mLSTM
blocks + 1 sLSTM block (48 = 6 groups x 8). d_ff=0 — the blocks' own
up/down projections (proj factor 2 mLSTM, 4/3 GLU in sLSTM) carry the FFN
capacity. mLSTM matrix memory: 4 heads x (512 x 512) per block.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    conv_width=4,
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    act="gelu",
    sharding_profile="dp_wide",
    train_microbatches=8,
    source="arXiv:2405.04517 (xLSTM-1.3B)",
)
