"""mistral-large-123b — 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified] The memory stress
test of the assignment: 123B dense params. Uses the fsdp_wide profile for
train/prefill (batch sharded over (data, model), weights ZeRO-3) so
per-chip activations and optimizer state fit a 16 GB v5e.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1000000.0,
    act="silu",
    sharding_profile="fsdp_wide",
    train_microbatches=1,  # batch already 256-way sharded -> B_local == 1
    train_profile="fsdp_wide",
    decode_profile="decode_big",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)
