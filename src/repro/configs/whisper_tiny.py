"""whisper-tiny — 4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865.

[arXiv:2212.04356; unverified] Encoder-decoder. The conv frontend is a
STUB per the assignment: input_specs() feeds precomputed frame embeddings
(B, 1500, 384). Vocab padded 51865 -> 51968 for clean sharding. RoPE is
used in place of Whisper's sinusoidal/learned positions (TPU adaptation,
noted in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    enc_dec=True,
    enc_seq=1500,
    block_pattern=("cross_attn_mlp",),
    act="gelu",
    sharding_profile="dp_wide",
    train_microbatches=4,
    source="arXiv:2212.04356 (whisper-tiny)",
)
