"""qwen2.5-14b — 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

[hf:Qwen/Qwen2.5 family; hf] Distinctives: QKV bias, GQA kv=8.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    act="silu",
    sharding_profile="dp_tp",  # paper-faithful baseline profile
    train_profile="fsdp_pure",  # SSPerf hillclimb: 110.5s -> 5.0s t_coll
    train_microbatches=1,
    source="hf:Qwen/Qwen2.5-14B",
)
