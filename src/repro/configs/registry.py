"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib
from typing import Callable

from .base import ModelConfig

_ARCH_MODULES = {
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES.keys())


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.CONFIG.validate()
