"""recurrentgemma-2b — 26 blocks d_model=2560, RG-LRU + local attention 1:2,
MQA (10H, kv=1, head_dim 256), d_ff=7680, vocab 256000, window 2048.

[arXiv:2402.19427; hf] Griffin pattern (rec, rec, attn) repeated; the two
leading blocks are unscanned so 26 = 2 + 8x3. O(1) recurrent state + a
2048-slot ring-buffer KV cache make the 500k-token decode cell runnable.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    d_rnn=2560,
    conv_width=4,
    window_size=2048,
    first_blocks=("rglru", "rglru"),
    block_pattern=("rglru", "rglru", "local_attn"),
    act="gelu",
    tie_embeddings=True,
    sharding_profile="dp_tp",
    decode_profile="decode_default",
    train_microbatches=8,
    source="arXiv:2402.19427 / hf:google/recurrentgemma-2b",
)
