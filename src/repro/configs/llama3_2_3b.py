"""llama3.2-3b — 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-1B family; unverified] Small Llama-3: RoPE
theta 500k, SwiGLU, RMSNorm, untied embeddings at 3B.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    act="silu",
    sharding_profile="dp_tp",
    train_microbatches=8,
    source="hf:meta-llama/Llama-3.2-3B (assignment)",
)
