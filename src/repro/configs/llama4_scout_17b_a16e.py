"""llama4-scout-17b-a16e — 48L d_model=5120 40H (GQA kv=8) d_ff=8192,
MoE 16 experts top-1 + 1 shared expert, vocab 202048.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] Every layer MoE with a
shared expert riding the same reduction (early-fusion multimodal parts are
out of assignment scope — text backbone only).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn_moe",),
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_ff_expert=8192,
                  capacity_factor=2.0, aux_coef=1e-3),
    rope_theta=500000.0,
    act="silu",
    sharding_profile="fsdp_tp",
    decode_profile="decode_big",
    train_microbatches=8,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
