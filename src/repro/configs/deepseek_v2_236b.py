"""deepseek-v2-236b — 60L d_model=5120 128H MLA (kv_lora=512) vocab=102400,
MoE 2 shared + 160 routed top-6, expert d_ff 1536; layer 0 dense d_ff 12288.

[arXiv:2405.04434; hf] MLA: q_lora 1536, kv_lora 512 + shared 64-dim rope
key; decode caches only the 576-dim compressed latent per token per layer.
Expert parallelism over the model axis (160/16 = 10 experts per rank).
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,  # dense layer-0 FFN width
    vocab_size=102400,
    attn_kind="mla",
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64, nope_dim=128, v_dim=128),
    first_blocks=("attn_mlp",),
    block_pattern=("attn_moe",),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536,
                  capacity_factor=2.0, aux_coef=1e-3),
    rope_theta=10000.0,
    act="silu",
    sharding_profile="fsdp_tp",
    decode_profile="decode_big",
    train_microbatches=8,
    source="arXiv:2405.04434 / hf:deepseek-ai/DeepSeek-V2",
)
