"""paligemma-3b — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

[arXiv:2407.07726; hf] SigLIP vision frontend is a STUB per the
assignment: input_specs() feeds 256 patch embeddings (B, 256, 2048)
already projected. Gemma-2b-style backbone: MQA, gated-GELU FFN,
prefix-LM masking over the image+prefix tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    prefix_len=256,
    prefix_lm=True,
    act="gelu",
    tie_embeddings=True,  # gemma ties embeddings
    sharding_profile="dp_tp",
    train_microbatches=8,
    source="arXiv:2407.07726 / hf:google/paligemma-3b",
)
