"""Jit'd public wrappers around the Pallas kernels.

* ``flash_attention`` — differentiable (custom_vjp over the fwd/bwd kernels),
  accepts model-layout (B, S, H, D) tensors with GQA broadcast, folds heads
  into the grid dim.
* ``decode_attention_op`` — model-layout decode step.
* ``rglru_op`` / ``mlstm_op`` — recurrence wrappers.
* ``moe_gmm_op`` — grouped matmul with block padding.

``interpret`` defaults to True off-TPU (this container validates kernels on
CPU via the Pallas interpreter); on a TPU backend the same code compiles to
Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import mlstm as _ml
from . import moe_gmm as _gmm
from . import rglru as _rg


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ==========================================================================
# Flash attention (differentiable)
# ==========================================================================
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, blk_q, blk_k):
    o, _ = _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=default_interpret(),
    )
    return o


def _flash_fwd(q, k, v, causal, window, blk_q, blk_k):
    o, lse = _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=default_interpret(),
    )
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, blk_q, blk_k, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _fa.flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, interpret=default_interpret(),
    )
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *, causal: bool = True, window: int = 0, blk_q: int = 128, blk_k: int = 128,
) -> jax.Array:
    """Model-layout flash attention with GQA broadcast. Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, G, D)).reshape(B, S, H, D)
        v = jnp.broadcast_to(v[:, :, :, None, :], (B, S, KV, G, D)).reshape(B, S, H, D)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    o = _flash(fold(q), fold(k), fold(v), causal, window, blk_q, blk_k)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)


# ==========================================================================
# Decode attention
# ==========================================================================
def decode_attention_op(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, T, KV, D)
    v_cache: jax.Array,
    k_pos: jax.Array,  # (T,)
    cur_pos: jax.Array,
    *, window: int = 0, blk_k: int = 256,
) -> jax.Array:
    B, _, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * KV, T, D)
    o = _dec.decode_attention(
        qf, kf, vf, k_pos, cur_pos, window=window, blk_k=blk_k,
        interpret=default_interpret(),
    )
    return o.reshape(B, KV, G, D).reshape(B, 1, H, D)


def paged_decode_attention_op(
    q: jax.Array,  # (B, 1, H, D)
    k_pool: jax.Array,  # (P+1, page, KV, D) shared page pool
    v_pool: jax.Array,
    page_table: jax.Array,  # (B, max_pages) int32
    cur_pos: jax.Array,  # (B,) int32
    *, n_lp: int, window: int = 0,
) -> jax.Array:
    """Model-layout paged decode: KV blocks gathered via the page table."""
    B, _, H, D = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    qf = q.reshape(B, H, D).reshape(B, KV, G, D)
    o = _dec.paged_decode_attention(
        qf, k_pool, v_pool, page_table, cur_pos, n_lp=n_lp, window=window,
        interpret=default_interpret(),
    )
    return o.reshape(B, 1, H, D)


def paged_chunk_attention_op(
    q: jax.Array,  # (B, C, H, D) chunk queries
    k_pool: jax.Array,  # (P+1, page, KV, D) shared page pool (chunk K/V written)
    v_pool: jax.Array,
    page_table: jax.Array,  # (B, max_pages) int32
    start: jax.Array,  # (B,) int32: tokens cached before the chunk
    *, n_lp: int,
) -> jax.Array:
    """Model-layout chunked-prefill attention over the paged KV (dense
    layers). The chunk's own K/V must already be scattered into the pool."""
    B, C, H, D = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    # Row order c*G + g per kv-head: (B, C, KV, G, D) -> (B, KV, C*G, D).
    qf = q.reshape(B, C, KV, G, D).transpose(0, 2, 1, 3, 4).reshape(B, KV, C * G, D)
    o = _dec.paged_chunk_attention(
        qf, k_pool, v_pool, page_table, start, n_lp=n_lp, group=G,
        interpret=default_interpret(),
    )
    return o.reshape(B, KV, C, G, D).transpose(0, 2, 1, 3, 4).reshape(B, C, H, D)


# --------------------------------------------------------------------------
# Paged kernels under a mesh: per-shard shard_map wrappers
# --------------------------------------------------------------------------
# GSPMD cannot partition a pallas_call, so under a multi-device mesh the
# paged kernels run inside shard_map: each shard calls the single-device op
# on its local q rows / head slice / pool slice. The caller (the attention
# layer) resolves the PartitionSpecs from the actual operand shapes and
# mesh; ``localize_pages`` is set only when the pool is truly partitioned
# across data shards (host page ids are then global — shard d owns rows
# [d * rows_local, (d + 1) * rows_local) of the pool, each block ending in
# its own trash row — so the local table is ``global - d * rows_local``).
def _localized(page_table: jax.Array, pool_rows_local: int) -> jax.Array:
    d = jax.lax.axis_index("data").astype(jnp.int32)
    return page_table - d * pool_rows_local


def paged_decode_attention_sharded(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    cur_pos: jax.Array,
    *,
    n_lp: int,
    window: int = 0,
    mesh,
    q_spec,
    pool_spec,
    table_spec,
    vec_spec,
    localize_pages: bool = False,
) -> jax.Array:
    """``paged_decode_attention_op`` run per-shard under ``mesh``."""
    from repro.compat import shard_map

    rows_local = k_pool.shape[0] // (
        mesh.shape["data"] if localize_pages else 1
    )

    def body(qs, ks, vs, pt, pos):
        if localize_pages:
            pt = _localized(pt, rows_local)
        return paged_decode_attention_op(
            qs, ks, vs, pt, pos, n_lp=n_lp, window=window
        )

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, pool_spec, pool_spec, table_spec, vec_spec),
        out_specs=q_spec,
        check=False,
    )(q, k_pool, v_pool, page_table, cur_pos)


def paged_chunk_attention_sharded(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    page_table: jax.Array,
    start: jax.Array,
    *,
    n_lp: int,
    mesh,
    q_spec,
    pool_spec,
    table_spec,
    vec_spec,
) -> jax.Array:
    """``paged_chunk_attention_op`` run per-shard under ``mesh``. Chunks
    are single-slot (B == 1), so only the head/model axis partitions —
    the caller falls back to the XLA gather path when the pool is
    data-partitioned."""
    from repro.compat import shard_map

    def body(qs, ks, vs, pt, st):
        return paged_chunk_attention_op(qs, ks, vs, pt, st, n_lp=n_lp)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, pool_spec, pool_spec, table_spec, vec_spec),
        out_specs=q_spec,
        check=False,
    )(q, k_pool, v_pool, page_table, start)


# ==========================================================================
# Recurrences
# ==========================================================================
def rglru_op(a: jax.Array, b: jax.Array, h0: jax.Array | None = None, **kw) -> jax.Array:
    return _rg.rglru_scan_kernel(a, b, h0, interpret=default_interpret(), **kw)


def mlstm_op(
    q: jax.Array,  # (B, S, nh, dh) NOT pre-scaled
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (B, S, nh)
    f_pre: jax.Array,
    *, chunk: int = 64,
) -> jax.Array:
    B, S, nh, dh = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * nh, S, dh)
    foldg = lambda x: x.transpose(0, 2, 1).reshape(B * nh, S)
    h = _ml.mlstm_chunk_kernel(
        fold(q), fold(k), fold(v), foldg(i_pre), foldg(f_pre),
        chunk=chunk, interpret=default_interpret(),
    )
    return h.reshape(B, nh, S, dh).transpose(0, 2, 1, 3)


# ==========================================================================
# MoE grouped matmul
# ==========================================================================
def moe_gmm_op(
    lhs: jax.Array,  # (M, K), rows sorted by group, boundaries % blk_m == 0
    rhs: jax.Array,  # (G, K, N)
    group_sizes: jax.Array,  # (G,) multiples of blk_m summing to M
    *, blk_m: int = 128, blk_n: int = 128,
) -> jax.Array:
    M = lhs.shape[0]
    gm = _gmm.pad_group_sizes_to_blocks(group_sizes, blk_m, M)
    return _gmm.gmm(lhs, rhs, gm, blk_m=blk_m, blk_n=blk_n, interpret=default_interpret())
