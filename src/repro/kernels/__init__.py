"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
wrapped in ops.py (jit'd public API, custom_vjp where differentiable) and
asserted against ref.py (pure-jnp oracles) across shape/dtype sweeps in
tests/test_kernels.py. interpret=True on CPU; Mosaic on TPU.
"""
