"""Flash attention (fwd + bwd) as Pallas TPU kernels.

TPU adaptation of the FlashAttention-2 schedule [arXiv:2307.08691]:
  * no warps/shared-memory — tiles are BlockSpec VMEM blocks, the MXU sees
    (blk_q x d) @ (d x blk_k) contractions, and the online-softmax running
    (m, l, acc) state lives in VMEM scratch carried across the sequential
    innermost grid dimension (TPU grids execute minor-to-major in order,
    which replaces the GPU's explicit k-loop inside one program).
  * Q/K/V layout: (B*H, S, D) — heads are folded into the grid's major dim,
    so one program instance owns one (batch, head) pair.
  * causal/window masking is positional (jnp.where), with whole-block skips
    expressed via ``pl.when`` on the block indices.
  * blk_q/blk_k default to 128 (MXU-aligned); D is the full head dim.

Backward follows FA-2: LSE saved from fwd; one kernel computes dQ (k-inner
loop), a second computes dK/dV (q-inner loop). delta = rowsum(dO * O) is
computed outside in jnp (cheap, bandwidth-bound).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
F32 = jnp.float32


def _mask(qi, ki, blk_q, blk_k, causal, window, q_offset):
    """(blk_q, blk_k) boolean validity for this tile."""
    q_pos = q_offset + qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    m = jnp.ones((blk_q, blk_k), jnp.bool_)
    if causal:
        m = m & (k_pos <= q_pos)
    if window:
        m = m & (k_pos > q_pos - window)
    return m


# ==========================================================================
# Forward
# ==========================================================================
def _fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
    *, sm_scale, causal, window, blk_q, blk_k, n_k, q_offset,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Whole-tile skip for causal/window structure.
    q_hi = q_offset + (qi + 1) * blk_q - 1  # highest query position in tile
    k_lo = ki * blk_k
    run = k_lo <= q_hi if causal else True
    if window:
        k_hi = (ki + 1) * blk_k - 1
        q_lo = q_offset + qi * blk_q
        run = jnp.logical_and(run, k_hi > q_lo - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(F32)  # (blk_q, D)
        k = k_ref[0].astype(F32)  # (blk_k, D)
        v = v_ref[0].astype(F32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32
        ) * sm_scale  # (blk_q, blk_k)
        msk = _mask(qi, ki, blk_q, blk_k, causal, window, q_offset)
        s = jnp.where(msk, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
        )
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l)).astype(lse_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Sk, D)
    v: jax.Array,  # (BH, Sk, D)
    *, causal: bool = True, window: int = 0, sm_scale: float | None = None,
    blk_q: int = 128, blk_k: int = 128, q_offset: int = 0,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)
    sm = sm_scale if sm_scale is not None else D ** -0.5
    n_q, n_k = Sq // blk_q, Sk // blk_k

    kernel = functools.partial(
        _fwd_kernel, sm_scale=sm, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_k=n_k, q_offset=q_offset,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q, D), F32),
            pltpu.VMEM((blk_q,), F32),
            pltpu.VMEM((blk_q,), F32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ==========================================================================
# Backward: dQ kernel (loop over K blocks), dK/dV kernel (loop over Q blocks)
# ==========================================================================
def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, acc_ref,
    *, sm_scale, causal, window, blk_q, blk_k, n_k, q_offset,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_hi = q_offset + (qi + 1) * blk_q - 1
    run = ki * blk_k <= q_hi if causal else True
    if window:
        run = jnp.logical_and(run, (ki + 1) * blk_k - 1 > q_offset + qi * blk_q - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        v = v_ref[0].astype(F32)
        do = do_ref[0].astype(F32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * sm_scale
        msk = _mask(qi, ki, blk_q, blk_k, causal, window, q_offset)
        p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())), preferred_element_type=F32)
        ds = p * (dp - delta[:, None]) * sm_scale
        acc_ref[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(ki == n_k - 1)
    def _flush():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, sm_scale, causal, window, blk_q, blk_k, n_q, q_offset,
):
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_hi = q_offset + (qi + 1) * blk_q - 1
    run = ki * blk_k <= q_hi if causal else True
    if window:
        run = jnp.logical_and(run, (ki + 1) * blk_k - 1 > q_offset + qi * blk_q - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        v = v_ref[0].astype(F32)
        do = do_ref[0].astype(F32)
        lse = lse_ref[0]
        delta = delta_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * sm_scale
        msk = _mask(qi, ki, blk_q, blk_k, causal, window, q_offset)
        p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)  # (blk_q, blk_k)
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())), preferred_element_type=F32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())), preferred_element_type=F32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())), preferred_element_type=F32)

    @pl.when(qi == n_q - 1)
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q, k, v, o, lse, do,
    *, causal=True, window=0, sm_scale=None, blk_q=128, blk_k=128,
    q_offset=0, interpret=None,
):
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    sm = sm_scale if sm_scale is not None else D ** -0.5
    n_q, n_k = Sq // blk_q, Sk // blk_k
    delta = jnp.sum(do.astype(F32) * o.astype(F32), axis=-1)  # (BH, Sq)

    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, sm_scale=sm, causal=causal, window=window,
            blk_q=blk_q, blk_k=blk_k, n_k=n_k, q_offset=q_offset,
        ),
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((blk_q, D), F32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, sm_scale=sm, causal=causal, window=window,
            blk_q=blk_q, blk_k=blk_k, n_q=n_q, q_offset=q_offset,
        ),
        grid=(BH, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, D), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk_q, D), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, blk_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, blk_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_k, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((BH, Sk, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((blk_k, D), F32), pltpu.VMEM((blk_k, D), F32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
