"""Chunkwise mLSTM (xLSTM matrix-memory) Pallas kernel.

One program instance owns one (batch, head); the chunk index is the
sequential innermost grid dimension carrying the stabilised state
(C: dh x dh, n: dh, m: 1) in VMEM scratch. Within a chunk of length L the
math is the parallel form (the same as repro.models.recurrent.mlstm_chunked,
the oracle): intra-chunk (L x L) score matmuls hit the MXU; the inter-chunk
contributions use the carried state. All state math is fp32.

Inputs are per-head: q/k/v (BH, S, dh) (q pre-scaled by dh^-0.5), gate
pre-activations i/f (BH, S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _mlstm_kernel(
    q_ref, k_ref, v_ref, i_ref, f_ref, h_ref,
    C_ref, n_ref, m_ref,
    *, L,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0].astype(F32)  # (L, dh)
    k = k_ref[0].astype(F32)
    v = v_ref[0].astype(F32)
    a = i_ref[0].astype(F32)  # (L,) log input gate
    g = -jax.nn.softplus(-f_ref[0].astype(F32))  # (L,) log sigmoid(f)

    C = C_ref[...]
    n = n_ref[...]
    m = m_ref[0]

    b = jnp.cumsum(g)  # (L,)
    btot = b[L - 1]

    # Per-position stabiliser.
    intra_carry = a - b
    run_max = jax.lax.cummax(intra_carry, axis=0)
    m_state = b + m
    m_out = jnp.maximum(m_state, b + run_max)  # (L,)

    # Intra-chunk attention-like term.
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32)
    logD = b[:, None] + (a - b)[None, :] - m_out[:, None]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    D = jnp.where(s_idx <= t_idx, jnp.exp(logD), 0.0)
    wS = scores * D
    intra_num = jax.lax.dot_general(wS, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    intra_den = jnp.sum(wS, axis=1)  # (L,)

    # Inter-chunk (state) term.
    sdec = jnp.exp(m_state - m_out)  # (L,)
    qC = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    inter_num = qC * sdec[:, None]
    inter_den = (q @ n.reshape(-1, 1))[:, 0] * sdec

    num = intra_num + inter_num
    den = inter_den + intra_den
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
    h_ref[0] = (num / denom[:, None]).astype(h_ref.dtype)

    # State update to chunk end.
    m_a = jnp.max(a + btot - b)
    m_new = jnp.maximum(m + btot, m_a)
    state_scale = jnp.exp(m + btot - m_new)
    in_w = jnp.exp(a + btot - b - m_new)  # (L,)
    C_ref[...] = C * state_scale + jax.lax.dot_general(
        k * in_w[:, None], v, (((0,), (0,)), ((), ())), preferred_element_type=F32
    )
    n_ref[...] = n * state_scale + jnp.sum(k * in_w[:, None], axis=0)
    m_ref[0] = m_new


def mlstm_chunk_kernel(
    q: jax.Array,  # (BH, S, dh), pre-scaled
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (BH, S)
    f_pre: jax.Array,  # (BH, S)
    *, chunk: int = 64, interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    BH, S, dh = q.shape
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    n_chunks = S // L

    return pl.pallas_call(
        functools.partial(_mlstm_kernel, L=L),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, L, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
            pl.BlockSpec((1, L), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, L, dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), F32),
            pltpu.VMEM((dh,), F32),
            pltpu.VMEM((1,), F32),
        ],
        interpret=interpret,
    )(q, k, v, i_pre, f_pre)
