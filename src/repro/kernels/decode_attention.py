"""Flash-decoding split-K attention kernel (one new token vs a long cache).

GPU flash-decoding [arXiv:2311.01282] splits the KV length across SMs and
combines partials in a second pass. The TPU adaptation runs the KV blocks as
the sequential innermost grid dimension with the running (m, l, acc) state
in VMEM scratch — the combine is the carry, no second pass needed; split-K
ACROSS chips comes from sharding the cache seq dim over the mesh (the
decode_default profile), whose partial-softmax combine XLA handles.

Layout: q (BKV, G, D) — one program per (batch, kv-head); G = query heads
per kv head ride the sublane dim. k/v: (BKV, T, D). Validity is positional:
slots with k_pos > cur_pos (or outside the window ring) are masked, so the
same kernel serves dense caches and ring buffers.

``paged_decode_attention`` is the paged-serving variant: KV lives in a
shared page pool (P+1, page, KV, D) and each slot's blocks are gathered
through its page-table row, passed as a scalar-prefetch operand so the
BlockSpec index map DMAs physical pages directly — no gathered copy of
the cache is ever materialised. Logical slot validity is computed
in-kernel from the page index, so partially-filled tail pages and
ring-folded windows need no extra inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
F32 = jnp.float32


def _decode_kernel(
    q_ref, k_ref, v_ref, kpos_ref, curpos_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale, window, blk_k, n_k,
):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(F32)  # (G, D)
    k = k_ref[0].astype(F32)  # (blk_k, D)
    v = v_ref[0].astype(F32)
    k_pos = kpos_ref[...]  # (blk_k,)
    cur = curpos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * sm_scale
    valid = (k_pos <= cur) & (k_pos >= 0)
    if window:
        valid = valid & (k_pos > cur - window)
    s = jnp.where(valid[None, :], s, NEG_INF)  # (G, blk_k)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )
    m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # (BKV, G, D)
    k: jax.Array,  # (BKV, T, D)
    v: jax.Array,  # (BKV, T, D)
    k_pos: jax.Array,  # (T,) int32 positions held by each slot
    cur_pos: jax.Array,  # scalar int32
    *, window: int = 0, sm_scale: float | None = None, blk_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    BKV, G, D = q.shape
    T = k.shape[1]
    blk_k = min(blk_k, T)
    assert T % blk_k == 0, (T, blk_k)
    n_k = T // blk_k
    sm = sm_scale if sm_scale is not None else D ** -0.5

    return pl.pallas_call(
        functools.partial(
            _decode_kernel, sm_scale=sm, window=window, blk_k=blk_k, n_k=n_k
        ),
        grid=(BKV, n_k),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((blk_k,), lambda b, j: (j,)),
            pl.BlockSpec((1,), lambda b, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), F32),
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G,), F32),
        ],
        interpret=interpret,
    )(q, k, v, k_pos, cur_pos[None].astype(jnp.int32))


def _paged_decode_kernel(
    pt_ref, cp_ref,  # scalar prefetch: (B, MP) page table, (B,) cur positions
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale, window, page, n_lp,
):
    b = pl.program_id(0)
    j = pl.program_id(2)  # logical page (innermost: sequential accumulation)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(F32)  # (G, D)
    k = k_ref[0, :, 0, :].astype(F32)  # (page, D)
    v = v_ref[0, :, 0, :].astype(F32)
    cur = cp_ref[b]

    # Positional validity from the logical slot index alone: dense slots hold
    # position s; ring slots s < window hold the latest p <= cur with
    # p % window == s (negative -> never written). Tail-page slots past the
    # write head and trash-page blocks fall out as invalid automatically.
    s_idx = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)[0]
    if window:
        k_pos = cur - ((cur - s_idx) % window)
        k_pos = jnp.where(s_idx < window, k_pos, -1)
    else:
        k_pos = s_idx
    valid = (k_pos >= 0) & (k_pos <= cur)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * sm_scale
    s = jnp.where(valid[None, :], s, NEG_INF)  # (G, page)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )
    m_ref[...] = m_cur

    @pl.when(j == n_lp - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_chunk_kernel(
    pt_ref, start_ref,  # scalar prefetch: (B, MP) page table, (B,) chunk starts
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale, page, n_lp, G,
):
    b = pl.program_id(0)
    j = pl.program_id(2)  # logical page (innermost: sequential accumulation)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(F32)  # (C*G, D): query row r = c*G + g
    k = k_ref[0, :, 0, :].astype(F32)  # (page, D)
    v = v_ref[0, :, 0, :].astype(F32)
    start = start_ref[b]
    CG = q.shape[0]

    # Dense chunked prefill: logical slot s holds position s; query row r is
    # chunk token c = r // G at absolute position start + c. Trash-backed
    # table entries and the chunk's own padded tail sit at k_pos > q_pos and
    # mask out — the kernel needs no extra validity inputs.
    k_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (CG, page), 1)
    q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (CG, page), 0) // G
    valid = k_pos <= q_pos  # (CG, page)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32) * sm_scale
    s = jnp.where(valid, s, NEG_INF)  # (CG, page)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )
    m_ref[...] = m_cur

    @pl.when(j == n_lp - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_chunk_attention(
    q: jax.Array,  # (B, KV, C*G, D) chunk queries, row r = c*G + g
    k_pool: jax.Array,  # (P+1, page, KV, D) shared pool incl. trash page
    v_pool: jax.Array,
    page_table: jax.Array,  # (B, max_pages) int32
    start: jax.Array,  # (B,) int32: tokens cached before the chunk
    *, n_lp: int, group: int, sm_scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunked-prefill flash attention over page-table-gathered KV blocks.

    The dense-layer companion of :func:`paged_decode_attention` for C > 1
    query tokens: the chunk's K/V are scattered into the pool *before* the
    call, then every chunk token attends to the already-paged prefix plus
    its chunk predecessors through the same scalar-prefetched page table —
    per-(token, slot) causal validity is computed in-kernel from the page
    index and the chunk start, so the kernel never materialises a gathered
    cache copy or a mask input.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    B, KV, CG, D = q.shape
    page = k_pool.shape[1]
    sm = sm_scale if sm_scale is not None else D ** -0.5
    assert n_lp <= page_table.shape[1], (n_lp, page_table.shape)
    assert CG % group == 0, (CG, group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_lp),
        in_specs=[
            pl.BlockSpec((1, 1, CG, D), lambda b, h, j, pt, st: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, j, pt, st: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, j, pt, st: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, CG, D), lambda b, h, j, pt, st: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((CG, D), F32),
            pltpu.VMEM((CG,), F32),
            pltpu.VMEM((CG,), F32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_chunk_kernel, sm_scale=sm, page=page, n_lp=n_lp, G=group
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, CG, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start.astype(jnp.int32), q, k_pool, v_pool)


def paged_decode_attention(
    q: jax.Array,  # (B, KV, G, D)
    k_pool: jax.Array,  # (P+1, page, KV, D) shared pool incl. trash page
    v_pool: jax.Array,
    page_table: jax.Array,  # (B, max_pages) int32 physical page per logical page
    cur_pos: jax.Array,  # (B,) int32 position of each slot's query token
    *, n_lp: int, window: int = 0, sm_scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash decode over page-table-gathered KV blocks.

    One program per (slot, kv-head, logical page); the page table rides as a
    scalar-prefetch operand so the k/v BlockSpecs DMA physical page
    ``page_table[b, j]`` for grid step ``(b, h, j)``. ``n_lp`` bounds the
    logical pages attended — ``ceil(window / page)`` for ring-folded
    windowed layers (a bounded working set), the full table width for dense.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    B, KV, G, D = q.shape
    page = k_pool.shape[1]
    sm = sm_scale if sm_scale is not None else D ** -0.5
    assert n_lp <= page_table.shape[1], (n_lp, page_table.shape)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_lp),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, pt, cp: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, j, pt, cp: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D), lambda b, h, j, pt, cp: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, pt, cp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), F32),
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G,), F32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_decode_kernel, sm_scale=sm, window=window, page=page, n_lp=n_lp
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), cur_pos.astype(jnp.int32), q, k_pool, v_pool)
