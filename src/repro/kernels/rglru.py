"""Blocked RG-LRU linear-recurrence kernel.

GPU implementations use warp-level scans; TPU has no warp shuffle, so the
adaptation is a *blocked sequential* scan: the grid is (B, D/blk_d, T/blk_t)
with the time dimension innermost (sequential on TPU), the carry h
(blk_d lanes) living in VMEM scratch across time blocks, and an unrolled
elementwise FMA loop inside each (blk_t, blk_d) tile. Lanes (d) are the
vector dimension — the VPU processes 8x128 vregs per step; there is no
cross-lane dependency, so the only serialization is over time, exactly the
recurrence's data dependency.

Computes h_t = a_t * h_{t-1} + b_t given precomputed (a, b); the gate math
(sigmoids, softplus) stays in XLA where it fuses with the projections.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hcarry, *, blk_t, unroll):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        hcarry[...] = h0_ref[0].astype(F32)

    a = a_ref[0].astype(F32)  # (blk_t, blk_d)
    b = b_ref[0].astype(F32)
    h = hcarry[...]  # (blk_d,)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, blk_t, step, h, unroll=unroll)
    hcarry[...] = h


def rglru_scan_kernel(
    a: jax.Array,  # (B, T, D) decay in (0,1)
    b: jax.Array,  # (B, T, D) gated input
    h0: jax.Array | None = None,  # (B, D) initial state
    *, blk_t: int = 256, blk_d: int = 256, unroll: int = 8,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    B, T, D = a.shape
    blk_t = min(blk_t, T)
    blk_d = min(blk_d, D)
    assert T % blk_t == 0 and D % blk_d == 0, (T, blk_t, D, blk_d)
    if h0 is None:
        h0 = jnp.zeros((B, D), F32)

    return pl.pallas_call(
        functools.partial(_rglru_kernel, blk_t=blk_t, unroll=unroll),
        grid=(B, D // blk_d, T // blk_t),
        in_specs=[
            pl.BlockSpec((1, blk_t, blk_d), lambda b_, d, t: (b_, t, d)),
            pl.BlockSpec((1, blk_t, blk_d), lambda b_, d, t: (b_, t, d)),
            pl.BlockSpec((1, blk_d), lambda b_, d, t: (b_, d)),
        ],
        out_specs=pl.BlockSpec((1, blk_t, blk_d), lambda b_, d, t: (b_, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((blk_d,), F32)],
        interpret=interpret,
    )(a, b, h0)
