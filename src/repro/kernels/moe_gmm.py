"""Grouped matmul (MoE expert FFN) Pallas kernel — megablocks, TPU-style.

GPU megablocks [arXiv:2211.15841] builds CSR block-sparse GEMMs; the TPU
adaptation exploits that our dispatcher (repro/models/moe.py) delivers rows
SORTED by expert. With group boundaries pre-padded to blk_m multiples, every
(m-block, n-block) tile belongs to exactly ONE expert, so the kernel is a
dense tiled matmul whose rhs block index is data-dependent: a scalar-prefetch
array maps m-block -> group id and drives the rhs BlockSpec index_map
(PrefetchScalarGridSpec — the TPU analogue of megablocks' row indices).

lhs (M, K) @ rhs[group_of_block] (K, N) -> out (M, N), fp32 accumulation,
K is kept whole per tile (d_model/d_ff sized — fits VMEM alongside the
blk_m x blk_n accumulator).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _gmm_kernel(group_map_ref, lhs_ref, rhs_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        lhs_ref[...].astype(jnp.float32),
        rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=F32,
    ).astype(out_ref.dtype)


def gmm(
    lhs: jax.Array,  # (M, K) rows sorted by group; group boundaries % blk_m == 0
    rhs: jax.Array,  # (G, K, N)
    group_map: jax.Array,  # (M // blk_m,) int32: m-block -> group id
    *, blk_m: int = 128, blk_n: int = 128, interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        from repro.kernels.ops import default_interpret

        interpret = default_interpret()
    M, K = lhs.shape
    G, K2, N = rhs.shape
    assert K == K2, (K, K2)
    blk_m = min(blk_m, M)
    blk_n = min(blk_n, N)
    assert M % blk_m == 0 and N % blk_n == 0, (M, blk_m, N, blk_n)
    assert group_map.shape == (M // blk_m,), group_map.shape

    grid = (M // blk_m, N // blk_n)
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((blk_m, K), lambda i, j, gm: (i, 0)),
                pl.BlockSpec((1, K, blk_n), lambda i, j, gm: (gm[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, gm: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
        interpret=interpret,
    )(group_map, lhs, rhs)


def pad_group_sizes_to_blocks(group_sizes: jax.Array, blk_m: int, cap: int):
    """Host-side helper (static shapes): given per-group row counts that are
    already multiples of blk_m, produce the m-block -> group map."""
    starts = jnp.cumsum(group_sizes) - group_sizes
    blocks = jnp.arange(cap // blk_m) * blk_m
    # group of a block = number of groups whose start <= block offset, minus 1
    gm = jnp.sum(blocks[:, None] >= starts[None, :], axis=1) - 1
    return jnp.clip(gm, 0, group_sizes.shape[0] - 1).astype(jnp.int32)
