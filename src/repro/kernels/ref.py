"""Pure-jnp oracles for every kernel in this package.

These are the ground truth the interpret-mode kernels are asserted against
(tests/test_kernels.py sweeps shapes and dtypes). They are deliberately
naive — full softmax, step-by-step recurrences, per-group python loops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


# -- attention ----------------------------------------------------------------
def sdpa_ref(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Sk, D)
    v: jax.Array,
    *, causal: bool = True, window: int = 0, sm_scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    sm = sm_scale if sm_scale is not None else D ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(F32), k.astype(F32)) * sm
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m = m & (k_pos <= q_pos)
    if window:
        m = m & (k_pos > q_pos - window)
    s = jnp.where(m[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(F32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (BKV, G, D)
    k: jax.Array,  # (BKV, T, D)
    v: jax.Array,
    k_pos: jax.Array,  # (T,)
    cur_pos: jax.Array,
    *, window: int = 0, sm_scale: float | None = None,
) -> jax.Array:
    D = q.shape[-1]
    sm = sm_scale if sm_scale is not None else D ** -0.5
    s = jnp.einsum("bgd,btd->bgt", q.astype(F32), k.astype(F32)) * sm
    valid = (k_pos <= cur_pos) & (k_pos >= 0)
    if window:
        valid = valid & (k_pos > cur_pos - window)
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgt,btd->bgd", p, v.astype(F32)).astype(q.dtype)


# -- RG-LRU ---------------------------------------------------------------
def rglru_ref(a: jax.Array, b: jax.Array, h0: jax.Array | None = None) -> jax.Array:
    """Sequential h_t = a_t h_{t-1} + b_t. a/b: (B, T, D)."""
    B, T, D = a.shape
    h = jnp.zeros((B, D), F32) if h0 is None else h0.astype(F32)

    def step(h, t):
        h = a[:, t].astype(F32) * h + b[:, t].astype(F32)
        return h, h

    _, hs = jax.lax.scan(step, h, jnp.arange(T))
    return hs.swapaxes(0, 1).astype(a.dtype)


# -- mLSTM ---------------------------------------------------------------
def mlstm_ref(
    q: jax.Array,  # (BH, S, dh) pre-scaled
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,  # (BH, S)
    f_pre: jax.Array,
) -> jax.Array:
    BH, S, dh = q.shape

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt = q[:, t].astype(F32), k[:, t].astype(F32), v[:, t].astype(F32)
        at = i_pre[:, t].astype(F32)
        lf = -jax.nn.softplus(-f_pre[:, t].astype(F32))
        m_new = jnp.maximum(lf + m, at)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(at - m_new)
        C = C * fp[:, None, None] + ip[:, None, None] * jnp.einsum("bd,be->bde", kt, vt)
        n = n * fp[:, None] + ip[:, None] * kt
        num = jnp.einsum("bd,bde->be", qt, C)
        den = jnp.einsum("bd,bd->b", qt, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[:, None]
        return (C, n, m_new), h

    init = (
        jnp.zeros((BH, dh, dh), F32),
        jnp.zeros((BH, dh), F32),
        jnp.full((BH,), -1e30, F32),
    )
    _, hs = jax.lax.scan(step, init, jnp.arange(S))
    return hs.swapaxes(0, 1).astype(q.dtype)


# -- grouped matmul ---------------------------------------------------------
def gmm_ref(lhs: jax.Array, rhs: jax.Array, group_map: jax.Array, blk_m: int) -> jax.Array:
    """Per-m-block dense matmul against the mapped group's rhs."""
    M, K = lhs.shape
    out = []
    for i in range(M // blk_m):
        g = int(group_map[i])
        out.append(lhs[i * blk_m : (i + 1) * blk_m].astype(F32) @ rhs[g].astype(F32))
    return jnp.concatenate(out, axis=0).astype(lhs.dtype)
