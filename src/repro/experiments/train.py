"""Training sweeps as Memento experiment functions.

One task = one (arch, lr, optimizer-variant) training run through
``train/loop.py`` — the loop heartbeats the task, checkpoints sharded state
under a key-stable directory, and resumes from the last complete step when a
killed sweep is re-run. The returned metrics dict is what lands in the
Memento result cache.

Axes/settings understood by :func:`train_sweep`:

  arch (required)        registry name
  lr                     peak learning rate (default 1e-3)
  int8_opt               int8 optimizer moments (default False)
  steps                  training steps (default 50)
  seq_len, global_batch  shape (defaults 64, 8)
  warmup_steps           LR warmup (default min(20, steps // 4))
  ckpt_every, log_every  loop cadence (defaults 50, 20)
  workdir                checkpoint root; per-task subdir is keyed by the
                         task hash (default ".memento-train-sweep")
  reduced                use the smoke-scale config copy (default True)
  data_seed, noise       synthetic pipeline knobs (defaults 0, 0.05)
"""
from __future__ import annotations

from typing import Any

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.core.task import Context
from repro.data.pipeline import DataConfig
from repro.sharding.rules import ShardingCtx
from repro.train.loop import TrainRunConfig, train_run
from repro.train.optimizer import AdamWConfig, Schedule

from repro.analysis.metrics import MetricSpec

from .serve import _opt

# Declarative registration for repro.analysis: the train metrics worth
# extracting from sweep results (``Examiner(TRAIN_METRIC_SPECS)``).
TRAIN_METRIC_SPECS: tuple[MetricSpec, ...] = (
    MetricSpec("tokens_per_s", unit="tok/s"),
    MetricSpec("wall_s", unit="s"),
    MetricSpec("loss_first"),
    MetricSpec("loss_last"),
    MetricSpec(
        "loss_drop",
        extract=lambda v: v["loss_first"] - v["loss_last"],
    ),
)


def train_matrix(archs, lrs, int8=(False,), **settings: Any):
    """Build the (arch x lr x int8_opt) ConfigMatrix; ``settings`` become
    matrix settings. Compose with ``+``/``*``/``where``/``derive``."""
    from repro.core.matrix import ConfigMatrix

    return ConfigMatrix.from_dict(
        {
            "parameters": {
                "arch": list(archs),
                "lr": list(lrs),
                "int8_opt": list(int8),
            },
            "settings": dict(settings),
        }
    )


def train_sweep(ctx: Context) -> dict[str, Any]:
    """Experiment function: run (or resume) one training cell, return metrics."""
    arch = ctx["arch"]
    cfg = get_config(arch)
    if _opt(ctx, "reduced", True):
        cfg = cfg.reduced()
    steps = int(_opt(ctx, "steps", 50))
    shape = ShapeConfig(
        "sweep",
        "train",
        seq_len=int(_opt(ctx, "seq_len", 64)),
        global_batch=int(_opt(ctx, "global_batch", 8)),
    )
    lr = float(_opt(ctx, "lr", 1e-3))
    int8_opt = bool(_opt(ctx, "int8_opt", False))
    workdir = str(_opt(ctx, "workdir", ".memento-train-sweep"))
    run = TrainRunConfig(
        steps=steps,
        ckpt_every=int(_opt(ctx, "ckpt_every", 50)),
        log_every=int(_opt(ctx, "log_every", 20)),
        ckpt_dir=f"{workdir}/ckpt-{ctx.key[:10]}",
        opt=AdamWConfig(
            schedule=Schedule(
                base_lr=lr,
                warmup_steps=int(_opt(ctx, "warmup_steps", min(20, max(1, steps // 4)))),
                total_steps=steps,
            ),
            int8_moments=int8_opt,
        ),
        data=DataConfig(
            seed=int(_opt(ctx, "data_seed", 0)),
            vocab_size=cfg.vocab_size,
            noise=float(_opt(ctx, "noise", 0.05)),
        ),
    )
    res = train_run(cfg, shape, ShardingCtx.null(), run, ctx=ctx)
    return {
        "arch": arch,
        "lr": lr,
        "int8": int8_opt,
        "steps": steps,
        "tokens_per_step": shape.tokens,
        "wall_s": res["wall_s"],
        "tokens_per_s": shape.tokens * steps / res["wall_s"] if res["wall_s"] else 0.0,
        "loss_first": res["loss_first"],
        "loss_last": res["loss_last"],
    }
