"""Serving sweeps as Memento experiment functions.

One task = one scheduler configuration driven over a deterministic synthetic
workload (optionally Poisson-timed), returning throughput/latency/memory
metrics as a plain dict — picklable, cacheable, and comparable across the
matrix. Every knob is read from the task's params first, then its settings,
then a default, so any knob can be swept as a matrix axis or fixed for the
whole sweep.

Axes/settings understood by :func:`serve_sweep`:

  arch (required)        registry name, e.g. "llama3.2-3b"
  attn_backend           "xla" | "pallas" (default: the config's own)
  n_slots, cache_len     scheduler shape (defaults 4, 128)
  paged, page_size,      page-pool knobs (defaults True, 16, capacity parity)
  n_pages, prefill_buckets
  chunk_budget           unified token-budget step: per-step tokens shared by
                         decode rows + a prefill chunk (None/0 -> whole-prompt
                         prefill at admission)
  min_chunk              smallest chunk bucket (default 16)
  preemption             "off" | "swap" | "recompute" (reservation-free
                         admission + LRU page reclaim; needs chunk_budget)
  prefix_sharing         adopt indexed prompt-prefix pages (default True;
                         effective on fully-paged streaming models)
  tenant_quota           per-tenant worst-case page cap (default None)
  tenant_weights         {tenant: weight} stride-fair admission (default None)
  speculative            drafted multi-token decode steps with batched
                         verify (default False; greedy slots only)
  draft_k                max draft tokens per verify call (default 4)
  drafter                "ngram" (self-speculative prompt lookup, default)
                         or "oracle" (an untimed reference pass records
                         each request's greedy continuation and replays
                         it — the high-acceptance upper bound; run with
                         prefix_sharing off for row comparability, or the
                         reference pass also warms the prefix index)
  n_requests             workload size (default 8)
  prompt_lens            cycled prompt lengths (default (4, 8, 12))
  shared_prefix_len      tokens of one shared prompt prefix prepended to
                         every request (default 0; the prefix-sharing
                         workload knob — prompt_lens become tail lengths)
  prime_prefix           pre-submit one prefix-only request before timing so
                         the timed requests hit a warm prefix index
                         (default False; its TTFT is reported as ttft_cold_s)
  n_tenants              round-robin requests over this many tenants
                         ("t0".."tN-1", default 1)
  max_new_tokens         per-request decode budget (default 8)
  temperature            0 => greedy (default)
  arrival_rate_hz        Poisson arrival rate; 0/absent => offline batch
  mesh_shape             sharded stepping: (data, model) devices, as a
                         tuple/list or a "1x2" string (default None ->
                         single device). Needs that many visible XLA
                         devices (see launch/mesh.py)
  sharding_profile       ShardingProfile for the mesh (default
                         "decode_default")
  reduced                use the smoke-scale config copy (default True)
  warmup                 pre-compile per prompt bucket before timing (default True)
  seed                   workload RNG seed (default 0)
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Any

import numpy as np

from repro.analysis.metrics import MetricSpec
from repro.configs.registry import get_config
from repro.core.task import Context
from repro.serve.request import Request
from repro.serve.plan import pow2_ceil
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx

# Declarative registration for repro.analysis: the serve metrics worth
# extracting from sweep results (``Examiner(SERVE_METRIC_SPECS)``). Raw keys
# of the serve_sweep result dict plus derived ms-scale latencies.
SERVE_METRIC_SPECS: tuple[MetricSpec, ...] = (
    MetricSpec("tokens_per_s", unit="tok/s"),
    MetricSpec("wall_s", unit="s"),
    MetricSpec("latency_p50_s", unit="s"),
    MetricSpec("latency_p95_s", unit="s"),
    MetricSpec("ttft_p50_s", unit="s"),
    MetricSpec("itl_p50_s", unit="s"),
    MetricSpec("itl_p95_s", unit="s"),
    MetricSpec("accept_rate"),
    MetricSpec("tokens_per_model_step", unit="tok/step"),
    MetricSpec("peak_cache_bytes", unit="B"),
    MetricSpec(
        "itl_p50_ms", unit="ms",
        extract=lambda v: None if v.get("itl_p50_s") is None
        else v["itl_p50_s"] * 1e3,
    ),
    MetricSpec(
        "ttft_p50_ms", unit="ms",
        extract=lambda v: None if v.get("ttft_p50_s") is None
        else v["ttft_p50_s"] * 1e3,
    ),
    MetricSpec("predicted_step_ms", unit="ms"),
    # Measured inter-token latency over the analytic roofline bound: how
    # far the smoke-scale CPU run sits above the v5e prediction. Only the
    # *trend across meshes* is meaningful off-TPU, not the magnitude.
    MetricSpec(
        "roofline_ratio",
        extract=lambda v: (
            None
            if not v.get("predicted_step_ms") or v.get("itl_p50_s") is None
            else v["itl_p50_s"] * 1e3 / v["predicted_step_ms"]
        ),
    ),
)


def _mesh_shape_opt(value: Any) -> tuple[int, int] | None:
    """Normalize a mesh_shape knob: None, (d, m), [d, m], or "dxm"."""
    if value is None:
        return None
    if isinstance(value, str):
        d, m = value.lower().split("x")
        return (int(d), int(m))
    d, m = value
    return (int(d), int(m))


def _opt(ctx: Context, name: str, default: Any) -> Any:
    """Param if swept, else setting, else default."""
    try:
        return ctx[name]
    except KeyError:
        return default


def serve_matrix(
    archs,
    backends=("xla",),
    scheduler: dict[str, Any] | None = None,
    **workload: Any,
):
    """Build the (arch x attn_backend x scheduler-knob) ConfigMatrix.

    ``scheduler`` maps extra axis names to value lists (e.g.
    ``{"paged": [True, False]}``); ``workload`` keys become matrix settings.
    The result is a plain ConfigMatrix — compose with ``+``/``*``/``where``.
    """
    from repro.core.matrix import ConfigMatrix

    params: dict[str, Any] = {"arch": list(archs), "attn_backend": list(backends)}
    for name, values in (scheduler or {}).items():
        params[name] = list(values)
    return ConfigMatrix.from_dict({"parameters": params, "settings": dict(workload)})


def serve_sweep_distributed(
    matrix,
    queue_dir,
    workdir,
    namespace: str = "serve",
    lease_s: float = 600.0,
    max_attempts: int = 3,
    notification_provider=None,
    runner_config=None,
    stream: bool = False,
    owner: str | None = None,
):
    """Drain one serving sweep cooperatively across launcher hosts.

    Every host calls this with the same ``matrix``, ``queue_dir`` and
    ``workdir`` (both on a shared filesystem); tasks are leased through the
    file queue, metrics land in the shared FsCache, and each host returns
    the *full* sweep's ResultSet (or, with ``stream=True``, an iterator of
    results in completion order — cache hits first, then completions from
    any host). The default lease is generous because one serving cell
    includes model compiles; the runtime's background renewer keeps it
    alive however long a cell runs.
    """
    from repro.core import Memento, RunnerConfig

    eng = Memento(
        serve_sweep,
        notification_provider=notification_provider,
        workdir=workdir,
        namespace=namespace,
        runner_config=runner_config
        or RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    method = eng.stream_distributed if stream else eng.run_distributed
    return method(
        matrix, queue_dir, lease_s=lease_s, max_attempts=max_attempts, owner=owner
    )


def serve_sweep(ctx: Context) -> dict[str, Any]:
    """Experiment function: drive one serving configuration, return metrics."""
    arch = ctx["arch"]
    cfg = get_config(arch)
    if _opt(ctx, "reduced", True):
        cfg = cfg.reduced()
    backend = _opt(ctx, "attn_backend", cfg.attn_backend)
    cfg = replace(cfg, attn_backend=backend)

    from repro.models import lm
    from repro.models.schema import init_params

    import jax

    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(_opt(ctx, "seed", 0)))
    chunk_budget = _opt(ctx, "chunk_budget", None) or None
    mesh_shape = _mesh_shape_opt(_opt(ctx, "mesh_shape", None))
    sched_cfg = SchedulerConfig(
        n_slots=int(_opt(ctx, "n_slots", 4)),
        cache_len=int(_opt(ctx, "cache_len", 128)),
        paged=bool(_opt(ctx, "paged", True)),
        page_size=int(_opt(ctx, "page_size", 16)),
        n_pages=_opt(ctx, "n_pages", None),
        prefill_buckets=bool(_opt(ctx, "prefill_buckets", True)),
        chunk_budget=None if chunk_budget is None else int(chunk_budget),
        min_chunk=int(_opt(ctx, "min_chunk", 16)),
        preemption=str(_opt(ctx, "preemption", "off")),
        prefix_sharing=bool(_opt(ctx, "prefix_sharing", True)),
        tenant_quota=_opt(ctx, "tenant_quota", None),
        tenant_weights=_opt(ctx, "tenant_weights", None),
        speculative=bool(_opt(ctx, "speculative", False)),
        draft_k=int(_opt(ctx, "draft_k", 4)),
        seed=int(_opt(ctx, "seed", 0)),
        mesh_shape=mesh_shape,
        sharding_profile=str(_opt(ctx, "sharding_profile", "decode_default")),
    )
    drafter_kind = str(_opt(ctx, "drafter", "ngram"))
    if drafter_kind not in ("ngram", "oracle"):
        raise ValueError(f"unknown drafter {drafter_kind!r}")
    sched = Scheduler(cfg, params, ShardingCtx.null(), sched_cfg)

    rng = np.random.default_rng(int(_opt(ctx, "seed", 0)))
    n_req = int(_opt(ctx, "n_requests", 8))
    prompt_lens = [int(p) for p in _opt(ctx, "prompt_lens", (4, 8, 12))]
    shared_len = int(_opt(ctx, "shared_prefix_len", 0))
    n_tenants = int(_opt(ctx, "n_tenants", 1))
    max_new = int(_opt(ctx, "max_new_tokens", 8))
    temperature = float(_opt(ctx, "temperature", 0.0))
    lens = [prompt_lens[i % len(prompt_lens)] for i in range(n_req)]
    shared = rng.integers(0, cfg.vocab_size, size=shared_len).astype(np.int32)
    requests = [
        Request(
            np.concatenate(
                [shared, rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)]
            ),
            max_new_tokens=max_new,
            temperature=temperature,
            tenant=f"t{i % n_tenants}",
        )
        for i, p in enumerate(lens)
    ]

    if _opt(ctx, "warmup", True):
        # Compile every prompt-length bucket + the decode step outside the
        # timed window so the measured run sees steady-state latencies.
        warm_lens = {shared_len + p for p in lens}
        if shared_len and _opt(ctx, "prime_prefix", False):
            warm_lens.add(shared_len)  # the primer's own bucket
        for p in sorted(warm_lens):
            sched.submit(Request(np.zeros(p, np.int32), max_new_tokens=2))
        sched.run()
        if sched_cfg.speculative:
            # Compile the verify + rollback programs for every k-bucket
            # outside the timed window: a draft of out-of-vocab sentinels
            # can never be accepted, so one request per bucket exercises
            # verify and the rejection path (replay or pos fixup).
            from repro.serve.draft import ScriptDrafter

            wlen = max(shared_len + p for p in lens)
            seen: set[int] = set()
            for d in range(sched_cfg.draft_k, 0, -1):
                b = pow2_ceil(d + 1)
                if b in seen:
                    continue
                seen.add(b)
                sched.set_drafter(ScriptDrafter([np.full(d, -2, np.int32)]))
                sched.submit(Request(np.zeros(wlen, np.int32), max_new_tokens=d + 2))
                sched.run()
        sched.mem.reset_peaks()
        sched.deferred_admissions = 0

    if sched_cfg.speculative:
        if drafter_kind == "oracle":
            # Untimed reference pass: run the workload with drafting muted
            # (empty ScriptDrafter proposes nothing -> plain greedy) to
            # record each request's continuation, then replay it as a
            # perfect draft — the acceptance upper bound for this workload.
            from repro.serve.draft import ReplayDrafter, ScriptDrafter

            sched.set_drafter(ScriptDrafter([]))
            ref_rids = [
                sched.submit(
                    Request(
                        r.prompt, max_new_tokens=r.max_new_tokens,
                        temperature=r.temperature, tenant=r.tenant,
                    )
                )
                for r in requests
            ]
            while sched.pending or sched.num_active:
                ctx.heartbeat()
                sched.step()
            seqs = [
                np.concatenate(
                    [requests[i].prompt,
                     np.asarray(sched.result(rid).tokens, np.int32)]
                )
                for i, rid in enumerate(ref_rids)
            ]
            sched.set_drafter(ReplayDrafter(seqs))
            sched.mem.reset_peaks()
        else:
            from repro.serve.draft import NgramDrafter

            sched.set_drafter(NgramDrafter())

    ttft_cold = None
    if shared_len and _opt(ctx, "prime_prefix", False):
        # Prime the prefix index: one prefix-only request registers the
        # shared pages (its TTFT is the cold-prefix cost), so every timed
        # request adopts instead of recomputing the shared span.
        primer = sched.submit(Request(shared, max_new_tokens=1))
        while sched.pending or sched.num_active:
            sched.step()
        ttft_cold = sched.result(primer).ttft_s
        sched.mem.reset_peaks()

    rate = float(_opt(ctx, "arrival_rate_hz", 0.0) or 0.0)
    # Scope work counters past warmup (trace counters stay cumulative:
    # warmup exists precisely to absorb the compiles).
    steps_before = sched.total_decode_steps
    chunks_before = sched.total_chunk_steps
    preempts_before = sched.preemptions_total
    hits_before = sched.prefix_hits
    hit_tokens_before = sched.prefix_hit_tokens
    spec_before = sched.total_spec_steps
    replays_before = sched.total_spec_replays
    plan_before = sched.plan_time_s
    fallbacks_before = sched.spec_fallbacks
    drafted_before = sched.drafted_tokens_total
    accepted_before = sched.accepted_tokens_total
    t0 = time.perf_counter()
    if rate > 0.0:
        arrivals = np.cumsum(rng.exponential(scale=1.0 / rate, size=n_req))
        rids, i = [], 0
        while i < n_req or sched.pending or sched.num_active:
            ctx.heartbeat()
            now = time.perf_counter() - t0
            while i < n_req and arrivals[i] <= now:
                rids.append(sched.submit(requests[i]))
                i += 1
            if not sched.step() and i < n_req:
                time.sleep(max(0.0, arrivals[i] - (time.perf_counter() - t0)))
    else:
        rids = [sched.submit(r) for r in requests]
        while sched.pending or sched.num_active:
            ctx.heartbeat()
            sched.step()
    wall = time.perf_counter() - t0

    done = [sched.result(r) for r in rids]
    toks = sum(len(rs.tokens) for rs in done)
    lat = np.array([rs.latency_s for rs in done])
    ttft = np.array([rs.ttft_s for rs in done])
    # Inter-token latency across all in-flight decodes: the gap a streaming
    # client sees between consecutive tokens. Un-chunked long prefills of
    # *other* requests stall every in-flight decode and surface here as p95
    # spikes; the unified token-budget step is measured by this number.
    itl = [gap for rs in done for gap in rs.inter_token_s()]
    itl_a = np.array(itl) if itl else np.zeros(1)
    cache_bytes = sched.paged_cache_bytes()
    warm_ttft = np.array([rs.ttft_s for rs in done if rs.adopted_tokens > 0])
    decode_steps = sched.total_decode_steps - steps_before
    chunk_steps = sched.total_chunk_steps - chunks_before
    spec_steps = sched.total_spec_steps - spec_before
    spec_replays = sched.total_spec_replays - replays_before
    # Host-planner share: time spent in the pure plan layer (serve/plan.py)
    # over every scheduler step the timed window paid — the layered core's
    # overhead budget (microseconds against millisecond device steps).
    plan_s = sched.plan_time_s - plan_before
    plan_steps = decode_steps + chunk_steps + spec_steps + spec_replays
    drafted = sched.drafted_tokens_total - drafted_before
    accepted = sched.accepted_tokens_total - accepted_before
    # The headline speculation metric: generated tokens per model-step-
    # equivalent (decode steps + verify calls + rollback replays — every
    # forward pass the decode phase paid). Plain decoding pins this at
    # ~min(n_slots, live requests); speculation lifts it by accepted
    # tokens per verify.
    model_steps = decode_steps + spec_steps + spec_replays
    # Analytic v5e roofline for one decode step at this batch and mesh —
    # recorded next to the measured latencies so analysis can report the
    # measured/predicted ratio per mesh (launch/roofline.py).
    from repro.launch.roofline import predict_decode_step
    from repro.models.schema import count_params

    pred = predict_decode_step(
        cfg,
        count_params(lm.model_schema(cfg)),
        batch=sched_cfg.n_slots,
        mesh_shape=mesh_shape or (1, 1),
    )
    return {
        "arch": arch,
        "attn_backend": backend,
        "n_requests": n_req,
        "generated_tokens": toks,
        "tokens_per_s": toks / wall if wall > 0 else float("inf"),
        "wall_s": wall,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p95_s": float(np.percentile(lat, 95)),
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "itl_p50_s": float(np.percentile(itl_a, 50)),
        "itl_p95_s": float(np.percentile(itl_a, 95)),
        "decode_steps": decode_steps,
        "chunk_steps": chunk_steps,
        "spec_steps": spec_steps,
        "spec_replays": spec_replays,
        "spec_fallbacks": sched.spec_fallbacks - fallbacks_before,
        "drafted_tokens": drafted,
        "accepted_tokens": accepted,
        "accept_rate": accepted / drafted if drafted else None,
        "tokens_per_model_step": toks / model_steps if model_steps else None,
        "plan_time_s": plan_s,
        "plan_us_per_step": plan_s * 1e6 / plan_steps if plan_steps else None,
        "plan_frac": plan_s / wall if wall > 0 else None,
        "decode_traces": sched.decode_traces,
        "prefill_traces": sched.prefill_traces,
        "chunk_traces": sched.chunk_traces,
        "verify_traces": sched.verify_traces,
        "deferred_admissions": sched.stats()["deferred_admissions"],
        "quota_deferrals": sched.quota_deferrals,
        "preemptions": sched.preemptions_total - preempts_before,
        "prefix_hits": sched.prefix_hits - hits_before,
        "prefix_hit_tokens": sched.prefix_hit_tokens - hit_tokens_before,
        "ttft_cold_s": ttft_cold,
        "ttft_warm_p50_s": (
            float(np.percentile(warm_ttft, 50)) if warm_ttft.size else None
        ),
        "peak_cache_bytes": cache_bytes["peak_bytes"],
        "contiguous_cache_bytes": cache_bytes["contiguous_bytes"],
        "cache_bytes_per_page_per_device": cache_bytes[
            "bytes_per_page_per_device"
        ],
        "mesh": "1x1" if mesh_shape is None else f"{mesh_shape[0]}x{mesh_shape[1]}",
        "mesh_devices": sched.sctx.device_count(),
        "predicted_step_ms": pred.step_time_lower_bound * 1e3,
        "predicted_bottleneck": pred.bottleneck,
        "paged": sched_cfg.paged,
        "chunk_budget": sched_cfg.chunk_budget,
        "preemption": sched_cfg.preemption,
        "prefix_sharing": sched_cfg.prefix_sharing,
        "speculative": sched_cfg.speculative,
        "draft_k": sched_cfg.draft_k if sched_cfg.speculative else None,
        "drafter": drafter_kind if sched_cfg.speculative else None,
        "tokens": [rs.tokens for rs in done],
    }
