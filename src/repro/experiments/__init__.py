"""repro.experiments — workload adapters that run the jax_pallas stack
*through* the Memento core.

The serving and training subsystems are real experiment workloads: a sweep
over (model config x attn_backend x scheduler/pool settings) is a config
matrix, and running it through ``Memento`` buys caching, retries, streaming
results, and resume for free instead of hand-rolled loops.

    import repro.core as memento
    from repro.experiments import serve_sweep, serve_matrix

    results = memento.Memento(serve_sweep, workdir="sweeps", namespace="serve") \
        .run(serve_matrix(["llama3.2-3b"], backends=["xla", "pallas"]))

``serve_sweep`` / ``train_sweep`` are module-level experiment functions
(process-mode safe); ``serve_matrix`` / ``train_matrix`` build the matching
``ConfigMatrix`` — compose further with ``+``/``*``/``where``/``derive``.
"""
from .serve import SERVE_METRIC_SPECS, serve_matrix, serve_sweep, serve_sweep_distributed
from .train import TRAIN_METRIC_SPECS, train_matrix, train_sweep

__all__ = [
    "SERVE_METRIC_SPECS",
    "TRAIN_METRIC_SPECS",
    "serve_sweep",
    "serve_matrix",
    "serve_sweep_distributed",
    "train_sweep",
    "train_matrix",
]
