"""Runner-backed multi-host drain: the distributed sweep runtime.

Every participating host runs the same code against one queue directory and
one shared result cache (both on a shared filesystem):

  publish (idempotent)
    -> cache hits stream out first, before any claiming starts
    -> a claim feed pulls work from the FileQueue and drives the host's
       *full* local Runner: thread pool, per-task retry budget, hard
       timeouts, straggler speculation, checkpoint heartbeats
    -> a background lease-renewal thread keeps every locally-claimed lease
       alive, so long tasks that never call ``ctx.heartbeat()`` no longer
       lose their lease mid-run
    -> a poller surfaces completions from *other* hosts (done/ records plus
       the shared FsCache) into the same result stream, so each host's
       stream converges to the full matrix regardless of who ran what
    -> failures carry the real error + traceback in ``done/<key>.json`` and
       are retried across hosts: a task that failed on host A may be
       re-claimed by host B (or A) until ``max_attempts`` queue-level
       attempts are on record, then surfaces as ``failed`` with the
       *original* error.

The protocol needs no coordinator: termination is per-host ("every task of
my matrix has a final result somewhere"), and host death is covered by lease
expiry — survivors re-claim and re-run, which is safe because tasks are
idempotent (pure function + atomic cache writes + versioned checkpoints).
"""
from __future__ import annotations

import queue as _queue_mod
import threading
import time
from dataclasses import dataclass
from typing import Any, Iterator, Sequence

from .exceptions import QueueError
from .filequeue import FileQueue
from .matrix import TaskSpec
from .notifications import Event
from .runner import Runner
from .task import TaskResult


@dataclass
class DistributedConfig:
    max_attempts: int = 3  # queue-level (cross-host) attempts per task
    poll_s: float = 0.2  # remote done/cache poll + local result wait
    claim_ahead: int = 2  # keys claimed beyond the worker count
    progress_every_s: float = 5.0  # queue_progress notification cadence
    missing_result_grace_s: float = 5.0  # done-ok but cache miss tolerance


class LeaseRenewer:
    """Daemon thread renewing the leases of every locally-claimed key.

    Decouples lease liveness from the task's own ``ctx.heartbeat()``
    discipline: a task that crunches for an hour without heartbeating keeps
    its claim. A lease we fail to renew (broken by a peer after a stall) is
    dropped from the set and reported via :meth:`lost`.
    """

    def __init__(self, queue: FileQueue, interval_s: float | None = None):
        self.queue = queue
        self.interval_s = (
            interval_s if interval_s is not None else max(queue.lease_s / 3.0, 0.05)
        )
        self._keys: set[str] = set()
        self._lost: set[str] = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="memento-lease-renewer", daemon=True
        )

    def start(self) -> None:
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()

    def add(self, key: str) -> None:
        with self._lock:
            self._keys.add(key)

    def remove(self, key: str) -> None:
        with self._lock:
            self._keys.discard(key)

    def lost(self) -> set[str]:
        with self._lock:
            out, self._lost = self._lost, set()
        return out

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            with self._lock:
                keys = list(self._keys)
            for key in keys:
                try:
                    self.queue.renew(key)
                except QueueError:
                    with self._lock:
                        self._keys.discard(key)
                        self._lost.add(key)
                except Exception:
                    pass  # transient FS error: retry next tick


def _notify(runner: Runner, kind: str, message: str, **payload: Any) -> None:
    if runner.provider is None:
        return
    try:
        runner.provider.notify(Event(kind=kind, message=message, payload=payload))
    except Exception:
        pass  # providers must never take the run down


def _notify_finished(runner: Runner, res: TaskResult) -> None:
    if runner.provider is None:
        return
    try:
        runner.provider.task_finished(res)
    except Exception:
        pass


def stream_distributed(
    runner: Runner,
    queue: FileQueue,
    specs: Sequence[TaskSpec],
    config: DistributedConfig | None = None,
) -> Iterator[TaskResult]:
    """Cooperatively drain ``specs`` with other hosts; yield every task's
    final :class:`TaskResult` — cache hits first, then live completions from
    *any* host in completion order."""
    cfg = config or DistributedConfig()
    cache = runner.cache
    by_key: dict[str, TaskSpec] = {}
    order: list[str] = []
    for s in specs:
        if s.key not in by_key:
            by_key[s.key] = s
            order.append(s.key)

    workers = runner.config.resolved_workers()
    _notify(
        runner,
        "run_started",
        f"{len(order)} tasks, {workers} workers, distributed as {queue.owner}",
        owner=queue.owner,
        total=len(order),
        workers=workers,
    )

    # Phase 0: cache hits first. Also best-effort mark them done so the
    # queue's global state converges even if every host had a warm cache.
    unresolved: set[str] = set()
    n_cached = 0
    for key in order:
        entry = cache.get(key)
        if entry is not None:
            n_cached += 1
            if not queue.is_done(key) and queue.try_claim(key):
                queue.mark_done(key, "ok", {"cached": True})
            yield TaskResult(
                spec=by_key[key], status="cached", value=entry.value, wall_s=0.0
            )
        else:
            unresolved.add(key)
    if not unresolved:
        _notify(runner, "run_finished", f"{n_cached} cached / 0 live", cached=n_cached)
        return

    lock = threading.Lock()
    owned: set[str] = set()  # claimed by us, executing locally
    stop = threading.Event()
    renewer = LeaseRenewer(queue)
    max_owned = workers + max(0, cfg.claim_ahead)
    # Stagger the scan origin per host so N hosts don't all hammer the same
    # head-of-queue key on every round.
    rot = sum(ord(c) for c in queue.owner) % max(len(order), 1)

    def claim_source() -> Iterator[TaskSpec | None]:
        while not stop.is_set():
            with lock:
                if not unresolved:
                    return
                room = len(owned) < max_owned
                candidates = (
                    [k for k in order if k in unresolved and k not in owned]
                    if room
                    else []
                )
            candidates = candidates[rot % max(len(candidates), 1):] + \
                candidates[:rot % max(len(candidates), 1)]
            got: str | None = None
            for key in candidates:
                if queue.is_done(key):
                    continue  # a peer finished it; the poller will surface it
                if queue.try_claim(key):
                    if queue.is_done(key):
                        # The owner finished + released between our is_done
                        # check and this claim (done records are published
                        # before release); leave it to the poller.
                        queue.release(key)
                        continue
                    got = key
                    break
            if got is None:
                yield None  # nothing claimable right now; runner keeps polling
                continue
            with lock:
                owned.add(got)
            renewer.add(got)
            yield by_key[got]

    out: "_queue_mod.Queue[TaskResult | None]" = _queue_mod.Queue()
    local_error: list[BaseException] = []

    def local_loop() -> None:
        try:
            for res in runner.stream_source(claim_source()):
                out.put(res)
        except BaseException as e:  # noqa: BLE001 - surfaced to the consumer
            local_error.append(e)
        finally:
            out.put(None)  # sentinel: local side exhausted (or died)

    local = threading.Thread(target=local_loop, name="memento-local-drain", daemon=True)
    renewer.start()
    local.start()

    missing_since: dict[str, float] = {}
    t_progress = 0.0
    n_ok = n_failed = 0
    t0 = time.time()
    try:
        while True:
            with lock:
                if not unresolved:
                    break

            # -- local completions ------------------------------------------
            try:
                res = out.get(timeout=cfg.poll_s)
            except _queue_mod.Empty:
                res = None
            if res is None and local_error:
                # The local drain infrastructure died (not a task failure —
                # those are TaskResults). Hand our claims back to the cluster
                # and surface the error instead of silently hanging while the
                # renewer pins leases nobody is working on.
                with lock:
                    stranded = sorted(owned)
                for key in stranded:
                    renewer.remove(key)
                    queue.release(key)
                    with lock:
                        owned.discard(key)
                raise QueueError(
                    f"local drain on {queue.owner} died: {local_error[0]!r}"
                ) from local_error[0]
            if res is not None:
                key = res.spec.key
                renewer.remove(key)
                with lock:
                    live = key in unresolved
                if live and res.ok:
                    queue.mark_done(key, "ok", {"wall_s": res.wall_s})
                    with lock:
                        unresolved.discard(key)
                        owned.discard(key)
                    n_ok += 1
                    yield res
                elif live:
                    # If our lease was broken mid-run and a peer already
                    # completed this task successfully, their result wins —
                    # don't let our late local failure clobber it.
                    peer_rec = queue.read_done(key)
                    peer_entry = cache.get(key)
                    if peer_entry is not None:
                        with lock:
                            unresolved.discard(key)
                            owned.discard(key)
                        n_ok += 1
                        yield TaskResult(
                            spec=res.spec,
                            status="ok",
                            value=peer_entry.value,
                            host=str((peer_rec or {}).get("owner", "peer")),
                        )
                        continue
                    if peer_rec is not None and peer_rec.get("status") == "ok":
                        # done-ok but payload not visible yet: hand the key to
                        # the remote poller (with its grace window) instead of
                        # recording a failure over a success.
                        with lock:
                            owned.discard(key)
                        continue
                    terminal = queue.finalize_failure(
                        key,
                        res.error or res.status,
                        res.traceback_str,
                        max_attempts=cfg.max_attempts,
                    )
                    if terminal is not None:
                        with lock:
                            unresolved.discard(key)
                            owned.discard(key)
                        n_failed += 1
                        yield TaskResult(
                            spec=res.spec,
                            status=res.status,
                            error=terminal.get("error"),
                            traceback_str=terminal.get("traceback"),
                            attempts=int(terminal.get("attempts", 1) or 1),
                            started_unix=res.started_unix,
                            wall_s=res.wall_s,
                        )
                    else:
                        # Queue-level retry budget remains; finalize_failure
                        # released the claim, so any host — us included — may
                        # re-claim for the next attempt.
                        with lock:
                            owned.discard(key)
                        _notify(
                            runner,
                            "task_requeued",
                            f"{res.spec.describe()} failed on {queue.owner}; "
                            "released for cluster retry",
                            key=key,
                        )

            # -- leases we lost (peer broke them after a stall) --------------
            for key in renewer.lost():
                _notify(
                    runner,
                    "lease_lost",
                    f"lost lease on {key[:12]}; a peer may duplicate this task "
                    "(idempotent, results converge)",
                    key=key,
                )

            # -- remote completions -----------------------------------------
            with lock:
                remote_candidates = [
                    k for k in order if k in unresolved and k not in owned
                ]
            for key in remote_candidates:
                entry = cache.get(key)
                if entry is not None:
                    rec = queue.read_done(key) or {}
                    with lock:
                        unresolved.discard(key)
                    n_ok += 1
                    remote = TaskResult(
                        spec=by_key[key],
                        status="ok",
                        value=entry.value,
                        host=str(rec.get("owner", "peer")),
                        attempts=int(rec.get("attempts", 1) or 1),
                        wall_s=float(rec.get("wall_s", 0.0) or 0.0),
                    )
                    _notify_finished(runner, remote)
                    yield remote
                    continue
                rec = queue.read_done(key)
                if rec is None:
                    continue
                if rec.get("status") == "ok":
                    # Done record visible before the cache entry (FS lag), or
                    # the peer's cache write failed. Give it a grace window.
                    first_seen = missing_since.setdefault(key, time.time())
                    if time.time() - first_seen <= cfg.missing_result_grace_s:
                        continue
                    rec = dict(rec)
                    rec["status"] = "failed"
                    rec["error"] = (
                        f"completed on host {rec.get('owner')} but the result "
                        "never appeared in the shared cache"
                    )
                with lock:
                    unresolved.discard(key)
                n_failed += 1
                remote = TaskResult.from_done_record(by_key[key], rec)
                _notify_finished(runner, remote)
                yield remote

            # -- queue progress ---------------------------------------------
            now = time.time()
            if now - t_progress >= cfg.progress_every_s:
                t_progress = now
                prog = queue.progress()
                hosts = ", ".join(
                    f"{h}: {prog['claimed_by'].get(h, 0)} claimed/"
                    f"{prog['done_by'].get(h, 0)} done"
                    for h in sorted(set(prog["claimed_by"]) | set(prog["done_by"]))
                )
                elapsed = now - t0
                done_live = n_ok + n_failed
                remaining = max(int(prog["total"]) - int(prog["done"]), 0)
                eta = remaining * elapsed / done_live if done_live else None
                _notify(
                    runner,
                    "queue_progress",
                    f"{prog['done']}/{prog['total']} done" + (f" ({hosts})" if hosts else ""),
                    **prog,
                    owner=queue.owner,
                    elapsed_s=round(elapsed, 3),
                    eta_s=None if eta is None else round(eta, 3),
                )
    finally:
        stop.set()
        renewer.stop()
        local.join(timeout=5.0)
        _notify(
            runner,
            "run_finished",
            f"{n_ok} ok / {n_failed} failed "
            f"({n_cached} cached) in {time.time() - t0:.1f}s",
            ok=n_ok,
            failed=n_failed,
            cached=n_cached,
        )
