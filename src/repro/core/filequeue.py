"""Multi-host work distribution over a shared filesystem.

At 1000+ node scale the orchestrator itself must be distributed: one launcher
host per pod, all draining the same configuration matrix. We use the classic
shared-FS claim protocol (no network service to stand up, no single point of
failure):

  <queue>/tasks/<key>.json          task record (params digest, index)
  <queue>/claims/<key>.claim        atomically created with O_CREAT|O_EXCL;
                                    contains owner + lease expiry; renewed by
                                    heartbeats; an expired lease may be broken
                                    by any host (crash recovery)
  <queue>/done/<key>.json           completion record (results live in FsCache)

Atomic create-exclusive is the mutex; lease renewal is the liveness signal;
quorum is never needed because every task is idempotent (pure function +
atomic cache writes + versioned checkpoints), so the worst case of a broken
lease race is duplicated work, never corrupted state.
"""
from __future__ import annotations

import json
import os
import socket
import time
import uuid
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Iterable, Sequence

from .exceptions import QueueError
from .matrix import TaskSpec

TASKS = "tasks"
CLAIMS = "claims"
DONE = "done"


@dataclass
class QueueStats:
    total: int
    claimed: int
    done: int

    @property
    def available(self) -> int:
        return self.total - self.claimed - self.done


class FileQueue:
    """A shared-filesystem task queue with leases."""

    def __init__(
        self,
        root: str | os.PathLike[str],
        lease_s: float = 120.0,
        owner: str | None = None,
    ):
        self.root = Path(root)
        self.lease_s = float(lease_s)
        self.owner = owner or f"{socket.gethostname()}-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        for sub in (TASKS, CLAIMS, DONE):
            (self.root / sub).mkdir(parents=True, exist_ok=True)

    # -- population ---------------------------------------------------------
    def publish(self, specs: Sequence[TaskSpec]) -> int:
        """Idempotently register tasks; returns how many were newly added."""
        added = 0
        for spec in specs:
            path = self.root / TASKS / f"{spec.key}.json"
            if path.exists():
                continue
            tmp = path.with_name(f".{spec.key}.{self.owner}.tmp")
            tmp.write_text(
                json.dumps(
                    {
                        "key": spec.key,
                        "index": spec.index,
                        "published_by": self.owner,
                        "published_unix": time.time(),
                    }
                )
            )
            try:
                os.replace(tmp, path)
                added += 1
            except OSError as e:  # pragma: no cover - FS race
                tmp.unlink(missing_ok=True)
                if not path.exists():
                    raise QueueError(f"failed to publish {spec.key[:12]}: {e}") from e
        return added

    # -- claims ---------------------------------------------------------------
    def _claim_path(self, key: str) -> Path:
        return self.root / CLAIMS / f"{key}.claim"

    def _read_claim(self, key: str) -> dict[str, Any] | None:
        try:
            return json.loads(self._claim_path(key).read_text())
        except (OSError, json.JSONDecodeError):
            return None

    def _write_claim_body(self, fd: int) -> None:
        body = json.dumps(
            {"owner": self.owner, "expires_unix": time.time() + self.lease_s}
        )
        os.write(fd, body.encode())

    def try_claim(self, key: str) -> bool:
        """Claim ``key``; True on success. Breaks expired leases."""
        path = self._claim_path(key)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
        except FileExistsError:
            claim = self._read_claim(key)
            if claim is not None and claim.get("expires_unix", 0) > time.time():
                return False  # live claim held elsewhere
            # Expired or unreadable: break the lease, then race for the new one.
            try:
                path.unlink(missing_ok=True)
            except OSError:
                return False
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
            except FileExistsError:
                return False  # someone else won the re-claim race
        try:
            self._write_claim_body(fd)
        finally:
            os.close(fd)
        return True

    def renew(self, key: str) -> None:
        """Heartbeat: extend the lease. Raises if we no longer own it."""
        claim = self._read_claim(key)
        if claim is None or claim.get("owner") != self.owner:
            raise QueueError(
                f"lost lease on {key[:12]} (now owned by "
                f"{claim.get('owner') if claim else 'nobody'})"
            )
        tmp = self._claim_path(key).with_suffix(".renew")
        tmp.write_text(
            json.dumps({"owner": self.owner, "expires_unix": time.time() + self.lease_s})
        )
        os.replace(tmp, self._claim_path(key))

    def release(self, key: str) -> None:
        claim = self._read_claim(key)
        if claim is not None and claim.get("owner") == self.owner:
            self._claim_path(key).unlink(missing_ok=True)

    # -- completion -----------------------------------------------------------
    def mark_done(self, key: str, status: str, meta: dict[str, Any] | None = None) -> None:
        path = self.root / DONE / f"{key}.json"
        tmp = path.with_name(f".{key}.{self.owner}.tmp")
        tmp.write_text(
            json.dumps(
                {
                    "key": key,
                    "status": status,
                    "owner": self.owner,
                    "finished_unix": time.time(),
                    **(meta or {}),
                },
                default=str,
            )
        )
        os.replace(tmp, path)
        self.release(key)

    def is_done(self, key: str) -> bool:
        return (self.root / DONE / f"{key}.json").exists()

    # -- iteration --------------------------------------------------------------
    def pending_keys(self) -> list[str]:
        done = {p.stem for p in (self.root / DONE).glob("*.json")}
        keys = []
        for p in sorted((self.root / TASKS).glob("*.json")):
            if p.stem not in done:
                keys.append(p.stem)
        return keys

    def stats(self) -> QueueStats:
        total = sum(1 for _ in (self.root / TASKS).glob("*.json"))
        done = sum(1 for _ in (self.root / DONE).glob("*.json"))
        now = time.time()
        claimed = 0
        for p in (self.root / CLAIMS).glob("*.claim"):
            try:
                claim = json.loads(p.read_text())
                if claim.get("expires_unix", 0) > now:
                    claimed += 1
            except (OSError, json.JSONDecodeError):
                continue
        return QueueStats(total=total, claimed=claimed, done=done)


def drain(
    queue: FileQueue,
    specs_by_key: dict[str, TaskSpec],
    execute: Callable[[TaskSpec, Callable[[], None]], Any],
    on_result: Callable[[str, str, Any], None] | None = None,
    idle_rounds: int = 3,
    idle_sleep_s: float = 0.2,
) -> dict[str, str]:
    """Worker loop: claim -> execute (with lease heartbeat) -> mark done.

    Returns {key: status} for the tasks *this* worker completed. Multiple
    hosts call this concurrently on the same queue directory; termination is
    detected by observing ``idle_rounds`` consecutive scans with no claimable
    work and no live foreign claims outstanding.
    """
    completed: dict[str, str] = {}
    idle = 0
    while idle < idle_rounds:
        progressed = False
        for key in queue.pending_keys():
            if queue.is_done(key):
                continue
            spec = specs_by_key.get(key)
            if spec is None:
                continue  # published by a matrix version we don't have
            if not queue.try_claim(key):
                continue
            progressed = True

            def beat(k: str = key) -> None:
                queue.renew(k)

            try:
                value = execute(spec, beat)
                queue.mark_done(key, "ok")
                completed[key] = "ok"
                if on_result is not None:
                    on_result(key, "ok", value)
            except Exception as e:  # noqa: BLE001 - task isolation by design
                queue.mark_done(key, "failed", {"error": f"{type(e).__qualname__}: {e}"})
                completed[key] = "failed"
                if on_result is not None:
                    on_result(key, "failed", e)
        if progressed:
            idle = 0
        else:
            stats = queue.stats()
            if stats.available == 0 and stats.claimed == 0:
                idle += 1
            time.sleep(idle_sleep_s)
    return completed
