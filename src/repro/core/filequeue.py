"""Multi-host work distribution over a shared filesystem.

At 1000+ node scale the orchestrator itself must be distributed: one launcher
host per pod, all draining the same configuration matrix. We use the classic
shared-FS claim protocol (no network service to stand up, no single point of
failure):

  <queue>/tasks/<key>.json          task record (params digest, index)
  <queue>/claims/<key>.claim        atomically created with O_CREAT|O_EXCL;
                                    contains owner + lease expiry; renewed by
                                    heartbeats; an expired lease may be broken
                                    by any host (crash recovery)
  <queue>/fails/<key>.<nonce>.json  one record per failed execution attempt
                                    (any host); the cross-host retry budget
                                    counts these
  <queue>/done/<key>.json           completion record: status, owning host,
                                    and for failures the original error +
                                    traceback (results live in FsCache)

Atomic create-exclusive is the mutex; lease renewal is the liveness signal;
quorum is never needed because every task is idempotent (pure function +
atomic cache writes + versioned checkpoints), so the worst case of a broken
lease race is duplicated work, never corrupted state.

Lease breaking and release never ``unlink`` a claim in place — between
observing a claim and deleting it, another host may have legitimately
broken the lease and re-claimed, and the unlink would destroy *their* live
claim (both hosts then believe they own the task). Instead the claim file
is atomically renamed (``os.replace``) to a private tombstone, its content
is verified, and a claim that turns out to be live again is restored via a
no-clobber hard link. Only one host's rename can win for a given claim
file, which makes the break itself race-free.
"""
from __future__ import annotations

import json
import logging
import os
import socket
import time
import uuid
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Collection, Sequence

from .exceptions import QueueError
from .matrix import TaskSpec

log = logging.getLogger(__name__)

TASKS = "tasks"
CLAIMS = "claims"
FAILS = "fails"
DONE = "done"


@dataclass
class QueueStats:
    total: int
    claimed: int
    done: int

    @property
    def available(self) -> int:
        return self.total - self.claimed - self.done


class FileQueue:
    """A shared-filesystem task queue with leases."""

    def __init__(
        self,
        root: str | os.PathLike[str],
        lease_s: float = 120.0,
        owner: str | None = None,
    ):
        self.root = Path(root)
        self.lease_s = float(lease_s)
        self.owner = owner or f"{socket.gethostname()}-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        for sub in (TASKS, CLAIMS, FAILS, DONE):
            (self.root / sub).mkdir(parents=True, exist_ok=True)

    # -- population ---------------------------------------------------------
    def publish(self, specs: Sequence[TaskSpec]) -> int:
        """Idempotently register tasks; returns how many were newly added."""
        added = 0
        for spec in specs:
            path = self.root / TASKS / f"{spec.key}.json"
            if path.exists():
                continue
            tmp = path.with_name(f".{spec.key}.{self.owner}.tmp")
            tmp.write_text(
                json.dumps(
                    {
                        "key": spec.key,
                        "index": spec.index,
                        "published_by": self.owner,
                        "published_unix": time.time(),
                    }
                )
            )
            try:
                os.replace(tmp, path)
                added += 1
            except OSError as e:  # pragma: no cover - FS race
                tmp.unlink(missing_ok=True)
                if not path.exists():
                    raise QueueError(f"failed to publish {spec.key[:12]}: {e}") from e
        return added

    # -- claims ---------------------------------------------------------------
    def _claim_path(self, key: str) -> Path:
        return self.root / CLAIMS / f"{key}.claim"

    def _read_claim(self, key: str) -> dict[str, Any] | None:
        try:
            return json.loads(self._claim_path(key).read_text())
        except (OSError, json.JSONDecodeError):
            return None

    def _create_claim(self, path: Path) -> bool:
        """Atomically create ``path`` with a fully-written claim body; False
        if a claim already exists.

        Hard-linking a pre-written private file publishes existence and
        content in one step. Creating the file first and writing the body
        after (the old O_CREAT|O_EXCL approach) left a window where a peer
        read an empty claim, judged it "unreadable", and broke a live lease
        mid-claim — two hosts then drained the same key.
        """
        tmp = self.root / CLAIMS / f".{uuid.uuid4().hex[:8]}.new"
        tmp.write_text(
            json.dumps(
                {"owner": self.owner, "expires_unix": time.time() + self.lease_s}
            )
        )
        try:
            os.link(tmp, path)
            return True
        except OSError:
            return False
        finally:
            tmp.unlink(missing_ok=True)

    def _steal_claim(self, key: str) -> tuple[Path, dict[str, Any] | None] | None:
        """Atomically take ``key``'s claim file out of service.

        Renames the claim to a tombstone private to this call, so the content
        we then read is exactly the claim we removed — no other host can have
        mutated it in between (their rename/replace would have lost the race).
        Returns ``(tombstone_path, content)`` or None when no claim existed.
        """
        tomb = self.root / CLAIMS / f".{key}.{uuid.uuid4().hex[:8]}.tomb"
        try:
            os.replace(self._claim_path(key), tomb)
        except FileNotFoundError:
            return None
        except OSError:
            return None
        try:
            content: dict[str, Any] | None = json.loads(tomb.read_text())
        except (OSError, json.JSONDecodeError):
            content = None
        return tomb, content

    def _restore_claim(self, key: str, tomb: Path) -> None:
        """Put back a stolen claim that turned out to be live (not ours to
        break). Hard-link is atomic and refuses to clobber, so a fresh claim
        created in the tiny steal window is never destroyed."""
        try:
            os.link(tomb, self._claim_path(key))
        except OSError:
            pass  # a fresh claim took over in the window; leave it be
        tomb.unlink(missing_ok=True)

    def try_claim(self, key: str) -> bool:
        """Claim ``key``; True on success. Breaks expired leases."""
        path = self._claim_path(key)
        if self._create_claim(path):
            return True
        claim = self._read_claim(key)
        if claim is not None and claim.get("expires_unix", 0) > time.time():
            return False  # live claim held elsewhere
        # Expired or unreadable: break the lease by *renaming* the claim
        # to a tombstone. Re-check the tombstone's content — between our
        # read above and the rename, the owner may have renewed or a
        # faster host may have broken + re-claimed; a claim that is live
        # again is restored, not destroyed.
        stolen = self._steal_claim(key)
        if stolen is not None:
            tomb, content = stolen
            if content is not None and content.get("expires_unix", 0) > time.time():
                self._restore_claim(key, tomb)
                return False
            tomb.unlink(missing_ok=True)  # genuinely dead: lease broken
        return self._create_claim(path)

    def renew(self, key: str) -> None:
        """Heartbeat: extend the lease. Raises if we no longer own it.

        Two paths. While our lease is comfortably live, a blind
        ``os.replace`` is safe *and* windowless: peers only break expired
        leases, so nobody may legitimately take a live claim out from under
        us. Once the lease is near/past expiry that assumption dies — a peer
        may have broken + re-claimed between our read and our write — so the
        renewal switches to the same steal-verify protocol as
        :meth:`try_claim`/:meth:`release`, which raises instead of
        clobbering the peer's fresh claim.
        """
        claim = self._read_claim(key)
        if claim is None or claim.get("owner") != self.owner:
            raise QueueError(
                f"lost lease on {key[:12]} (now owned by "
                f"{claim.get('owner') if claim else 'nobody'})"
            )
        margin = self.lease_s * 0.25  # tolerated cross-host clock/scan skew
        if claim.get("expires_unix", 0) > time.time() + margin:
            tmp = self._claim_path(key).with_suffix(".renew")
            tmp.write_text(
                json.dumps(
                    {"owner": self.owner, "expires_unix": time.time() + self.lease_s}
                )
            )
            os.replace(tmp, self._claim_path(key))
            return
        stolen = self._steal_claim(key)
        if stolen is None:
            raise QueueError(f"lost lease on {key[:12]} (claim vanished)")
        tomb, content = stolen
        if content is None or content.get("owner") != self.owner:
            self._restore_claim(key, tomb)
            raise QueueError(
                f"lost lease on {key[:12]} (now owned by "
                f"{content.get('owner') if content else 'nobody'})"
            )
        tomb.unlink(missing_ok=True)
        # The claim path is momentarily absent; re-create it no-clobber so a
        # rival that claimed in the window is not overwritten.
        renewed = self.root / CLAIMS / f".{key}.{uuid.uuid4().hex[:8]}.renew"
        renewed.write_text(
            json.dumps({"owner": self.owner, "expires_unix": time.time() + self.lease_s})
        )
        try:
            os.link(renewed, self._claim_path(key))
        except OSError as e:
            raise QueueError(
                f"lost lease on {key[:12]} (re-claimed during renewal)"
            ) from e
        finally:
            renewed.unlink(missing_ok=True)

    def release(self, key: str) -> None:
        """Drop our claim on ``key`` (no-op if we no longer hold it).

        Ownership is verified *after* atomically renaming the claim to a
        tombstone: if the content shows another host re-claimed in the
        meantime (our lease expired and was broken), their claim is restored
        instead of destroyed.
        """
        claim = self._read_claim(key)
        if claim is None or claim.get("owner") != self.owner:
            return  # already released / broken; never touch a foreign claim
        stolen = self._steal_claim(key)
        if stolen is None:
            return
        tomb, content = stolen
        if content is not None and content.get("owner") != self.owner:
            self._restore_claim(key, tomb)
            return
        tomb.unlink(missing_ok=True)

    # -- failure attempts -----------------------------------------------------
    def record_failure(
        self, key: str, error: str, traceback_str: str | None = None
    ) -> int:
        """Append one attempt-failure record for ``key``; returns how many
        failed attempts are now on record across all hosts (the cross-host
        retry budget counts these)."""
        path = self.root / FAILS / f"{key}.{uuid.uuid4().hex[:8]}.json"
        tmp = path.with_name(f".{path.name}.tmp")
        tmp.write_text(
            json.dumps(
                {
                    "key": key,
                    "owner": self.owner,
                    "error": error,
                    "traceback": traceback_str,
                    "failed_unix": time.time(),
                },
                default=str,
            )
        )
        os.replace(tmp, path)
        return len(self.failure_records(key))

    def finalize_failure(
        self,
        key: str,
        error: str,
        traceback_str: str | None = None,
        max_attempts: int = 1,
    ) -> dict[str, Any] | None:
        """One failed execution attempt happened here: record it, then either
        release the claim for any host's next attempt (budget remains —
        returns None) or write the terminal done record carrying the
        *original* error + traceback and the attempt count (returns it)."""
        n = self.record_failure(key, error, traceback_str)
        if n < max_attempts:
            self.release(key)  # leave it for any host — this one included
            return None
        first = (self.failure_records(key) or [{}])[0]
        meta = {
            "error": first.get("error") or error,
            "traceback": first.get("traceback") or traceback_str,
            "attempts": n,
            "last_error": error,
        }
        self.mark_done(key, "failed", meta)
        return self.read_done(key) or {"key": key, "status": "failed", **meta}

    def failure_records(self, key: str) -> list[dict[str, Any]]:
        """All recorded failed attempts for ``key``, oldest first."""
        records = []
        for p in (self.root / FAILS).glob(f"{key}.*.json"):
            try:
                records.append(json.loads(p.read_text()))
            except (OSError, json.JSONDecodeError):
                continue
        records.sort(key=lambda r: r.get("failed_unix", 0.0))
        return records

    # -- completion -----------------------------------------------------------
    def mark_done(self, key: str, status: str, meta: dict[str, Any] | None = None) -> None:
        path = self.root / DONE / f"{key}.json"
        tmp = path.with_name(f".{key}.{self.owner}.tmp")
        tmp.write_text(
            json.dumps(
                {
                    "key": key,
                    "status": status,
                    "owner": self.owner,
                    "finished_unix": time.time(),
                    **(meta or {}),
                },
                default=str,
            )
        )
        os.replace(tmp, path)
        self.release(key)

    def is_done(self, key: str) -> bool:
        return (self.root / DONE / f"{key}.json").exists()

    def read_done(self, key: str) -> dict[str, Any] | None:
        """The completion record for ``key`` (status, owner, error/traceback
        for failures), or None if the task is not done."""
        try:
            return json.loads((self.root / DONE / f"{key}.json").read_text())
        except (OSError, json.JSONDecodeError):
            return None

    # -- iteration --------------------------------------------------------------
    def pending_keys(self) -> list[str]:
        done = {p.stem for p in (self.root / DONE).glob("*.json")}
        keys = []
        for p in sorted((self.root / TASKS).glob("*.json")):
            if p.stem not in done:
                keys.append(p.stem)
        return keys

    def stats(self, keys: Collection[str] | None = None) -> QueueStats:
        """Queue totals; restricted to ``keys`` when given, so a worker that
        only knows its own matrix version ignores foreign-published tasks."""
        keyset = set(keys) if keys is not None else None

        def known(stem: str) -> bool:
            return keyset is None or stem in keyset

        total = sum(1 for p in (self.root / TASKS).glob("*.json") if known(p.stem))
        done = sum(1 for p in (self.root / DONE).glob("*.json") if known(p.stem))
        now = time.time()
        claimed = 0
        for p in (self.root / CLAIMS).glob("*.claim"):
            if not known(p.stem):
                continue
            try:
                claim = json.loads(p.read_text())
                if claim.get("expires_unix", 0) > now:
                    claimed += 1
            except (OSError, json.JSONDecodeError):
                continue
        return QueueStats(total=total, claimed=claimed, done=done)

    # -- garbage collection ---------------------------------------------------
    def gc(
        self,
        max_age_s: float = 7 * 86400.0,
        grace_s: float | None = None,
        dry_run: bool = False,
    ) -> dict[str, int]:
        """Collect the debris crashed or long-finished drains leave behind.

        Three families, each safe to remove by protocol argument:

        * ``fails/<key>.<nonce>.json`` attempt records whose task has a
          terminal ``done/`` record (the retry budget can never be consulted
          again), or older than ``max_age_s`` regardless.
        * orphaned claim tombstones (``claims/.<key>.<hex>.tomb``): private
          to one steal-verify call, normally unlinked within milliseconds —
          an old one means its host died mid-break. Each is *audited* before
          retirement: a tombstone still holding a live claim for a task with
          no claim file and no done record is restored no-clobber (finishing
          the dead host's interrupted protocol step) rather than deleted;
          the usual long-expired case is unlinked. Worst case of retiring a
          tombstone is a re-run of an idempotent task, never corrupted state.
        * atomic-write scratch (``.*.tmp`` under any subdir, ``*.renew``
          under claims/): the real record, if any, was installed by
          ``os.replace``/``os.link``, so an old leftover is pure debris.

        Tombstones and scratch younger than ``grace_s`` (default 2x lease)
        are left alone — their owner may be mid-call. Never touches task
        records, live claims, or done records. Returns removal counts;
        ``dry_run`` counts without removing.
        """
        now = time.time()
        grace = 2.0 * self.lease_s if grace_s is None else float(grace_s)
        out = {"fails_purged": 0, "tombs_retired": 0, "tombs_restored": 0,
               "scratch_purged": 0}

        def age(p: Path) -> float:
            try:
                return now - p.stat().st_mtime
            except OSError:
                return -1.0  # vanished under us: another host collected it

        done = {p.stem for p in (self.root / DONE).glob("*.json")}
        for p in (self.root / FAILS).glob("*.json"):
            if p.name.startswith("."):
                continue  # scratch, handled below
            key = p.name[: -len(".json")].rsplit(".", 1)[0]
            if key in done or age(p) > max_age_s:
                out["fails_purged"] += 1
                if not dry_run:
                    p.unlink(missing_ok=True)
        for p in (self.root / CLAIMS).iterdir():
            name = p.name
            if not (name.endswith(".tomb") and name.startswith(".")):
                continue
            a = age(p)
            if a < 0 or a <= grace:
                continue
            key = name[1:].rsplit(".", 2)[0]
            try:
                content: dict[str, Any] | None = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                content = None
            live = content is not None and content.get("expires_unix", 0) > now
            if live and key not in done and not self._claim_path(key).exists():
                out["tombs_restored"] += 1
                if not dry_run:
                    self._restore_claim(key, p)
                continue
            out["tombs_retired"] += 1
            if not dry_run:
                p.unlink(missing_ok=True)
        for sub in (TASKS, CLAIMS, FAILS, DONE):
            for p in (self.root / sub).iterdir():
                scratch = (
                    p.name.startswith(".") and not p.name.endswith(".tomb")
                ) or (sub == CLAIMS and p.name.endswith(".renew"))
                if not scratch:
                    continue
                a = age(p)
                if a > grace:
                    out["scratch_purged"] += 1
                    if not dry_run:
                        p.unlink(missing_ok=True)
        return out

    def progress(self) -> dict[str, Any]:
        """Live per-host view for dashboards: who holds claims, who finished
        what. One directory scan, no payload reads."""
        now = time.time()
        claimed_by: dict[str, int] = {}
        for p in (self.root / CLAIMS).glob("*.claim"):
            try:
                claim = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            if claim.get("expires_unix", 0) > now:
                owner = str(claim.get("owner", "?"))
                claimed_by[owner] = claimed_by.get(owner, 0) + 1
        done_by: dict[str, int] = {}
        failed = 0
        n_done = 0
        for p in (self.root / DONE).glob("*.json"):
            n_done += 1
            try:
                rec = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            owner = str(rec.get("owner", "?"))
            done_by[owner] = done_by.get(owner, 0) + 1
            if rec.get("status") != "ok":
                failed += 1
        total = sum(1 for _ in (self.root / TASKS).glob("*.json"))
        return {
            "total": total,
            "done": n_done,
            "failed": failed,
            "claimed_by": claimed_by,
            "done_by": done_by,
        }


def drain(
    queue: FileQueue,
    specs_by_key: dict[str, TaskSpec],
    execute: Callable[[TaskSpec, Callable[[], None]], Any],
    on_result: Callable[[str, str, Any], None] | None = None,
    idle_rounds: int = 3,
    idle_sleep_s: float = 0.2,
    max_attempts: int = 1,
) -> dict[str, str]:
    """Worker loop: claim -> execute (with lease heartbeat) -> mark done.

    Returns {key: status} for the tasks *this* worker completed. Multiple
    hosts call this concurrently on the same queue directory; termination is
    detected by observing ``idle_rounds`` consecutive scans with no claimable
    work and no live foreign claims outstanding. Keys published by a matrix
    version this worker doesn't have (``spec is None``) are skipped AND
    excluded from the termination accounting — they can never become
    claimable here, so counting them would spin the loop forever.

    A failed execution is terminal only once ``max_attempts`` failures are on
    record across all hosts (see :meth:`FileQueue.record_failure`); before
    that the claim is released so any host — this one included — can retry.
    The terminal ``done/<key>.json`` carries the original error + traceback.
    """
    completed: dict[str, str] = {}
    known = set(specs_by_key)
    idle = 0
    warned_foreign = False
    while idle < idle_rounds:
        progressed = False
        pending = queue.pending_keys()
        n_foreign = sum(1 for k in pending if k not in known)
        if n_foreign and not warned_foreign:
            warned_foreign = True
            log.warning(
                "file-queue %s: skipping %d task(s) published by a foreign "
                "matrix version", queue.root, n_foreign,
            )
        for key in pending:
            spec = specs_by_key.get(key)
            if spec is None:
                continue  # published by a matrix version we don't have
            if queue.is_done(key):
                continue
            if not queue.try_claim(key):
                continue
            if queue.is_done(key):
                # The previous owner finished and released between our
                # is_done check and this claim (mark_done publishes the done
                # record before releasing, so it is visible now). Don't
                # re-run a completed task.
                queue.release(key)
                continue
            progressed = True

            def beat(k: str = key) -> None:
                queue.renew(k)

            try:
                value = execute(spec, beat)
                queue.mark_done(key, "ok")
                completed[key] = "ok"
                if on_result is not None:
                    on_result(key, "ok", value)
            except Exception as e:  # noqa: BLE001 - task isolation by design
                import traceback as _tb

                error = f"{type(e).__qualname__}: {e}"
                terminal = queue.finalize_failure(
                    key, error, _tb.format_exc(), max_attempts=max_attempts
                )
                if terminal is not None:
                    completed[key] = "failed"
                    if on_result is not None:
                        on_result(key, "failed", e)
        if progressed:
            idle = 0
        else:
            stats = queue.stats(keys=known)
            if stats.available <= 0 and stats.claimed == 0:
                idle += 1
            time.sleep(idle_sleep_s)
    return completed


def _cli(argv: Sequence[str] | None = None) -> int:
    """``python -m repro.core.filequeue`` — queue maintenance from cron or by
    hand on the shared filesystem, no engine import required."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.filequeue",
        description="Maintenance tools for shared-filesystem task queues.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    g = sub.add_parser(
        "gc", help="purge stale attempt records and orphaned lease debris"
    )
    g.add_argument("queue_dir")
    g.add_argument(
        "--max-age-s", type=float, default=7 * 86400.0,
        help="fail records older than this are stale even for unfinished tasks",
    )
    g.add_argument(
        "--grace-s", type=float, default=None,
        help="tombstone/scratch grace window (default: 2x lease)",
    )
    g.add_argument("--lease-s", type=float, default=120.0)
    g.add_argument("--dry-run", action="store_true")
    s = sub.add_parser("stats", help="queue totals")
    s.add_argument("queue_dir")
    s.add_argument(
        "--json", action="store_true",
        help="emit one JSON object (totals + per-host progress) for scripts",
    )
    args = ap.parse_args(argv)
    if not os.path.isdir(args.queue_dir):
        ap.error(f"not a queue directory: {args.queue_dir}")
    if args.cmd == "gc":
        q = FileQueue(args.queue_dir, lease_s=args.lease_s)
        out = q.gc(
            max_age_s=args.max_age_s, grace_s=args.grace_s, dry_run=args.dry_run
        )
        tag = " (dry run)" if args.dry_run else ""
        print(", ".join(f"{k}={v}" for k, v in out.items()) + tag)
    else:
        q = FileQueue(args.queue_dir)
        st = q.stats()
        if args.json:
            prog = q.progress()
            print(json.dumps({
                "total": st.total,
                "claimed": st.claimed,
                "done": st.done,
                "available": st.available,
                "failed": prog.get("failed", 0),
                "claimed_by": prog.get("claimed_by", {}),
                "done_by": prog.get("done_by", {}),
            }, sort_keys=True))
        else:
            print(
                f"total={st.total} claimed={st.claimed} done={st.done} "
                f"available={st.available}"
            )
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess test
    raise SystemExit(_cli())
