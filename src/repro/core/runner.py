"""Parallel task runner: retries, hard timeouts, straggler speculation.

Thread mode is the default — the heavy tasks in this framework (XLA
lower/compile, filesystem IO, JAX dispatch) all release the GIL, so threads
give real parallelism while sharing the in-process device state. Process mode
exists for python-bound workloads (requires the experiment function and task
parameters to be picklable / module-level).

Fault model (beyond the paper, needed at cluster scale):
  * a task raising       -> captured traceback, retried up to the budget
  * a task hanging       -> hard timeout, the attempt is abandoned (the thread
                            is left to die with the process), retried/marked
  * a straggler          -> speculative duplicate attempt once the runtime
                            exceeds ``straggler_factor`` x median of completed
                            peers; first finisher wins, tasks must be
                            idempotent (they are: pure functions + atomic
                            caches + versioned checkpoints)
  * the whole host dying -> handled one level up by the file-queue runner
                            (lease expiry) and by task checkpoints
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from .cache import BaseCache, NullCache
from .matrix import TaskSpec
from .notifications import Event, NotificationProvider
from .task import Context, TaskCheckpointStore, TaskResult


@dataclass
class RunnerConfig:
    max_workers: int | None = None  # None -> os.cpu_count()
    mode: str = "thread"  # "thread" | "process"
    retries: int = 1  # extra attempts after the first failure
    retry_backoff_s: float = 0.25
    task_timeout_s: float | None = None  # hard per-attempt timeout
    straggler_factor: float = 3.0
    straggler_min_s: float = 30.0
    enable_speculation: bool = True
    max_speculative: int = 4  # concurrent duplicate attempts across the run
    fail_fast: bool = False
    poll_interval_s: float = 0.05

    def resolved_workers(self) -> int:
        if self.max_workers is not None:
            return max(1, self.max_workers)
        return max(1, os.cpu_count() or 1)


@dataclass
class _Attempt:
    spec: TaskSpec
    number: int  # 1-based attempt number
    future: cf.Future
    started: float
    speculative: bool = False
    last_beat: float = field(default_factory=time.time)
    abandoned: bool = False


def _run_task(
    func: Callable[[Context], Any],
    spec: TaskSpec,
    ckpt_root: str | None,
    attempt: int,
    beat: Callable[[], None] | None,
    progress_cb: Callable[[str], None] | None,
) -> Any:
    ckpts = TaskCheckpointStore(ckpt_root, spec.key) if ckpt_root else None
    ctx = Context(
        spec=spec,
        checkpoints=ckpts,
        attempt=attempt,
        progress_cb=progress_cb,
        _heartbeat=beat,
    )
    return func(ctx)


class Runner:
    """Executes a list of TaskSpecs under a RunnerConfig."""

    def __init__(
        self,
        func: Callable[[Context], Any],
        cache: BaseCache | None = None,
        provider: NotificationProvider | None = None,
        config: RunnerConfig | None = None,
        checkpoint_root: str | None = None,
    ):
        self.func = func
        # NOT `cache or NullCache()`: an empty FsCache is len()==0 == falsy.
        self.cache = cache if cache is not None else NullCache()
        self.provider = provider
        self.config = config or RunnerConfig()
        self.checkpoint_root = checkpoint_root
        self.stats: dict[str, Any] = {}

    # -- notifications ------------------------------------------------------
    def _notify(self, kind: str, message: str, **payload: Any) -> None:
        if self.provider is None:
            return
        try:
            self.provider.notify(Event(kind=kind, message=message, payload=payload))
        except Exception:
            pass  # providers must never take the run down

    # -- main entry -----------------------------------------------------------
    def run(self, specs: Sequence[TaskSpec], force: bool = False) -> list[TaskResult]:
        cfg = self.config
        t_run0 = time.time()
        results: dict[str, TaskResult] = {}
        self._notify("run_started", f"{len(specs)} tasks, {cfg.resolved_workers()} workers")

        # 1) serve from cache
        to_run: list[TaskSpec] = []
        for spec in specs:
            entry = None if force else self.cache.get(spec.key)
            if entry is not None:
                results[spec.key] = TaskResult(
                    spec=spec, status="cached", value=entry.value, wall_s=0.0
                )
            else:
                to_run.append(spec)

        if to_run:
            if cfg.mode == "process":
                self._run_processes(to_run, results)
            else:
                self._run_threads(to_run, results)

        ordered = [results[s.key] for s in specs if s.key in results]
        n_ok = sum(1 for r in ordered if r.ok)
        n_failed = len(ordered) - n_ok
        wall = time.time() - t_run0
        self.stats = {
            "tasks": len(specs),
            "ok": n_ok,
            "failed": n_failed,
            "cached": sum(1 for r in ordered if r.status == "cached"),
            "wall_s": wall,
            "speculative_launched": self.stats.get("speculative_launched", 0),
        }
        self._notify(
            "run_finished",
            f"{n_ok} ok / {n_failed} failed in {wall:.1f}s",
            **{k: v for k, v in self.stats.items() if k != "tasks"},
        )
        return ordered

    # -- thread mode (full feature set) ---------------------------------------
    def _run_threads(
        self, specs: Sequence[TaskSpec], results: dict[str, TaskResult]
    ) -> None:
        cfg = self.config
        n_spec_launched = 0
        failures_left = {s.key: cfg.retries for s in specs}
        pending: list[TaskSpec] = list(specs)
        retry_at: list[tuple[float, TaskSpec, int]] = []  # (when, spec, next_attempt_no)
        attempts: dict[str, list[_Attempt]] = {}
        done_keys: set[str] = set()
        completed_durations: list[float] = []
        lock = threading.Lock()

        def make_beat(holder: _Attempt) -> Callable[[], None]:
            def beat() -> None:
                holder.last_beat = time.time()

            return beat

        pool = cf.ThreadPoolExecutor(max_workers=cfg.resolved_workers())
        try:

            def submit(spec: TaskSpec, number: int, speculative: bool = False) -> None:
                holder = _Attempt(
                    spec=spec,
                    number=number,
                    future=None,  # type: ignore[arg-type]
                    started=time.time(),
                    speculative=speculative,
                )
                holder.future = pool.submit(
                    _run_task,
                    self.func,
                    spec,
                    self.checkpoint_root,
                    number,
                    make_beat(holder),
                    None,
                )
                attempts.setdefault(spec.key, []).append(holder)
                self._notify(
                    "task_started",
                    spec.describe() + (" [speculative]" if speculative else ""),
                    key=spec.key,
                    attempt=number,
                )

            for spec in pending:
                submit(spec, 1)
            pending.clear()

            def record_success(att: _Attempt, value: Any) -> None:
                with lock:
                    if att.spec.key in done_keys:
                        return
                    done_keys.add(att.spec.key)
                wall = time.time() - att.started
                completed_durations.append(wall)
                res = TaskResult(
                    spec=att.spec,
                    status="ok",
                    value=value,
                    attempts=att.number,
                    started_unix=att.started,
                    wall_s=wall,
                    speculative=att.speculative,
                )
                results[att.spec.key] = res
                try:
                    self.cache.put(
                        att.spec.key,
                        value,
                        manifest={
                            "params": {
                                k: getattr(v, "__name__", None) or str(v)
                                for k, v in att.spec.params.items()
                            },
                            "wall_s": wall,
                            "attempts": att.number,
                        },
                    )
                except Exception as e:
                    self._notify("cache_error", f"{att.spec.key[:12]}: {e}")
                if self.provider is not None:
                    try:
                        self.provider.task_finished(res)
                    except Exception:
                        pass

            def record_failure(att: _Attempt, exc: BaseException | None, status: str) -> None:
                """Handle a failed/timed-out attempt: retry or finalise."""
                key = att.spec.key
                with lock:
                    if key in done_keys:
                        return
                live_twins = [
                    a
                    for a in attempts.get(key, [])
                    if a is not att and not a.future.done() and not a.abandoned
                ]
                if live_twins:
                    return  # a speculative duplicate is still running; let it finish
                if failures_left[key] > 0:
                    failures_left[key] -= 1
                    next_no = att.number + 1
                    self._notify(
                        "task_retry",
                        f"{att.spec.describe()} attempt {att.number} {status}; retrying",
                        key=key,
                        attempt=next_no,
                    )
                    retry_at.append((time.time() + self.config.retry_backoff_s, att.spec, next_no))
                    return
                with lock:
                    done_keys.add(key)
                if exc is not None:
                    res = TaskResult.from_exception(att.spec, exc, att.number, att.started)
                else:
                    res = TaskResult(
                        spec=att.spec,
                        status=status,
                        error=f"attempt exceeded {self.config.task_timeout_s}s",
                        attempts=att.number,
                        started_unix=att.started,
                        wall_s=time.time() - att.started,
                    )
                results[key] = res
                if self.provider is not None:
                    try:
                        self.provider.task_finished(res)
                    except Exception:
                        pass

            # -- supervision loop ---------------------------------------------
            while True:
                with lock:
                    n_done = len(done_keys)
                if n_done == len(specs):
                    break
                if cfg.fail_fast and any(not r.ok for r in results.values()):
                    break

                now = time.time()
                # due retries
                due = [r for r in retry_at if r[0] <= now]
                for item in due:
                    retry_at.remove(item)
                    _, spec, number = item
                    if spec.key not in done_keys:
                        submit(spec, number)

                live: list[_Attempt] = [
                    a
                    for atts in attempts.values()
                    for a in atts
                    if not a.future.done() and not a.abandoned
                ]

                # hard timeouts
                if cfg.task_timeout_s is not None:
                    for att in live:
                        if now - att.started > cfg.task_timeout_s:
                            att.abandoned = True
                            att.future.cancel()
                            self._notify(
                                "task_timeout",
                                f"{att.spec.describe()} abandoned after "
                                f"{cfg.task_timeout_s:.1f}s",
                                key=att.spec.key,
                            )
                            record_failure(att, None, "timeout")

                # straggler speculation
                if (
                    cfg.enable_speculation
                    and len(completed_durations) >= 3
                    and n_spec_launched < cfg.max_speculative
                ):
                    median = statistics.median(completed_durations)
                    threshold = max(cfg.straggler_min_s, cfg.straggler_factor * median)
                    for att in live:
                        if att.speculative or att.spec.key in done_keys:
                            continue
                        twins = attempts.get(att.spec.key, [])
                        if sum(1 for a in twins if not a.future.done()) > 1:
                            continue  # already speculated
                        if now - att.started > threshold:
                            n_spec_launched += 1
                            self.stats["speculative_launched"] = n_spec_launched
                            self._notify(
                                "straggler_respawned",
                                f"{att.spec.describe()} running {now - att.started:.1f}s "
                                f"(median {median:.1f}s); launching duplicate",
                                key=att.spec.key,
                            )
                            submit(att.spec, att.number, speculative=True)
                            if n_spec_launched >= cfg.max_speculative:
                                break

                # harvest finished futures
                finished = [
                    a
                    for atts in attempts.values()
                    for a in atts
                    if a.future.done() and not a.abandoned and not getattr(a, "_seen", False)
                ]
                for att in finished:
                    att._seen = True  # type: ignore[attr-defined]
                    if att.future.cancelled():
                        continue
                    exc = att.future.exception()
                    if exc is None:
                        record_success(att, att.future.result())
                    else:
                        self._notify(
                            "task_attempt_failed",
                            f"{att.spec.describe()} attempt {att.number}: {exc}",
                            key=att.spec.key,
                        )
                        record_failure(att, exc, "failed")

                if not finished and not due:
                    time.sleep(cfg.poll_interval_s)

            # drop any still-running abandoned attempts on the floor: cancel
            # what never started and do NOT wait for hung threads (they are
            # joined at interpreter exit; the fleet answer is process kill).
            for atts in attempts.values():
                for a in atts:
                    if not a.future.done():
                        a.future.cancel()
        finally:
            pool.shutdown(wait=False, cancel_futures=True)

    # -- process mode (no speculation/heartbeat; picklable funcs only) --------
    def _run_processes(
        self, specs: Sequence[TaskSpec], results: dict[str, TaskResult]
    ) -> None:
        cfg = self.config
        with cf.ProcessPoolExecutor(max_workers=cfg.resolved_workers()) as pool:
            fut_to_spec: dict[cf.Future, tuple[TaskSpec, float, int]] = {}
            for spec in specs:
                fut = pool.submit(_run_task, self.func, spec, self.checkpoint_root, 1, None, None)
                fut_to_spec[fut] = (spec, time.time(), 1)
            failures_left = {s.key: cfg.retries for s in specs}
            while fut_to_spec:
                done, _ = cf.wait(
                    list(fut_to_spec), timeout=1.0, return_when=cf.FIRST_COMPLETED
                )
                for fut in done:
                    spec, started, number = fut_to_spec.pop(fut)
                    exc = fut.exception()
                    if exc is None:
                        value = fut.result()
                        res = TaskResult(
                            spec=spec,
                            status="ok",
                            value=value,
                            attempts=number,
                            started_unix=started,
                            wall_s=time.time() - started,
                        )
                        results[spec.key] = res
                        try:
                            self.cache.put(spec.key, value, manifest={"wall_s": res.wall_s})
                        except Exception:
                            pass
                    elif failures_left[spec.key] > 0:
                        failures_left[spec.key] -= 1
                        nf = pool.submit(
                            _run_task, self.func, spec, self.checkpoint_root, number + 1, None, None
                        )
                        fut_to_spec[nf] = (spec, time.time(), number + 1)
                    else:
                        results[spec.key] = TaskResult.from_exception(
                            spec, exc, number, started
                        )
                    if self.provider is not None and spec.key in results:
                        try:
                            self.provider.task_finished(results[spec.key])
                        except Exception:
                            pass
