"""Parallel task runner: streaming results, retries, hard timeouts,
straggler speculation.

Thread mode is the default — the heavy tasks in this framework (XLA
lower/compile, filesystem IO, JAX dispatch) all release the GIL, so threads
give real parallelism while sharing the in-process device state. Process mode
exists for python-bound workloads (requires the experiment function and task
parameters to be picklable / module-level).

``stream()`` is the primary entry: a generator that yields each task's final
``TaskResult`` the moment it is known — cache hits first, then live results
in completion order. ``run()`` is a thin collector over it that restores
matrix order.

Fault model (beyond the paper, needed at cluster scale):
  * a task raising       -> captured traceback, retried up to the budget
  * a task hanging       -> hard timeout, the attempt is abandoned (the thread
                            is left to die with the process), retried/marked
  * a straggler          -> speculative duplicate attempt once the runtime
                            exceeds ``straggler_factor`` x median of completed
                            peers; first finisher wins, tasks must be
                            idempotent (they are: pure functions + atomic
                            caches + versioned checkpoints)
  * the whole host dying -> handled one level up by the file-queue runner
                            (lease expiry) and by task checkpoints

Attempt accounting is per *task*, not per submission: every submission
(primary, retry, or speculative duplicate) is one attempt, and a task is
finalised as failed once ``retries + 1`` attempts have failed — a failed
primary whose speculative twin also fails consumes two entries of the
budget, not one. All finalisation decisions happen under one lock.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

from .cache import BaseCache, NullCache, param_repr
from .matrix import TaskSpec
from .notifications import Event, NotificationProvider
from .task import Context, TaskCheckpointStore, TaskResult


@dataclass
class RunnerConfig:
    max_workers: int | None = None  # None -> os.cpu_count()
    mode: str = "thread"  # "thread" | "process"
    retries: int = 1  # extra attempts after the first failure
    retry_backoff_s: float = 0.25
    task_timeout_s: float | None = None  # hard per-attempt timeout
    straggler_factor: float = 3.0
    straggler_min_s: float = 30.0
    enable_speculation: bool = True
    max_speculative: int = 4  # concurrent duplicate attempts across the run
    fail_fast: bool = False
    poll_interval_s: float = 0.05

    def resolved_workers(self) -> int:
        if self.max_workers is not None:
            return max(1, self.max_workers)
        return max(1, os.cpu_count() or 1)


@dataclass
class _Attempt:
    spec: TaskSpec
    number: int  # 1-based attempt number (per task, across twins/retries)
    future: cf.Future
    started: float
    speculative: bool = False
    last_beat: float = field(default_factory=time.time)
    abandoned: bool = False
    seen: bool = False  # harvested by the supervision loop
    finished: float = 0.0  # stamped by a done-callback, not at harvest time

    @property
    def wall_s(self) -> float:
        # Harvest may lag completion when a stream consumer is slow; the
        # done-callback stamp keeps task timings honest regardless.
        return (self.finished or time.time()) - self.started


def _run_task(
    func: Callable[[Context], Any],
    spec: TaskSpec,
    ckpt_root: str | None,
    attempt: int,
    beat: Callable[[], None] | None,
    progress_cb: Callable[[str], None] | None,
) -> Any:
    ckpts = TaskCheckpointStore(ckpt_root, spec.key) if ckpt_root else None
    ctx = Context(
        spec=spec,
        checkpoints=ckpts,
        attempt=attempt,
        progress_cb=progress_cb,
        _heartbeat=beat,
    )
    return func(ctx)


class Runner:
    """Executes a list of TaskSpecs under a RunnerConfig."""

    def __init__(
        self,
        func: Callable[[Context], Any],
        cache: BaseCache | None = None,
        provider: NotificationProvider | None = None,
        config: RunnerConfig | None = None,
        checkpoint_root: str | None = None,
        manifest_extra: dict[str, Any] | None = None,
    ):
        self.func = func
        # NOT `cache or NullCache()`: an empty FsCache is len()==0 == falsy.
        self.cache = cache if cache is not None else NullCache()
        self.provider = provider
        self.config = config or RunnerConfig()
        self.checkpoint_root = checkpoint_root
        # Folded into every cache manifest (e.g. the Memento namespace, so
        # per-axis invalidation can respect namespace partitions).
        self.manifest_extra = dict(manifest_extra or {})
        self.stats: dict[str, Any] = {}

    def _manifest(self, spec: TaskSpec, **extra: Any) -> dict[str, Any]:
        return {
            "params": {k: param_repr(v) for k, v in spec.params.items()},
            **self.manifest_extra,
            **extra,
        }

    # -- notifications ------------------------------------------------------
    def _notify(self, kind: str, message: str, **payload: Any) -> None:
        if self.provider is None:
            return
        try:
            self.provider.notify(Event(kind=kind, message=message, payload=payload))
        except Exception:
            pass  # providers must never take the run down

    def _notify_finished(self, res: TaskResult) -> None:
        if self.provider is None:
            return
        try:
            self.provider.task_finished(res)
        except Exception:
            pass

    # -- main entries ---------------------------------------------------------
    def run(self, specs: Sequence[TaskSpec], force: bool = False) -> list[TaskResult]:
        """Blocking collector over :meth:`stream`, restoring spec order."""
        results = {r.spec.key: r for r in self.stream(specs, force=force)}
        ordered: list[TaskResult] = []
        seen: set[str] = set()
        for s in specs:
            if s.key in results and s.key not in seen:
                seen.add(s.key)
                ordered.append(results[s.key])
        return ordered

    def stream(
        self, specs: Sequence[TaskSpec], force: bool = False
    ) -> Iterator[TaskResult]:
        """Yield each task's final TaskResult as soon as it is known.

        Cache hits are yielded immediately (before any execution starts);
        live results follow in completion order. Duplicate keys in ``specs``
        are collapsed to the first occurrence.

        The supervision loop (timeouts, retries, speculation) runs between
        yields, so it is paced by the consumer: task *timings* stay honest
        (completion is stamped by a done-callback), but a consumer that
        blocks for a long time between results delays timeout/retry
        enforcement — do heavy per-result work elsewhere, or collect with
        :meth:`run`.
        """
        cfg = self.config
        t_run0 = time.time()
        self.stats = {}
        self._notify(
            "run_started",
            f"{len(specs)} tasks, {cfg.resolved_workers()} workers",
            total=len(specs),
            workers=cfg.resolved_workers(),
            mode=cfg.mode,
        )

        n_ok = n_failed = n_cached = 0
        to_run: list[TaskSpec] = []
        seen_keys: set[str] = set()
        for spec in specs:
            if spec.key in seen_keys:
                continue
            seen_keys.add(spec.key)
            entry = None if force else self.cache.get(spec.key)
            if entry is not None:
                n_ok += 1
                n_cached += 1
                yield TaskResult(
                    spec=spec, status="cached", value=entry.value, wall_s=0.0
                )
            else:
                to_run.append(spec)

        live = (
            self._stream_processes(to_run)
            if cfg.mode == "process"
            else self._stream_threads(to_run)
        )
        try:
            for res in live:
                if res.ok:
                    n_ok += 1
                else:
                    n_failed += 1
                yield res
        finally:
            live.close()
            wall = time.time() - t_run0
            self.stats = {
                "tasks": len(seen_keys),
                "ok": n_ok,
                "failed": n_failed,
                "cached": n_cached,
                "wall_s": wall,
                "speculative_launched": self.stats.get("speculative_launched", 0),
            }
            self._notify(
                "run_finished",
                f"{n_ok} ok / {n_failed} failed in {wall:.1f}s",
                **{k: v for k, v in self.stats.items() if k != "tasks"},
            )

    # -- thread mode (full feature set) ---------------------------------------
    def _stream_threads(self, specs: Sequence[TaskSpec]) -> Iterator[TaskResult]:
        if not specs:
            return
        yield from self.stream_source(iter(specs))

    def stream_source(
        self, source: "Iterator[TaskSpec | None]"
    ) -> Iterator[TaskResult]:
        """Thread-mode streaming over an *incremental* spec source.

        ``source`` is pulled between supervision rounds: each ``TaskSpec`` it
        yields is submitted to the pool immediately; ``None`` means "nothing
        available right now, ask again next round" (the pull resumes on the
        following round); exhaustion (``StopIteration``) means no further
        specs will ever arrive. The stream terminates once the source is
        exhausted and every submitted task is finalised.

        This is what lets an external work feed — the distributed file-queue
        claim loop — drive the full local machinery (thread pool, retries,
        hard timeouts, straggler speculation) instead of a one-task-at-a-time
        loop. A plain ``iter(list_of_specs)`` reproduces :meth:`run` exactly.

        Re-yielding a key that was already finalised resets that task's
        attempt state and runs it afresh — the distributed driver uses this
        for queue-level (cross-host) retry rounds. Only re-feed a key after
        consuming its previous final result.
        """
        cfg = self.config
        n_spec_launched = 0
        attempts_failed: dict[str, int] = {}  # failed attempts per task
        submitted: dict[str, TaskSpec] = {}
        source_exhausted = False
        retry_at: list[tuple[float, TaskSpec]] = []
        attempts: dict[str, list[_Attempt]] = {}
        done_keys: set[str] = set()
        completed_durations: list[float] = []
        fresh: list[TaskResult] = []  # finalised since the last yield round
        lock = threading.Lock()

        def make_beat(holder: _Attempt) -> Callable[[], None]:
            def beat() -> None:
                holder.last_beat = time.time()

            return beat

        pool = cf.ThreadPoolExecutor(max_workers=cfg.resolved_workers())
        try:

            def submit(spec: TaskSpec, speculative: bool = False) -> None:
                submitted[spec.key] = spec
                attempts_failed.setdefault(spec.key, 0)
                number = len(attempts.get(spec.key, [])) + 1
                holder = _Attempt(
                    spec=spec,
                    number=number,
                    future=None,  # type: ignore[arg-type]
                    started=time.time(),
                    speculative=speculative,
                )
                holder.future = pool.submit(
                    _run_task,
                    self.func,
                    spec,
                    self.checkpoint_root,
                    number,
                    make_beat(holder),
                    None,
                )
                holder.future.add_done_callback(
                    lambda _f, h=holder: setattr(h, "finished", time.time())
                )
                attempts.setdefault(spec.key, []).append(holder)
                self._notify(
                    "task_started",
                    spec.describe() + (" [speculative]" if speculative else ""),
                    key=spec.key,
                    attempt=number,
                )

            def admit(spec: TaskSpec) -> None:
                with lock:
                    if spec.key in done_keys:
                        # Re-fed after finalisation (queue-level retry):
                        # forget the previous round's attempt state.
                        done_keys.discard(spec.key)
                        attempts_failed[spec.key] = 0
                        attempts.pop(spec.key, None)
                submit(spec)

            def record_success(att: _Attempt, value: Any) -> None:
                with lock:
                    if att.spec.key in done_keys:
                        return
                    done_keys.add(att.spec.key)
                wall = att.wall_s
                completed_durations.append(wall)
                res = TaskResult(
                    spec=att.spec,
                    status="ok",
                    value=value,
                    attempts=len(attempts.get(att.spec.key, [])) or att.number,
                    started_unix=att.started,
                    wall_s=wall,
                    speculative=att.speculative,
                )
                fresh.append(res)
                try:
                    self.cache.put(
                        att.spec.key,
                        value,
                        manifest=self._manifest(
                            att.spec, wall_s=wall, attempts=att.number
                        ),
                    )
                except Exception as e:
                    self._notify("cache_error", f"{att.spec.key[:12]}: {e}")
                self._notify_finished(res)

            def record_failure(att: _Attempt, exc: BaseException | None, status: str) -> None:
                """Handle a failed/timed-out attempt: retry or finalise.

                The whole decision — duplicate-completion check, per-task
                attempt accounting, retry-vs-finalise — happens under the
                lock so concurrent completions can neither double-finalise
                nor under-count failed attempts.
                """
                key = att.spec.key
                with lock:
                    if key in done_keys:
                        return
                    attempts_failed[key] += 1
                    live_twins = [
                        a
                        for a in attempts.get(key, [])
                        if a is not att and not a.future.done() and not a.abandoned
                    ]
                    if live_twins:
                        # A duplicate attempt is still running and may yet
                        # succeed; its completion drives the next decision.
                        # This attempt's failure stays counted above.
                        return
                    if attempts_failed[key] <= cfg.retries:
                        self._notify(
                            "task_retry",
                            f"{att.spec.describe()} attempt {att.number} {status}; retrying",
                            key=key,
                            attempt=att.number + 1,
                        )
                        retry_at.append((time.time() + cfg.retry_backoff_s, att.spec))
                        return
                    done_keys.add(key)
                total_attempts = len(attempts.get(key, [])) or att.number
                if exc is not None:
                    res = TaskResult.from_exception(att.spec, exc, total_attempts, att.started)
                else:
                    res = TaskResult(
                        spec=att.spec,
                        status=status,
                        error=f"attempt exceeded {cfg.task_timeout_s}s",
                        attempts=total_attempts,
                        started_unix=att.started,
                        wall_s=att.wall_s,
                    )
                fresh.append(res)
                self._notify_finished(res)

            # -- supervision loop ---------------------------------------------
            failed_seen = False
            while True:
                # Pull newly available work. A list source is drained whole on
                # the first round (the classic submit-everything-upfront); an
                # incremental source hands over what it has and yields None.
                if not source_exhausted:
                    while True:
                        try:
                            item = next(source)
                        except StopIteration:
                            source_exhausted = True
                            break
                        if item is None:
                            break  # nothing available this round
                        admit(item)
                with lock:
                    n_done = len(done_keys)
                if source_exhausted and n_done == len(submitted):
                    break
                if cfg.fail_fast and failed_seen:
                    break

                now = time.time()
                # due retries
                due = [r for r in retry_at if r[0] <= now]
                for item in due:
                    retry_at.remove(item)
                    _, spec = item
                    if spec.key not in done_keys:
                        submit(spec)

                live: list[_Attempt] = [
                    a
                    for atts in attempts.values()
                    for a in atts
                    if not a.future.done() and not a.abandoned
                ]

                # hard timeouts
                if cfg.task_timeout_s is not None:
                    for att in live:
                        if now - att.started > cfg.task_timeout_s:
                            att.abandoned = True
                            att.future.cancel()
                            self._notify(
                                "task_timeout",
                                f"{att.spec.describe()} abandoned after "
                                f"{cfg.task_timeout_s:.1f}s",
                                key=att.spec.key,
                            )
                            record_failure(att, None, "timeout")

                # harvest finished futures BEFORE deciding to speculate, so a
                # just-failed twin is accounted for and not treated as "this
                # task has no duplicate yet".
                finished = [
                    a
                    for atts in attempts.values()
                    for a in atts
                    if a.future.done() and not a.abandoned and not a.seen
                ]
                for att in finished:
                    att.seen = True
                    if att.future.cancelled():
                        continue
                    exc = att.future.exception()
                    if exc is None:
                        record_success(att, att.future.result())
                    else:
                        self._notify(
                            "task_attempt_failed",
                            f"{att.spec.describe()} attempt {att.number}: {exc}",
                            key=att.spec.key,
                        )
                        record_failure(att, exc, "failed")

                # straggler speculation
                if (
                    cfg.enable_speculation
                    and len(completed_durations) >= 3
                    and n_spec_launched < cfg.max_speculative
                ):
                    median = statistics.median(completed_durations)
                    threshold = max(cfg.straggler_min_s, cfg.straggler_factor * median)
                    for att in live:
                        if att.speculative or att.spec.key in done_keys:
                            continue
                        if attempts_failed[att.spec.key] > 0:
                            # Speculation is for stragglers, not flaky tasks:
                            # once an attempt has *failed*, further duplicates
                            # would just burn the retry budget.
                            continue
                        twins = attempts.get(att.spec.key, [])
                        if sum(1 for a in twins if not a.future.done()) > 1:
                            continue  # already speculated
                        if now - att.started > threshold:
                            n_spec_launched += 1
                            self.stats["speculative_launched"] = n_spec_launched
                            self._notify(
                                "straggler_respawned",
                                f"{att.spec.describe()} running {now - att.started:.1f}s "
                                f"(median {median:.1f}s); launching duplicate",
                                key=att.spec.key,
                            )
                            submit(att.spec, speculative=True)
                            if n_spec_launched >= cfg.max_speculative:
                                break

                # stream out everything finalised this round
                if fresh:
                    for res in fresh:
                        if not res.ok:
                            failed_seen = True
                        yield res
                    fresh.clear()
                elif not finished and not due:
                    time.sleep(cfg.poll_interval_s)

            for res in fresh:
                yield res
            fresh.clear()

            # drop any still-running abandoned attempts on the floor: cancel
            # what never started and do NOT wait for hung threads (they are
            # joined at interpreter exit; the fleet answer is process kill).
            for atts in attempts.values():
                for a in atts:
                    if not a.future.done():
                        a.future.cancel()
        finally:
            pool.shutdown(wait=False, cancel_futures=True)

    # -- process mode (no speculation/heartbeat; picklable funcs only) --------
    def _stream_processes(self, specs: Sequence[TaskSpec]) -> Iterator[TaskResult]:
        if not specs:
            return
        cfg = self.config
        with cf.ProcessPoolExecutor(max_workers=cfg.resolved_workers()) as pool:
            fut_to_spec: dict[cf.Future, tuple[TaskSpec, float, int]] = {}
            for spec in specs:
                fut = pool.submit(_run_task, self.func, spec, self.checkpoint_root, 1, None, None)
                fut_to_spec[fut] = (spec, time.time(), 1)
            failures_left = {s.key: cfg.retries for s in specs}
            while fut_to_spec:
                done, _ = cf.wait(
                    list(fut_to_spec), timeout=1.0, return_when=cf.FIRST_COMPLETED
                )
                for fut in done:
                    spec, started, number = fut_to_spec.pop(fut)
                    exc = fut.exception()
                    res: TaskResult | None = None
                    if exc is None:
                        value = fut.result()
                        res = TaskResult(
                            spec=spec,
                            status="ok",
                            value=value,
                            attempts=number,
                            started_unix=started,
                            wall_s=time.time() - started,
                        )
                        try:
                            self.cache.put(
                                spec.key, value,
                                manifest=self._manifest(spec, wall_s=res.wall_s),
                            )
                        except Exception:
                            pass
                    elif failures_left[spec.key] > 0:
                        failures_left[spec.key] -= 1
                        nf = pool.submit(
                            _run_task, self.func, spec, self.checkpoint_root, number + 1, None, None
                        )
                        fut_to_spec[nf] = (spec, time.time(), number + 1)
                    else:
                        res = TaskResult.from_exception(spec, exc, number, started)
                    if res is not None:
                        self._notify_finished(res)
                        yield res
