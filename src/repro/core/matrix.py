"""The configuration matrix — the core of the paper.

``ConfigMatrix`` takes the exact schema from the paper:

    {
      "parameters": {name: [value, ...], ...},
      "settings":   {constants visible to every task},
      "exclude":    [{name: value, ...}, ...],   # partial assignments to prune
    }

and expands it into the cartesian product of parameter values, skipping any
combination that matches an ``exclude`` entry (an exclude entry matches when
*all* of its key/value pairs match the combination — it may mention any
subset of the parameter names, which is the "lookup table" semantics in the
paper). Each surviving combination becomes a :class:`TaskSpec` with a stable
content hash (see :mod:`repro.core.hashing`).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Mapping, Sequence

from .exceptions import ConfigMatrixError
from .hashing import stable_hash, task_key

PARAMETERS = "parameters"
SETTINGS = "settings"
EXCLUDE = "exclude"
_ALLOWED_KEYS = {PARAMETERS, SETTINGS, EXCLUDE}


@dataclass(frozen=True)
class TaskSpec:
    """A single fully-assigned experiment, ready to run.

    ``params`` is the one-value-per-axis assignment; ``settings`` are the
    matrix-level constants; ``key`` is the stable content hash that names
    this task in caches / checkpoints / queues.
    """

    index: int
    params: dict[str, Any]
    settings: dict[str, Any]
    key: str

    def describe(self, maxlen: int = 120) -> str:
        def short(v: Any) -> str:
            s = getattr(v, "__name__", None) or str(v)
            return s if len(s) <= 40 else s[:37] + "..."

        body = ", ".join(f"{k}={short(v)}" for k, v in self.params.items())
        if len(body) > maxlen:
            body = body[: maxlen - 3] + "..."
        return f"task[{self.index}] {self.key[:12]} ({body})"


def _matches_exclude(combo: Mapping[str, Any], rule: Mapping[str, Any]) -> bool:
    """A rule matches when every (key, value) it names equals the combo's."""
    for k, v in rule.items():
        if k not in combo:
            return False
        cv = combo[k]
        if cv is v:
            continue
        try:
            if cv == v:
                continue
        except Exception:
            return False
        # Fall back to hash identity so e.g. equal dataclasses / arrays match.
        try:
            if stable_hash(cv) == stable_hash(v):
                continue
        except Exception:
            return False
        return False
    return True


@dataclass
class ConfigMatrix:
    """Validated configuration matrix with lazy task expansion."""

    parameters: dict[str, list[Any]]
    settings: dict[str, Any] = field(default_factory=dict)
    exclude: list[dict[str, Any]] = field(default_factory=list)

    @classmethod
    def from_dict(cls, matrix: Mapping[str, Any]) -> "ConfigMatrix":
        if not isinstance(matrix, Mapping):
            raise ConfigMatrixError("config matrix must be a mapping")
        unknown = set(matrix.keys()) - _ALLOWED_KEYS
        if unknown:
            raise ConfigMatrixError(
                f"unknown config matrix keys {sorted(unknown)}; "
                f"allowed: {sorted(_ALLOWED_KEYS)}"
            )
        params = matrix.get(PARAMETERS)
        if not isinstance(params, Mapping) or not params:
            raise ConfigMatrixError("'parameters' must be a non-empty mapping")
        norm_params: dict[str, list[Any]] = {}
        for name, values in params.items():
            if not isinstance(name, str) or not name:
                raise ConfigMatrixError(f"parameter name {name!r} must be a non-empty str")
            if isinstance(values, (str, bytes)) or not isinstance(values, Sequence):
                raise ConfigMatrixError(
                    f"parameter {name!r} must map to a sequence of values, "
                    f"got {type(values).__qualname__}"
                )
            values = list(values)
            if not values:
                raise ConfigMatrixError(f"parameter {name!r} has no values")
            norm_params[name] = values
        settings = dict(matrix.get(SETTINGS, {}) or {})
        exclude_raw = matrix.get(EXCLUDE, []) or []
        if isinstance(exclude_raw, Mapping):
            exclude_raw = [exclude_raw]
        excludes: list[dict[str, Any]] = []
        for i, rule in enumerate(exclude_raw):
            if not isinstance(rule, Mapping) or not rule:
                raise ConfigMatrixError(f"exclude[{i}] must be a non-empty mapping")
            bad = set(rule.keys()) - set(norm_params.keys())
            if bad:
                raise ConfigMatrixError(
                    f"exclude[{i}] names unknown parameters {sorted(bad)}"
                )
            excludes.append(dict(rule))
        return cls(parameters=norm_params, settings=settings, exclude=excludes)

    # -- shape ----------------------------------------------------------------
    @property
    def axis_names(self) -> list[str]:
        return list(self.parameters.keys())

    @property
    def cartesian_size(self) -> int:
        n = 1
        for values in self.parameters.values():
            n *= len(values)
        return n

    def __len__(self) -> int:
        return sum(1 for _ in self.tasks())

    # -- expansion ------------------------------------------------------------
    def combinations(self) -> Iterator[dict[str, Any]]:
        names = self.axis_names
        for combo in itertools.product(*(self.parameters[n] for n in names)):
            assignment = dict(zip(names, combo))
            if any(_matches_exclude(assignment, rule) for rule in self.exclude):
                continue
            yield assignment

    def tasks(self) -> Iterator[TaskSpec]:
        for i, assignment in enumerate(self.combinations()):
            yield TaskSpec(
                index=i,
                params=assignment,
                settings=dict(self.settings),
                key=task_key(assignment),
            )

    def task_list(self) -> list[TaskSpec]:
        out = list(self.tasks())
        if not out:
            raise ConfigMatrixError(
                "configuration matrix expands to zero tasks (everything excluded?)"
            )
        return out

    # -- filtering (useful for partial re-runs / sharded launchers) ------------
    def subset(self, predicate: Callable[[dict[str, Any]], bool]) -> list[TaskSpec]:
        return [t for t in self.tasks() if predicate(t.params)]

    def shard(self, shard_index: int, num_shards: int) -> list[TaskSpec]:
        """Deterministic round-robin split of the task list across launchers."""
        if not (0 <= shard_index < num_shards):
            raise ConfigMatrixError(
                f"shard_index {shard_index} out of range for {num_shards} shards"
            )
        return [t for t in self.tasks() if t.index % num_shards == shard_index]
