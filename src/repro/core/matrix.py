"""The configuration matrix — the core of the paper — plus a compositional
algebra for building large experiment sets out of small ones.

``ConfigMatrix`` takes the exact schema from the paper:

    {
      "parameters": {name: [value, ...], ...},
      "settings":   {constants visible to every task},
      "exclude":    [{name: value, ...}, ...],   # partial assignments to prune
    }

and expands it into the cartesian product of parameter values, skipping any
combination that matches an ``exclude`` entry (an exclude entry matches when
*all* of its key/value pairs match the combination — it may mention any
subset of the parameter names, which is the "lookup table" semantics in the
paper). Each surviving combination becomes a :class:`TaskSpec` with a stable
content hash (see :mod:`repro.core.hashing`) over its params *and* settings.

Matrices compose instead of being written as one giant dict:

    m1 + m2               # chain/union — concatenated, de-duplicated by task key
    m1 * m2               # cartesian product over disjoint parameter axes
    m.where(pred)         # callable exclude: keep assignments where pred(params)
    m.derive(name, fn)    # computed parameter name=fn(params), hashed into the key

Composites are lazy (nothing expands until ``tasks()``/``task_list()``) and
every operator accepts either another matrix or a paper-schema dict.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Mapping, Sequence

from .exceptions import ConfigMatrixError
from .hashing import stable_hash, task_key

PARAMETERS = "parameters"
SETTINGS = "settings"
EXCLUDE = "exclude"
_ALLOWED_KEYS = {PARAMETERS, SETTINGS, EXCLUDE}


@dataclass(frozen=True)
class TaskSpec:
    """A single fully-assigned experiment, ready to run.

    ``params`` is the one-value-per-axis assignment; ``settings`` are the
    matrix-level constants; ``key`` is the stable content hash that names
    this task in caches / checkpoints / queues.
    """

    index: int
    params: dict[str, Any]
    settings: dict[str, Any]
    key: str

    def describe(self, maxlen: int = 120) -> str:
        def short(v: Any) -> str:
            s = getattr(v, "__name__", None) or str(v)
            return s if len(s) <= 40 else s[:37] + "..."

        body = ", ".join(f"{k}={short(v)}" for k, v in self.params.items())
        if len(body) > maxlen:
            body = body[: maxlen - 3] + "..."
        return f"task[{self.index}] {self.key[:12]} ({body})"


def _matches_exclude(combo: Mapping[str, Any], rule: Mapping[str, Any]) -> bool:
    """A rule matches when every (key, value) it names equals the combo's."""
    for k, v in rule.items():
        if k not in combo:
            return False
        cv = combo[k]
        if cv is v:
            continue
        try:
            if cv == v:
                continue
        except Exception:
            return False
        # Fall back to hash identity so e.g. equal dataclasses / arrays match.
        try:
            if stable_hash(cv) == stable_hash(v):
                continue
        except Exception:
            return False
        return False
    return True


def as_matrix(obj: "MatrixBase | Mapping[str, Any]") -> "MatrixBase":
    """Coerce a paper-schema dict (or pass through a matrix) for composition."""
    if isinstance(obj, MatrixBase):
        return obj
    if isinstance(obj, Mapping):
        return ConfigMatrix.from_dict(obj)
    raise ConfigMatrixError(
        f"expected a ConfigMatrix (or paper-schema dict), got {type(obj).__qualname__}"
    )


class MatrixBase:
    """Shared algebra + expansion for leaf and composite matrices.

    Subclasses implement :meth:`assignments`, yielding ``(params, settings)``
    pairs; everything else (operators, task expansion, de-dup, sharding) is
    generic. Expansion is lazy — composites hold references, not task lists.
    """

    # -- expansion (subclass contract) -----------------------------------
    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        raise NotImplementedError  # pragma: no cover - interface

    @property
    def axis_names(self) -> list[str]:
        raise NotImplementedError  # pragma: no cover - interface

    # -- algebra -----------------------------------------------------------
    def __add__(self, other: "MatrixBase | Mapping[str, Any]") -> "ChainMatrix":
        return ChainMatrix(self, as_matrix(other))

    def __mul__(self, other: "MatrixBase | Mapping[str, Any]") -> "ProductMatrix":
        return ProductMatrix(self, as_matrix(other))

    def where(self, predicate: Callable[[dict[str, Any]], bool]) -> "WhereMatrix":
        """Keep only assignments for which ``predicate(params)`` is truthy —
        the callable complement of the paper's dict ``exclude`` rules."""
        return WhereMatrix(self, predicate)

    def derive(self, name: str, fn: Callable[[dict[str, Any]], Any]) -> "DerivedMatrix":
        """Add a computed parameter ``name = fn(params)`` to every assignment.

        The derived value is part of the task's parameter dict and therefore
        of its cache key — deriving with a different function re-runs."""
        return DerivedMatrix(self, name, fn)

    # -- task expansion ----------------------------------------------------
    def tasks(self, namespace: str | None = None) -> Iterator[TaskSpec]:
        """Expand to TaskSpecs, de-duplicated by task key (first wins)."""
        seen: set[str] = set()
        index = 0
        for params, settings in self.assignments():
            key = task_key(params, settings, namespace)
            if key in seen:
                continue
            seen.add(key)
            yield TaskSpec(
                index=index, params=dict(params), settings=dict(settings), key=key
            )
            index += 1

    def task_list(self, namespace: str | None = None) -> list[TaskSpec]:
        out = list(self.tasks(namespace))
        if not out:
            raise ConfigMatrixError(
                "configuration matrix expands to zero tasks (everything excluded?)"
            )
        return out

    def __len__(self) -> int:
        return sum(1 for _ in self.tasks())

    def __iter__(self) -> Iterator[TaskSpec]:
        """Iterate expanded TaskSpecs — so views returned by ``shard()`` /
        ``subset()`` keep behaving like the task lists they used to be."""
        return self.tasks()

    # -- filtering (useful for partial re-runs / sharded launchers) ------------
    def subset(self, predicate: Callable[[dict[str, Any]], bool]) -> "TaskViewMatrix":
        """Lazy task-level filter. The result is a matrix: chain it with
        ``+``/``*``/``.where()``/``.derive()``, or iterate / ``.tasks()``
        for the (index-preserving) TaskSpec view."""
        return TaskViewMatrix(self, lambda t: predicate(t.params))

    def shard(self, shard_index: int, num_shards: int) -> "TaskViewMatrix":
        """Deterministic round-robin split of the task list across launchers.

        Returns a lazy matrix view (composable like any other); task
        indices and keys are those of the base matrix."""
        if not (0 <= shard_index < num_shards):
            raise ConfigMatrixError(
                f"shard_index {shard_index} out of range for {num_shards} shards"
            )
        return TaskViewMatrix(self, lambda t: t.index % num_shards == shard_index)


@dataclass
class ConfigMatrix(MatrixBase):
    """Validated leaf configuration matrix (the paper schema)."""

    parameters: dict[str, list[Any]]
    settings: dict[str, Any] = field(default_factory=dict)
    exclude: list[dict[str, Any]] = field(default_factory=list)

    @classmethod
    def from_dict(cls, matrix: Mapping[str, Any]) -> "ConfigMatrix":
        if not isinstance(matrix, Mapping):
            raise ConfigMatrixError("config matrix must be a mapping")
        unknown = set(matrix.keys()) - _ALLOWED_KEYS
        if unknown:
            raise ConfigMatrixError(
                f"unknown config matrix keys {sorted(unknown)}; "
                f"allowed: {sorted(_ALLOWED_KEYS)}"
            )
        params = matrix.get(PARAMETERS)
        if not isinstance(params, Mapping) or not params:
            raise ConfigMatrixError("'parameters' must be a non-empty mapping")
        norm_params: dict[str, list[Any]] = {}
        for name, values in params.items():
            if not isinstance(name, str) or not name:
                raise ConfigMatrixError(f"parameter name {name!r} must be a non-empty str")
            if isinstance(values, (str, bytes)) or not isinstance(values, Sequence):
                raise ConfigMatrixError(
                    f"parameter {name!r} must map to a sequence of values, "
                    f"got {type(values).__qualname__}"
                )
            values = list(values)
            if not values:
                raise ConfigMatrixError(f"parameter {name!r} has no values")
            norm_params[name] = values
        settings = dict(matrix.get(SETTINGS, {}) or {})
        exclude_raw = matrix.get(EXCLUDE, []) or []
        if isinstance(exclude_raw, Mapping):
            exclude_raw = [exclude_raw]
        excludes: list[dict[str, Any]] = []
        for i, rule in enumerate(exclude_raw):
            if not isinstance(rule, Mapping) or not rule:
                raise ConfigMatrixError(f"exclude[{i}] must be a non-empty mapping")
            bad = set(rule.keys()) - set(norm_params.keys())
            if bad:
                raise ConfigMatrixError(
                    f"exclude[{i}] names unknown parameters {sorted(bad)}"
                )
            excludes.append(dict(rule))
        return cls(parameters=norm_params, settings=settings, exclude=excludes)

    # -- shape ----------------------------------------------------------------
    @property
    def axis_names(self) -> list[str]:
        return list(self.parameters.keys())

    @property
    def cartesian_size(self) -> int:
        n = 1
        for values in self.parameters.values():
            n *= len(values)
        return n

    def __len__(self) -> int:
        # Faster than the generic path: leaf combinations need no hashing.
        return sum(1 for _ in self.combinations())

    # -- expansion ------------------------------------------------------------
    def combinations(self) -> Iterator[dict[str, Any]]:
        names = self.axis_names
        for combo in itertools.product(*(self.parameters[n] for n in names)):
            assignment = dict(zip(names, combo))
            if any(_matches_exclude(assignment, rule) for rule in self.exclude):
                continue
            yield assignment

    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        for combo in self.combinations():
            yield combo, self.settings


class TaskViewMatrix(MatrixBase):
    """Lazy task-level view of a base matrix (``shard()`` / ``subset()``).

    Filtering happens on expanded :class:`TaskSpec`s (the only place shard
    indices exist), but the view is still a :class:`MatrixBase`: it chains
    with ``+``, crosses with ``*``, and filters further with ``where()`` —
    composition re-expands through :meth:`assignments`, while direct
    iteration / :meth:`tasks` preserves the base matrix's task indices and
    keys (so a shard's tasks keep the identity they'd have in the full
    run)."""

    def __init__(self, base: MatrixBase, keep: Callable[[TaskSpec], bool]):
        self.base = base
        self.keep = keep

    @property
    def axis_names(self) -> list[str]:
        return self.base.axis_names

    def tasks(self, namespace: str | None = None) -> Iterator[TaskSpec]:
        for t in self.base.tasks(namespace):
            if self.keep(t):
                yield t

    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        for t in self.base.tasks():
            if self.keep(t):
                yield t.params, t.settings


class ChainMatrix(MatrixBase):
    """Union/concatenation: every part's tasks in order, de-duped by key."""

    def __init__(self, *parts: MatrixBase):
        flat: list[MatrixBase] = []
        for p in parts:
            if isinstance(p, ChainMatrix):
                flat.extend(p.parts)  # keep chains shallow: (a+b)+c == a+b+c
            else:
                flat.append(p)
        self.parts = flat

    @property
    def axis_names(self) -> list[str]:
        names: dict[str, None] = {}
        for p in self.parts:
            for n in p.axis_names:
                names.setdefault(n)
        return list(names)

    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        for p in self.parts:
            yield from p.assignments()


class ProductMatrix(MatrixBase):
    """Cartesian product over *disjoint* parameter axes.

    Settings merge; a key present on both sides with different values is an
    error (silently preferring one side would change task identities)."""

    def __init__(self, left: MatrixBase, right: MatrixBase):
        overlap = set(left.axis_names) & set(right.axis_names)
        if overlap:
            raise ConfigMatrixError(
                f"matrix product requires disjoint parameter axes; "
                f"both sides define {sorted(overlap)}"
            )
        self.left = left
        self.right = right

    @property
    def axis_names(self) -> list[str]:
        return list(self.left.axis_names) + list(self.right.axis_names)

    @staticmethod
    def _merge_settings(a: dict[str, Any], b: dict[str, Any]) -> dict[str, Any]:
        merged = dict(a)
        for k, v in b.items():
            if k in merged:
                try:
                    same = merged[k] == v
                except Exception:
                    same = merged[k] is v
                if not same:
                    raise ConfigMatrixError(
                        f"conflicting setting {k!r} in matrix product: "
                        f"{merged[k]!r} vs {v!r}"
                    )
            merged[k] = v
        return merged

    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        for lp, ls in self.left.assignments():
            for rp, rs in self.right.assignments():
                yield {**lp, **rp}, self._merge_settings(ls, rs)


class WhereMatrix(MatrixBase):
    """Callable filter: keeps assignments where ``predicate(params)``."""

    def __init__(self, base: MatrixBase, predicate: Callable[[dict[str, Any]], bool]):
        if not callable(predicate):
            raise ConfigMatrixError("where() takes a callable predicate over params")
        self.base = base
        self.predicate = predicate

    @property
    def axis_names(self) -> list[str]:
        return self.base.axis_names

    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        for params, settings in self.base.assignments():
            if self.predicate(params):
                yield params, settings


class DerivedMatrix(MatrixBase):
    """Adds a computed parameter ``name = fn(params)`` to every assignment."""

    def __init__(
        self, base: MatrixBase, name: str, fn: Callable[[dict[str, Any]], Any]
    ):
        if not isinstance(name, str) or not name:
            raise ConfigMatrixError("derived parameter name must be a non-empty str")
        if not callable(fn):
            raise ConfigMatrixError("derive() takes a callable over params")
        if name in base.axis_names:
            raise ConfigMatrixError(
                f"derived parameter {name!r} collides with an existing axis"
            )
        self.base = base
        self.name = name
        self.fn = fn

    @property
    def axis_names(self) -> list[str]:
        return list(self.base.axis_names) + [self.name]

    def assignments(self) -> Iterator[tuple[dict[str, Any], dict[str, Any]]]:
        for params, settings in self.base.assignments():
            if self.name in params:
                raise ConfigMatrixError(
                    f"derived parameter {self.name!r} already present in assignment"
                )
            yield {**params, self.name: self.fn(params)}, settings
