"""Task execution context and results.

``Context`` is what a user's ``exp_func(context)`` receives — the paper's
example accesses the task's parameters, checks/restores checkpoints, and
declares what to checkpoint. ``TaskResult`` is the engine's record of one
execution attempt (value or failure + timing + provenance).
"""
from __future__ import annotations

import os
import pickle
import socket
import statistics
import tempfile
import time
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

from .exceptions import CheckpointError
from .matrix import TaskSpec


class TaskCheckpointStore:
    """Versioned pickle checkpoints for one task, atomic on shared FS.

    Layout: ``<root>/<task-key>/ckpt-<n>.pkl`` with ``LATEST`` pointing at the
    newest complete file. Writes go through a temp file + rename so a crash
    mid-write can never be mistaken for a complete checkpoint.
    """

    def __init__(self, root: str | os.PathLike[str], key: str):
        self.dir = Path(root) / key
        self.dir.mkdir(parents=True, exist_ok=True)

    def _latest_path(self) -> Path:
        return self.dir / "LATEST"

    def latest_version(self) -> int | None:
        p = self._latest_path()
        if not p.exists():
            return None
        try:
            v = int(p.read_text().strip())
        except ValueError:
            return None
        return v if (self.dir / f"ckpt-{v}.pkl").exists() else None

    def exists(self) -> bool:
        return self.latest_version() is not None

    def save(self, obj: Any) -> int:
        version = (self.latest_version() or 0) + 1
        target = self.dir / f"ckpt-{version}.pkl"
        try:
            fd, tmp = tempfile.mkstemp(prefix=".ckpt-", dir=self.dir)
            with os.fdopen(fd, "wb") as f:
                pickle.dump(obj, f, protocol=pickle.HIGHEST_PROTOCOL)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, target)
            fd2, tmp2 = tempfile.mkstemp(prefix=".latest-", dir=self.dir)
            with os.fdopen(fd2, "w") as f:
                f.write(str(version))
            os.replace(tmp2, self._latest_path())
        except Exception as e:
            raise CheckpointError(f"failed to save checkpoint v{version}: {e}") from e
        # Keep only the two most recent checkpoints.
        for old in sorted(self.dir.glob("ckpt-*.pkl")):
            try:
                v = int(old.stem.split("-")[1])
            except (IndexError, ValueError):
                continue
            if v <= version - 2:
                old.unlink(missing_ok=True)
        return version

    def restore(self) -> Any:
        v = self.latest_version()
        if v is None:
            raise CheckpointError("no checkpoint to restore")
        try:
            with open(self.dir / f"ckpt-{v}.pkl", "rb") as f:
                return pickle.load(f)
        except Exception as e:
            raise CheckpointError(f"failed to restore checkpoint v{v}: {e}") from e


@dataclass
class Context:
    """Handle passed to the user's experiment function for one task."""

    spec: TaskSpec
    checkpoints: TaskCheckpointStore | None = None
    attempt: int = 0
    cancel_requested: Callable[[], bool] = lambda: False
    progress_cb: Callable[[str], None] | None = None
    _heartbeat: Callable[[], None] | None = None

    # Paper API: parameters and settings are plain attribute access.
    @property
    def params(self) -> dict[str, Any]:
        return self.spec.params

    @property
    def settings(self) -> dict[str, Any]:
        return self.spec.settings

    @property
    def key(self) -> str:
        return self.spec.key

    def __getitem__(self, name: str) -> Any:
        if name in self.spec.params:
            return self.spec.params[name]
        if name in self.spec.settings:
            return self.spec.settings[name]
        raise KeyError(name)

    # -- checkpointing ------------------------------------------------------
    def checkpoint_exists(self) -> bool:
        return bool(self.checkpoints and self.checkpoints.exists())

    def checkpoint(self, obj: Any) -> int:
        if self.checkpoints is None:
            raise CheckpointError("checkpointing is disabled for this run")
        self.heartbeat()
        return self.checkpoints.save(obj)

    def restore(self, default: Any = None) -> Any:
        if self.checkpoints is None or not self.checkpoints.exists():
            if default is not None:
                return default
            raise CheckpointError(f"task {self.key[:12]} has no checkpoint")
        return self.checkpoints.restore()

    # -- liveness -------------------------------------------------------------
    def heartbeat(self) -> None:
        """Long-running tasks should call this periodically; the runner uses it
        for straggler detection and the file-queue uses it to renew leases."""
        if self._heartbeat is not None:
            self._heartbeat()

    def progress(self, message: str) -> None:
        self.heartbeat()
        if self.progress_cb is not None:
            self.progress_cb(f"{self.spec.describe()}: {message}")


@dataclass
class TaskResult:
    """Outcome of one task (possibly after retries)."""

    spec: TaskSpec
    status: str  # "ok" | "failed" | "timeout" | "cached" | "skipped"
    value: Any = None
    error: str | None = None
    traceback_str: str | None = None
    attempts: int = 1
    started_unix: float = 0.0
    wall_s: float = 0.0
    host: str = field(default_factory=socket.gethostname)
    pid: int = field(default_factory=os.getpid)
    speculative: bool = False

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "cached")

    @classmethod
    def from_exception(
        cls, spec: TaskSpec, exc: BaseException, attempts: int, started: float
    ) -> "TaskResult":
        return cls(
            spec=spec,
            status="failed",
            error=f"{type(exc).__qualname__}: {exc}",
            traceback_str="".join(
                traceback.format_exception(type(exc), exc, exc.__traceback__)
            ),
            attempts=attempts,
            started_unix=started,
            wall_s=time.time() - started,
        )

    @classmethod
    def from_done_record(
        cls, spec: TaskSpec, record: dict[str, Any], value: Any = None
    ) -> "TaskResult":
        """Build a result from a file-queue ``done/<key>.json`` record — how
        one host surfaces a task another host executed, with the *real* error
        + traceback and the owning host rather than a generic placeholder."""
        status = "ok" if record.get("status") == "ok" else "failed"
        error = record.get("error")
        if status != "ok" and not error:
            error = f"failed on host {record.get('owner', '?')} (no error recorded)"
        return cls(
            spec=spec,
            status=status,
            value=value,
            error=None if status == "ok" else str(error),
            traceback_str=record.get("traceback") or None,
            attempts=int(record.get("attempts", 1) or 1),
            wall_s=float(record.get("wall_s", 0.0) or 0.0),
            host=str(record.get("owner", "peer")),
        )

    def summary(self) -> str:
        base = f"{self.spec.describe()} -> {self.status} in {self.wall_s:.2f}s"
        if self.error:
            base += f" ({self.error})"
        return base


class _TaskList(list):
    """A list of TaskResults that is also callable.

    ``ResultSet.ok`` predates the v2 API as a property; v2 documents
    ``results.ok()`` / ``results.failed()``. Returning a callable list keeps
    both spellings working on the same attribute.
    """

    def __call__(self) -> "_TaskList":
        return self


@dataclass
class Pivot:
    """A 2-D view over two parameter axes (analysis without pandas)."""

    row_axis: str
    col_axis: str
    rows: list[Any]
    cols: list[Any]
    cells: list[list[Any]]  # cells[i][j], None where no task landed

    def __str__(self) -> str:
        def s(v: Any) -> str:
            if isinstance(v, float):
                return f"{v:.4g}"
            return getattr(v, "__name__", None) or str(v)

        header = [f"{self.row_axis}\\{self.col_axis}"] + [s(c) for c in self.cols]
        body = [[s(r)] + [s(c) if c is not None else "-" for c in row]
                for r, row in zip(self.rows, self.cells)]
        widths = [max(len(line[i]) for line in [header] + body) for i in range(len(header))]
        fmt = lambda line: "  ".join(c.rjust(w) for c, w in zip(line, widths))
        return "\n".join([fmt(header)] + [fmt(line) for line in body])


_PIVOT_AGGS: dict[str, Callable[[list[Any]], Any]] = {
    "mean": lambda vs: sum(vs) / len(vs),
    "median": statistics.median,
    "min": min,
    "max": max,
    "sum": sum,
    "count": len,
    "first": lambda vs: vs[0],
    "last": lambda vs: vs[-1],
}


def _resolve_pivot_agg(
    agg: str | Callable[[list[Any]], Any] | None,
) -> Callable[[list[Any]], Any] | None:
    if agg is None or callable(agg):
        return agg
    try:
        return _PIVOT_AGGS[agg]
    except KeyError:
        raise ValueError(
            f"unknown agg {agg!r}; one of {sorted(_PIVOT_AGGS)} or a callable"
        ) from None


class ResultSet:
    """Ordered collection of task results with paper-style conveniences.

    Assembly is lazy: constructed from any iterable (e.g. the live stream of
    a running ``Memento.stream``), the underlying iterator is only drained on
    first access, so building a ResultSet over a stream costs nothing until
    the results are actually needed.
    """

    def __init__(self, results: "list[TaskResult] | Any"):
        self._results: list[TaskResult] = []
        self._pending = iter(results)

    def _assemble(self) -> list[TaskResult]:
        if self._pending is not None:
            self._results.extend(self._pending)
            self._pending = None
            self._results.sort(key=lambda r: r.spec.index)
        return self._results

    def materialize(self) -> "ResultSet":
        """Drain the underlying stream now (blocks until the run finishes)."""
        self._assemble()
        return self

    def __iter__(self):
        return iter(self._assemble())

    def __len__(self) -> int:
        return len(self._assemble())

    def __getitem__(self, i: int) -> TaskResult:
        return self._assemble()[i]

    @property
    def ok(self) -> _TaskList:
        """Successful results — usable as a list (``results.ok``) or called
        (``results.ok()``)."""
        return _TaskList(r for r in self._assemble() if r.ok)

    @property
    def failed(self) -> _TaskList:
        return _TaskList(r for r in self._assemble() if not r.ok)

    @property
    def values(self) -> list[Any]:
        return [r.value for r in self._assemble() if r.ok]

    def value_by_params(self, **params: Any) -> Any:
        for r in self._assemble():
            if all(r.spec.params.get(k) == v for k, v in params.items()):
                if not r.ok:
                    raise LookupError(f"matching task {r.spec.key[:12]} failed: {r.error}")
                return r.value
        raise LookupError(f"no task matches {params}")

    # -- analysis -----------------------------------------------------------
    def pivot(
        self,
        rows: str,
        cols: str,
        value_fn: Callable[[TaskResult], Any] | None = None,
        agg: str | Callable[[list[Any]], Any] | None = None,
    ) -> Pivot:
        """Pivot successful results over two parameter axes.

        ``value_fn`` maps a TaskResult to the cell value (default:
        ``r.value``). When several tasks land in one cell (other axes vary),
        the ambiguity is an error unless ``agg`` says how to combine them:
        a callable over the cell's values (in task-index order), or one of
        ``"mean" | "median" | "min" | "max" | "sum" | "count" | "first" |
        "last"``.
        """
        value_fn = value_fn or (lambda r: r.value)
        agg_fn = _resolve_pivot_agg(agg)
        row_labels: list[Any] = []
        col_labels: list[Any] = []
        cells: dict[tuple[int, int], list[Any]] = {}

        def _index(labels: list[Any], v: Any) -> int:
            for i, existing in enumerate(labels):
                if existing is v or existing == v:
                    return i
            labels.append(v)
            return len(labels) - 1

        for r in self._assemble():
            if not r.ok:
                continue
            p = r.spec.params
            if rows not in p or cols not in p:
                continue
            ij = _index(row_labels, p[rows]), _index(col_labels, p[cols])
            cells.setdefault(ij, []).append(value_fn(r))
        if agg_fn is None:
            for (i, j), vs in cells.items():
                if len(vs) > 1:
                    raise ValueError(
                        f"pivot cell ({row_labels[i]!r}, {col_labels[j]!r}) is "
                        f"ambiguous: {len(vs)} tasks land in it (other axes "
                        f"vary); pass agg='mean'/'last'/... or a callable, or "
                        f"narrow the matrix"
                    )
            agg_fn = lambda vs: vs[0]  # noqa: E731
        grid = [
            [agg_fn(cells[i, j]) if (i, j) in cells else None
             for j in range(len(col_labels))]
            for i in range(len(row_labels))
        ]
        return Pivot(row_axis=rows, col_axis=cols, rows=row_labels, cols=col_labels,
                     cells=grid)

    def to_csv(self, path: str | os.PathLike[str] | None = None) -> str:
        """Flatten to CSV: one row per task (param columns + status/timing +
        value columns). Mapping values become one column per key; returns the
        CSV text and optionally writes it to ``path``."""
        import csv
        import io

        results = self._assemble()
        param_cols: dict[str, None] = {}
        value_cols: dict[str, None] = {}
        scalar_value = False
        for r in results:
            for k in r.spec.params:
                param_cols.setdefault(k)
            if r.ok and isinstance(r.value, dict):
                for k in r.value:
                    value_cols.setdefault(k)
            elif r.ok and r.value is not None:
                scalar_value = True
        vcols = list(value_cols) + (["value"] if scalar_value or not value_cols else [])
        header = list(param_cols) + ["status", "attempts", "wall_s"] + vcols

        def cell(v: Any) -> Any:
            return getattr(v, "__name__", None) or v

        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        w.writerow(header)
        for r in results:
            row = [cell(r.spec.params.get(k, "")) for k in param_cols]
            row += [r.status, r.attempts, f"{r.wall_s:.4f}"]
            for k in vcols:
                if k == "value":
                    row.append(cell(r.value) if r.ok and not isinstance(r.value, dict) else "")
                else:
                    row.append(cell(r.value.get(k, "")) if r.ok and isinstance(r.value, dict) else "")
            w.writerow(row)
        text = buf.getvalue()
        if path is not None:
            Path(path).write_text(text)
        return text

    def summary(self) -> str:
        results = self._assemble()
        n_ok = len(self.ok)
        n_cached = sum(1 for r in results if r.status == "cached")
        lines = [
            f"{len(results)} tasks: {n_ok} ok ({n_cached} from cache), "
            f"{len(self.failed)} failed"
        ]
        lines.extend(r.summary() for r in self.failed)
        return "\n".join(lines)
