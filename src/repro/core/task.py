"""Task execution context and results.

``Context`` is what a user's ``exp_func(context)`` receives — the paper's
example accesses the task's parameters, checks/restores checkpoints, and
declares what to checkpoint. ``TaskResult`` is the engine's record of one
execution attempt (value or failure + timing + provenance).
"""
from __future__ import annotations

import os
import pickle
import socket
import tempfile
import time
import traceback
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

from .exceptions import CheckpointError
from .matrix import TaskSpec


class TaskCheckpointStore:
    """Versioned pickle checkpoints for one task, atomic on shared FS.

    Layout: ``<root>/<task-key>/ckpt-<n>.pkl`` with ``LATEST`` pointing at the
    newest complete file. Writes go through a temp file + rename so a crash
    mid-write can never be mistaken for a complete checkpoint.
    """

    def __init__(self, root: str | os.PathLike[str], key: str):
        self.dir = Path(root) / key
        self.dir.mkdir(parents=True, exist_ok=True)

    def _latest_path(self) -> Path:
        return self.dir / "LATEST"

    def latest_version(self) -> int | None:
        p = self._latest_path()
        if not p.exists():
            return None
        try:
            v = int(p.read_text().strip())
        except ValueError:
            return None
        return v if (self.dir / f"ckpt-{v}.pkl").exists() else None

    def exists(self) -> bool:
        return self.latest_version() is not None

    def save(self, obj: Any) -> int:
        version = (self.latest_version() or 0) + 1
        target = self.dir / f"ckpt-{version}.pkl"
        try:
            fd, tmp = tempfile.mkstemp(prefix=".ckpt-", dir=self.dir)
            with os.fdopen(fd, "wb") as f:
                pickle.dump(obj, f, protocol=pickle.HIGHEST_PROTOCOL)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, target)
            fd2, tmp2 = tempfile.mkstemp(prefix=".latest-", dir=self.dir)
            with os.fdopen(fd2, "w") as f:
                f.write(str(version))
            os.replace(tmp2, self._latest_path())
        except Exception as e:
            raise CheckpointError(f"failed to save checkpoint v{version}: {e}") from e
        # Keep only the two most recent checkpoints.
        for old in sorted(self.dir.glob("ckpt-*.pkl")):
            try:
                v = int(old.stem.split("-")[1])
            except (IndexError, ValueError):
                continue
            if v <= version - 2:
                old.unlink(missing_ok=True)
        return version

    def restore(self) -> Any:
        v = self.latest_version()
        if v is None:
            raise CheckpointError("no checkpoint to restore")
        try:
            with open(self.dir / f"ckpt-{v}.pkl", "rb") as f:
                return pickle.load(f)
        except Exception as e:
            raise CheckpointError(f"failed to restore checkpoint v{v}: {e}") from e


@dataclass
class Context:
    """Handle passed to the user's experiment function for one task."""

    spec: TaskSpec
    checkpoints: TaskCheckpointStore | None = None
    attempt: int = 0
    cancel_requested: Callable[[], bool] = lambda: False
    progress_cb: Callable[[str], None] | None = None
    _heartbeat: Callable[[], None] | None = None

    # Paper API: parameters and settings are plain attribute access.
    @property
    def params(self) -> dict[str, Any]:
        return self.spec.params

    @property
    def settings(self) -> dict[str, Any]:
        return self.spec.settings

    @property
    def key(self) -> str:
        return self.spec.key

    def __getitem__(self, name: str) -> Any:
        if name in self.spec.params:
            return self.spec.params[name]
        if name in self.spec.settings:
            return self.spec.settings[name]
        raise KeyError(name)

    # -- checkpointing ------------------------------------------------------
    def checkpoint_exists(self) -> bool:
        return bool(self.checkpoints and self.checkpoints.exists())

    def checkpoint(self, obj: Any) -> int:
        if self.checkpoints is None:
            raise CheckpointError("checkpointing is disabled for this run")
        self.heartbeat()
        return self.checkpoints.save(obj)

    def restore(self, default: Any = None) -> Any:
        if self.checkpoints is None or not self.checkpoints.exists():
            if default is not None:
                return default
            raise CheckpointError(f"task {self.key[:12]} has no checkpoint")
        return self.checkpoints.restore()

    # -- liveness -------------------------------------------------------------
    def heartbeat(self) -> None:
        """Long-running tasks should call this periodically; the runner uses it
        for straggler detection and the file-queue uses it to renew leases."""
        if self._heartbeat is not None:
            self._heartbeat()

    def progress(self, message: str) -> None:
        self.heartbeat()
        if self.progress_cb is not None:
            self.progress_cb(f"{self.spec.describe()}: {message}")


@dataclass
class TaskResult:
    """Outcome of one task (possibly after retries)."""

    spec: TaskSpec
    status: str  # "ok" | "failed" | "timeout" | "cached" | "skipped"
    value: Any = None
    error: str | None = None
    traceback_str: str | None = None
    attempts: int = 1
    started_unix: float = 0.0
    wall_s: float = 0.0
    host: str = field(default_factory=socket.gethostname)
    pid: int = field(default_factory=os.getpid)
    speculative: bool = False

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "cached")

    @classmethod
    def from_exception(
        cls, spec: TaskSpec, exc: BaseException, attempts: int, started: float
    ) -> "TaskResult":
        return cls(
            spec=spec,
            status="failed",
            error=f"{type(exc).__qualname__}: {exc}",
            traceback_str="".join(
                traceback.format_exception(type(exc), exc, exc.__traceback__)
            ),
            attempts=attempts,
            started_unix=started,
            wall_s=time.time() - started,
        )

    def summary(self) -> str:
        base = f"{self.spec.describe()} -> {self.status} in {self.wall_s:.2f}s"
        if self.error:
            base += f" ({self.error})"
        return base


class ResultSet:
    """Ordered collection of task results with paper-style conveniences."""

    def __init__(self, results: list[TaskResult]):
        self._results = sorted(results, key=lambda r: r.spec.index)

    def __iter__(self):
        return iter(self._results)

    def __len__(self) -> int:
        return len(self._results)

    def __getitem__(self, i: int) -> TaskResult:
        return self._results[i]

    @property
    def ok(self) -> list[TaskResult]:
        return [r for r in self._results if r.ok]

    @property
    def failed(self) -> list[TaskResult]:
        return [r for r in self._results if not r.ok]

    @property
    def values(self) -> list[Any]:
        return [r.value for r in self._results if r.ok]

    def value_by_params(self, **params: Any) -> Any:
        for r in self._results:
            if all(r.spec.params.get(k) == v for k, v in params.items()):
                if not r.ok:
                    raise LookupError(f"matching task {r.spec.key[:12]} failed: {r.error}")
                return r.value
        raise LookupError(f"no task matches {params}")

    def summary(self) -> str:
        n_ok = len(self.ok)
        n_cached = sum(1 for r in self._results if r.status == "cached")
        lines = [
            f"{len(self._results)} tasks: {n_ok} ok ({n_cached} from cache), "
            f"{len(self.failed)} failed"
        ]
        lines.extend(r.summary() for r in self.failed)
        return "\n".join(lines)
