"""Result caches keyed by task hash.

``FsCache`` is the production cache: one directory per task key holding
``result.pkl`` (the payload) and ``manifest.json`` (status, params repr,
timings, payload digest). Writes are atomic (tmp file + rename) so a crash
mid-write never produces a half-entry; reads verify the payload digest and
quarantine corrupt entries instead of returning garbage.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Iterator

from .exceptions import CacheCorruptionError, CacheError

MANIFEST = "manifest.json"
PAYLOAD = "result.pkl"
QUARANTINE = "_quarantine"


@dataclass
class CacheEntry:
    key: str
    value: Any
    manifest: dict[str, Any]


def param_repr(value: Any) -> str:
    """Canonical string form of one task-parameter value as recorded in
    cache manifests — shared by the writers (runner) and readers
    (``Memento.invalidate``) so partial-params matching round-trips."""
    return getattr(value, "__name__", None) or str(value)


class BaseCache:
    def get(self, key: str) -> CacheEntry | None:  # pragma: no cover - interface
        raise NotImplementedError

    def put(self, key: str, value: Any, manifest: dict[str, Any] | None = None) -> None:
        raise NotImplementedError  # pragma: no cover - interface

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def invalidate(self, key: str) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def keys(self) -> Iterator[str]:
        """Iterate stored task keys (for sweep-level invalidation). Caches
        that cannot enumerate return nothing."""
        return iter(())

    def manifest(self, key: str) -> dict[str, Any] | None:
        """Manifest-only read (no payload deserialisation where the backend
        allows it) — the matching side of sweep-level invalidation."""
        entry = self.get(key)
        return entry.manifest if entry is not None else None


class NullCache(BaseCache):
    """Caching disabled (paper: force re-run)."""

    def get(self, key: str) -> CacheEntry | None:
        return None

    def put(self, key: str, value: Any, manifest: dict[str, Any] | None = None) -> None:
        return None

    def invalidate(self, key: str) -> None:
        return None


class MemoryCache(BaseCache):
    """Process-local cache; used by tests and as a read-through layer."""

    def __init__(self) -> None:
        self._store: dict[str, CacheEntry] = {}
        self._lock = threading.Lock()

    def get(self, key: str) -> CacheEntry | None:
        with self._lock:
            return self._store.get(key)

    def put(self, key: str, value: Any, manifest: dict[str, Any] | None = None) -> None:
        with self._lock:
            self._store[key] = CacheEntry(key, value, dict(manifest or {}))

    def invalidate(self, key: str) -> None:
        with self._lock:
            self._store.pop(key, None)

    def keys(self) -> Iterator[str]:
        with self._lock:
            return iter(list(self._store.keys()))

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)


class FsCache(BaseCache):
    """Filesystem cache safe for concurrent writers on a shared FS."""

    def __init__(self, root: str | os.PathLike[str]):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        (self.root / QUARANTINE).mkdir(exist_ok=True)
        self._lock = threading.Lock()

    def _dir(self, key: str) -> Path:
        if not key or "/" in key or key.startswith("."):
            raise CacheError(f"invalid cache key {key!r}")
        return self.root / key

    # -- write ------------------------------------------------------------
    def put(self, key: str, value: Any, manifest: dict[str, Any] | None = None) -> None:
        entry_dir = self._dir(key)
        try:
            payload = pickle.dumps(value, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception as e:
            raise CacheError(f"result for task {key[:12]} is not picklable: {e}") from e
        digest = hashlib.sha256(payload).hexdigest()
        man = dict(manifest or {})
        man.update(
            {
                "key": key,
                "payload_sha256": digest,
                "payload_bytes": len(payload),
                "written_unix": time.time(),
                "writer_pid": os.getpid(),
            }
        )
        tmp = Path(tempfile.mkdtemp(prefix=f".wip-{key[:12]}-", dir=self.root))
        try:
            (tmp / PAYLOAD).write_bytes(payload)
            (tmp / MANIFEST).write_text(json.dumps(man, indent=2, default=str))
            with self._lock:
                if entry_dir.exists():
                    shutil.rmtree(entry_dir, ignore_errors=True)
                os.replace(tmp, entry_dir)
        except Exception as e:
            shutil.rmtree(tmp, ignore_errors=True)
            raise CacheError(f"failed to write cache entry {key[:12]}: {e}") from e

    # -- read -------------------------------------------------------------
    def get(self, key: str) -> CacheEntry | None:
        entry_dir = self._dir(key)
        man_path = entry_dir / MANIFEST
        pay_path = entry_dir / PAYLOAD
        if not man_path.exists() or not pay_path.exists():
            return None
        try:
            manifest = json.loads(man_path.read_text())
            payload = pay_path.read_bytes()
            digest = hashlib.sha256(payload).hexdigest()
            if digest != manifest.get("payload_sha256"):
                raise CacheCorruptionError(
                    f"cache entry {key[:12]} payload digest mismatch"
                )
            value = pickle.loads(payload)
        except CacheCorruptionError:
            self._quarantine(key)
            return None
        except Exception:
            self._quarantine(key)
            return None
        return CacheEntry(key=key, value=value, manifest=manifest)

    def manifest(self, key: str) -> dict[str, Any] | None:
        """Read only manifest.json — invalidation scans stay O(entries),
        never unpickling payloads."""
        man_path = self._dir(key) / MANIFEST
        try:
            return json.loads(man_path.read_text())
        except FileNotFoundError:
            return None
        except Exception:
            self._quarantine(key)
            return None

    def _quarantine(self, key: str) -> None:
        entry_dir = self._dir(key)
        dest = self.root / QUARANTINE / f"{key}-{int(time.time()*1e6)}"
        try:
            with self._lock:
                if entry_dir.exists():
                    os.replace(entry_dir, dest)
        except OSError:
            shutil.rmtree(entry_dir, ignore_errors=True)

    def invalidate(self, key: str) -> None:
        with self._lock:
            shutil.rmtree(self._dir(key), ignore_errors=True)

    # -- introspection ------------------------------------------------------
    def keys(self) -> Iterator[str]:
        for child in self.root.iterdir():
            if child.is_dir() and not child.name.startswith((".", "_")):
                yield child.name

    def __len__(self) -> int:
        return sum(1 for _ in self.keys())

    def stats(self) -> dict[str, Any]:
        n, total = 0, 0
        for key in self.keys():
            p = self._dir(key) / PAYLOAD
            if p.exists():
                n += 1
                total += p.stat().st_size
        return {"entries": n, "payload_bytes": total, "root": str(self.root)}
