"""repro.core — the Memento experiment engine (the paper's contribution).

Public API mirrors the paper:

    import repro.core as memento
    results = memento.Memento(exp_func, memento.ConsoleNotificationProvider()) \
        .run(config_matrix)
"""
from .cache import BaseCache, CacheEntry, FsCache, MemoryCache, NullCache
from .exceptions import (
    CacheCorruptionError,
    CacheError,
    CheckpointError,
    ConfigMatrixError,
    HashingError,
    LeaseExpiredError,
    MementoError,
    QueueError,
    RetriesExhaustedError,
    TaskFailedError,
    TaskTimeoutError,
)
from .distributed import DistributedConfig, LeaseRenewer, stream_distributed
from .filequeue import FileQueue, QueueStats, drain
from .hashing import canonicalize, qualified_name, stable_hash, task_key
from .matrix import (
    ChainMatrix,
    ConfigMatrix,
    DerivedMatrix,
    MatrixBase,
    ProductMatrix,
    TaskSpec,
    TaskViewMatrix,
    WhereMatrix,
    as_matrix,
)
from .memento import Memento
from .notifications import (
    CallbackNotificationProvider,
    ConsoleNotificationProvider,
    Event,
    FileNotificationProvider,
    MultiProvider,
    NotificationProvider,
    ProgressNotificationProvider,
    RecordingProvider,
    WebhookNotificationProvider,
)
from .runner import Runner, RunnerConfig
from .task import Context, Pivot, ResultSet, TaskCheckpointStore, TaskResult
