"""Stable content hashing of task parameters.

The paper: "Each parameter is assigned a hash value when generating the
tasks" — the hash is the task's identity for caching and resumption, so it
must be stable across processes, python versions of dict ordering, and runs.

Canonicalisation rules:
  * mappings   -> sorted (by canonical key) list of [key, value] pairs
  * sequences  -> lists (tuples/lists/sets all normalise; sets are sorted)
  * callables / classes -> "py://<module>.<qualname>"; closures rejected
  * dataclasses -> their field dict, tagged with the class qualname
  * numpy scalars/arrays -> dtype + shape + data bytes digest
  * objects exposing ``memento_hash()`` or ``to_hash_dict()`` -> delegated
  * floats -> repr (shortest round-trip), NaN/inf normalised
Anything else is rejected loudly (HashingError) instead of silently using
``id()``-dependent repr — silent instability is how caches lie.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
import math
from typing import Any

from .exceptions import HashingError

try:  # numpy is always present in this repo, but keep the core importable without it
    import numpy as _np
except Exception:  # pragma: no cover
    _np = None

_MAX_DEPTH = 64


def qualified_name(obj: Any) -> str:
    """Stable ``module.qualname`` identifier for a callable/class."""
    module = getattr(obj, "__module__", None)
    qualname = getattr(obj, "__qualname__", None) or getattr(obj, "__name__", None)
    if module is None or qualname is None:
        raise HashingError(f"cannot derive a qualified name for {obj!r}")
    if "<locals>" in qualname:
        # A closure's identity is not reproducible across runs.
        raise HashingError(
            f"{module}.{qualname} is defined inside a function; Memento task "
            "parameters must be module-level callables/classes so their hash "
            "is stable across runs"
        )
    if "<lambda>" in qualname:
        raise HashingError(
            f"lambda in {module} cannot be hashed stably; use a named function"
        )
    return f"py://{module}.{qualname}"


def canonicalize(value: Any, depth: int = 0) -> Any:
    """Reduce ``value`` to a JSON-serialisable canonical form."""
    if depth > _MAX_DEPTH:
        raise HashingError("parameter nesting exceeds maximum canonicalisation depth")

    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        if math.isnan(value):
            return {"__float__": "nan"}
        if math.isinf(value):
            return {"__float__": "inf" if value > 0 else "-inf"}
        return {"__float__": repr(value)}
    if isinstance(value, bytes):
        return {"__bytes_sha256__": hashlib.sha256(value).hexdigest()}
    if _np is not None and isinstance(value, _np.generic):
        return canonicalize(value.item(), depth + 1)
    if _np is not None and isinstance(value, _np.ndarray):
        return {
            "__ndarray__": {
                "dtype": str(value.dtype),
                "shape": list(value.shape),
                "digest": hashlib.sha256(_np.ascontiguousarray(value).tobytes()).hexdigest(),
            }
        }
    # Delegation hooks (checked before dataclass so objects can override).
    hook = getattr(value, "memento_hash", None)
    if callable(hook):
        return {"__memento_hash__": str(hook())}
    hook = getattr(value, "to_hash_dict", None)
    if callable(hook):
        return {
            "__object__": type(value).__qualname__,
            "fields": canonicalize(hook(), depth + 1),
        }
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return {
            "__dataclass__": qualified_name(type(value)),
            "fields": canonicalize(dataclasses.asdict(value), depth + 1),
        }
    if isinstance(value, dict):
        items = [
            [canonicalize(k, depth + 1), canonicalize(v, depth + 1)]
            for k, v in value.items()
        ]
        items.sort(key=lambda kv: json.dumps(kv[0], sort_keys=True))
        return {"__dict__": items}
    if isinstance(value, (list, tuple)):
        return [canonicalize(v, depth + 1) for v in value]
    if isinstance(value, (set, frozenset)):
        elems = [canonicalize(v, depth + 1) for v in value]
        elems.sort(key=lambda e: json.dumps(e, sort_keys=True))
        return {"__set__": elems}
    if inspect.isclass(value) or callable(value):
        return qualified_name(value)
    raise HashingError(
        f"cannot stably hash parameter of type {type(value).__qualname__}: {value!r}. "
        "Provide a memento_hash()/to_hash_dict() method, or use primitives."
    )


def stable_hash(value: Any) -> str:
    """SHA-256 hex digest of the canonical form of ``value``."""
    canon = canonicalize(value)
    blob = json.dumps(canon, sort_keys=True, separators=(",", ":")).encode("utf-8")
    return hashlib.sha256(blob).hexdigest()


def task_key(
    params: dict[str, Any],
    settings: dict[str, Any] | None = None,
    namespace: str | None = None,
) -> str:
    """The identity of a task.

    Hashes the full parameter assignment *and* the matrix settings (two
    matrices with identical params but different settings are different
    experiments — they must never serve each other's cached results), plus
    an optional experiment namespace so unrelated experiment functions can
    share a workdir without key collisions.
    """
    if not isinstance(params, dict):
        raise HashingError("task parameters must be a dict")
    if settings is not None and not isinstance(settings, dict):
        raise HashingError("task settings must be a dict")
    ident: dict[str, Any] = {"params": params, "settings": settings or {}}
    if namespace:
        ident["namespace"] = str(namespace)
    return stable_hash(ident)
