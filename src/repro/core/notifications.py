"""Notification providers.

The paper ships a ``ConsoleNotificationProvider``; we add file, callback and
aggregating providers plus a webhook-shaped provider that writes the payload
it *would* post (this container has no network; on a cluster you'd point it
at Slack/PagerDuty). Providers must never take the run down: every dispatch
is wrapped and failures are counted, not raised.
"""
from __future__ import annotations

import json
import sys
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, TextIO

from .task import TaskResult


def _scalar_metrics(value: Any) -> dict[str, float]:
    """Numeric scalar entries of a result value — the metrics that travel in
    structured ``task_finished`` payloads (and feed ``repro.analysis``; the
    analysis layer keeps its own copy since core never imports it)."""
    if not isinstance(value, dict):
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            return {}
        return {"value": float(value)}
    out: dict[str, float] = {}
    for k, v in value.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[str(k)] = float(v)
    return out


@dataclass
class Event:
    kind: str  # task_started | task_finished | task_failed | task_retry |
    #            straggler_respawned | run_started | run_finished |
    #            queue_progress | task_dry
    message: str
    unix_time: float = field(default_factory=time.time)
    payload: dict[str, Any] = field(default_factory=dict)

    def to_record(self) -> dict[str, Any]:
        """Flat JSON-safe record — the JSONL journal schema shared by
        :class:`FileNotificationProvider` and the analysis dashboard:
        ``{"t", "kind", "message", **payload}``."""
        return {
            "t": self.unix_time,
            "kind": self.kind,
            "message": self.message,
            **self.payload,
        }


class NotificationProvider:
    """Interface. ``notify`` must be cheap and exception-safe."""

    def notify(self, event: Event) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    # Paper-compatible sugar -------------------------------------------------
    def task_dry(self, spec: Any) -> None:
        """Dry-run report for one task (paper: report what *would* run).

        Default implementation routes through :meth:`notify` as a
        ``task_dry`` event, so every provider gets dry-run output for free;
        override for richer formatting."""
        self.notify(
            Event(
                kind="task_dry",
                message=f"would run {spec.describe()}",
                payload={"key": spec.key, "params": spec.params},
            )
        )

    def task_finished(self, result: TaskResult) -> None:
        payload: dict[str, Any] = {
            "key": result.spec.key,
            "status": result.status,
            "params": dict(result.spec.params),
            "host": result.host,
            "wall_s": result.wall_s,
            "attempts": result.attempts,
            "cached": result.status == "cached",
        }
        if result.ok:
            payload["metrics"] = _scalar_metrics(result.value)
        else:
            payload["error"] = result.error
            payload["traceback"] = result.traceback_str
        self.notify(
            Event(
                kind="task_finished" if result.ok else "task_failed",
                message=result.summary(),
                payload=payload,
            )
        )

    def run_finished(self, n_ok: int, n_failed: int, wall_s: float) -> None:
        self.notify(
            Event(
                kind="run_finished",
                message=f"run finished: {n_ok} ok, {n_failed} failed in {wall_s:.1f}s",
                payload={"ok": n_ok, "failed": n_failed, "wall_s": wall_s},
            )
        )


class ConsoleNotificationProvider(NotificationProvider):
    """The provider from the paper's demo snippet."""

    def __init__(self, stream: TextIO | None = None, verbose: bool = True):
        self.stream = stream or sys.stderr
        self.verbose = verbose
        self._lock = threading.Lock()

    def notify(self, event: Event) -> None:
        if not self.verbose and event.kind in ("task_started",):
            return
        stamp = time.strftime("%H:%M:%S", time.localtime(event.unix_time))
        with self._lock:
            print(f"[memento {stamp}] {event.kind}: {event.message}", file=self.stream)


class FileNotificationProvider(NotificationProvider):
    """Append-only JSONL event log — greppable post-mortem trail."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()

    def notify(self, event: Event) -> None:
        rec = event.to_record()
        with self._lock, open(self.path, "a") as f:
            f.write(json.dumps(rec, default=str) + "\n")


class CallbackNotificationProvider(NotificationProvider):
    def __init__(self, fn: Callable[[Event], None]):
        self.fn = fn

    def notify(self, event: Event) -> None:
        self.fn(event)


class WebhookNotificationProvider(NotificationProvider):
    """Writes the JSON payloads it would POST to ``url`` into a spool dir.

    On a networked cluster, subclass and override ``send``.
    """

    def __init__(self, url: str, spool_dir: str | Path):
        self.url = url
        self.spool = Path(spool_dir)
        self.spool.mkdir(parents=True, exist_ok=True)
        self._n = 0
        self._lock = threading.Lock()

    def send(self, body: dict[str, Any]) -> None:
        with self._lock:
            self._n += 1
            (self.spool / f"event-{self._n:06d}.json").write_text(
                json.dumps(body, indent=2, default=str)
            )

    def notify(self, event: Event) -> None:
        self.send(
            {"url": self.url, "kind": event.kind, "text": event.message, **event.payload}
        )


class ProgressNotificationProvider(NotificationProvider):
    """Live sweep progress in completion order (minimal console version).

    Feed it from ``Memento.stream()``::

        prov = ProgressNotificationProvider(total=len(matrix))
        for result in prov.track(eng.stream(matrix)):
            ...   # consume incrementally; progress lines render as a side
                  # effect: "[memento] 12/40 done (3 cached, 1 failed) ETA 42s"

    or pass it as the Memento's ``notification_provider`` — it derives the
    same counts from ``task_finished``/``task_failed`` events (cache hits
    are only visible on the stream path, since hits bypass execution).
    The ETA extrapolates the observed live-completion rate over the
    remaining tasks; cached results are instant and excluded from the rate.

    On a distributed run it additionally consumes the driver's periodic
    ``queue_progress`` events, rendering the cluster-wide view with live
    per-host claimed/done counts::

        [memento] queue 12/40 done (hostA-1: 3 claimed/5 done, hostB-2: ...)

    The latest snapshot stays available as ``prov.queue_state``.
    """

    def __init__(
        self,
        total: int | None = None,
        stream: TextIO | None = None,
        min_interval_s: float = 0.0,
    ):
        self.total = total
        self.stream = stream or sys.stderr
        self.min_interval_s = min_interval_s
        self.done = 0  # ok + failed + cached
        self.failed = 0
        self.cached = 0
        self.queue_state: dict[str, Any] | None = None  # last queue_progress
        self._t0: float | None = None
        self._t_last_print = 0.0
        self._lock = threading.Lock()

    # -- stream path --------------------------------------------------------
    def track(self, results: Any) -> Any:
        """Wrap a ``Memento.stream()`` iterator: yields every result through
        unchanged while updating (and printing) progress."""
        for result in results:
            self.update(result)
            yield result

    def update(self, result: TaskResult) -> None:
        with self._lock:
            if self._t0 is None:
                self._t0 = time.time()
            self.done += 1
            if result.status == "cached":
                self.cached += 1
            elif not result.ok:
                self.failed += 1
            self._render()

    # -- event path (Memento notification_provider) -------------------------
    def notify(self, event: Event) -> None:
        with self._lock:
            if event.kind == "run_started":
                self._t0 = time.time()
                return
            if event.kind == "queue_progress":
                self.queue_state = dict(event.payload)
                self._render_queue()
                return
            if event.kind not in ("task_finished", "task_failed"):
                return
            if self._t0 is None:
                self._t0 = time.time()
            self.done += 1
            if event.kind == "task_failed":
                self.failed += 1
            self._render()

    # -- rendering ----------------------------------------------------------
    def eta_s(self) -> float | None:
        """Seconds to drain the remaining tasks at the live completion rate."""
        live_done = self.done - self.cached
        if self.total is None or self._t0 is None or live_done <= 0:
            return None
        remaining = max(self.total - self.done, 0)
        rate = live_done / max(time.time() - self._t0, 1e-9)
        return remaining / rate if rate > 0 else None

    def _render(self) -> None:
        now = time.time()
        if self.min_interval_s and now - self._t_last_print < self.min_interval_s:
            return
        self._t_last_print = now
        total = f"/{self.total}" if self.total is not None else ""
        extras = []
        if self.cached:
            extras.append(f"{self.cached} cached")
        if self.failed:
            extras.append(f"{self.failed} failed")
        detail = f" ({', '.join(extras)})" if extras else ""
        eta = self.eta_s()
        eta_s = f" ETA {eta:.0f}s" if eta is not None else ""
        print(f"[memento] {self.done}{total} done{detail}{eta_s}", file=self.stream)

    def _render_queue(self) -> None:
        q = self.queue_state or {}
        hosts = sorted(set(q.get("claimed_by", {})) | set(q.get("done_by", {})))
        per_host = ", ".join(
            f"{h}: {q.get('claimed_by', {}).get(h, 0)} claimed/"
            f"{q.get('done_by', {}).get(h, 0)} done"
            for h in hosts
        )
        failed = f", {q['failed']} failed" if q.get("failed") else ""
        detail = f" ({per_host})" if per_host else ""
        print(
            f"[memento] queue {q.get('done', 0)}/{q.get('total', 0)} done"
            f"{failed}{detail}",
            file=self.stream,
        )


class MultiProvider(NotificationProvider):
    """Fan out to several providers; swallow (but count) their failures."""

    def __init__(self, *providers: NotificationProvider):
        self.providers = list(providers)
        self.dispatch_errors = 0

    def notify(self, event: Event) -> None:
        for p in self.providers:
            try:
                p.notify(event)
            except Exception:
                self.dispatch_errors += 1


class RecordingProvider(NotificationProvider):
    """Test helper: records every event."""

    def __init__(self) -> None:
        self.events: list[Event] = []
        self._lock = threading.Lock()

    def notify(self, event: Event) -> None:
        with self._lock:
            self.events.append(event)

    def kinds(self) -> list[str]:
        with self._lock:
            return [e.kind for e in self.events]
