"""The paper-facing facade.

    import repro.core as memento

    notif = memento.ConsoleNotificationProvider()
    results = memento.Memento(exp_func, notif).run(config_matrix)

matches the snippet in the paper (section 3) verbatim modulo module name.

Beyond the paper, ``Memento.stream()`` yields each task's result the moment
it is known (cache hits first), and ``run()`` is a thin blocking collector
over the same stream — both accept paper-schema dicts or composed matrices
(see :mod:`repro.core.matrix`).
"""
from __future__ import annotations

from dataclasses import replace
from pathlib import Path
from typing import Any, Callable, Iterator, Mapping

from .cache import BaseCache, FsCache, MemoryCache, NullCache
from .distributed import DistributedConfig, stream_distributed
from .filequeue import FileQueue
from .matrix import ConfigMatrix, MatrixBase, TaskSpec, as_matrix
from .notifications import ConsoleNotificationProvider, NotificationProvider
from .runner import Runner, RunnerConfig
from .task import Context, ResultSet, TaskResult


class Memento:
    """Run an experiment function over every task of a configuration matrix.

    Parameters
    ----------
    exp_func:
        ``exp_func(context) -> result``. The context exposes ``params``,
        ``settings``, checkpoint save/restore, and heartbeats.
    notification_provider:
        where run/task events go (console by default, as in the paper).
    workdir:
        root for the result cache + task checkpoints. ``None`` -> in-memory
        cache, checkpointing disabled (pure-functional quick runs).
    namespace:
        optional experiment namespace folded into every task key, so two
        different experiment functions can share one workdir/cache without
        serving each other's results.
    """

    def __init__(
        self,
        exp_func: Callable[[Context], Any],
        notification_provider: NotificationProvider | None = None,
        workdir: str | Path | None = None,
        runner_config: RunnerConfig | None = None,
        cache: BaseCache | None = None,
        namespace: str | None = None,
    ):
        self.exp_func = exp_func
        self.provider = notification_provider or ConsoleNotificationProvider(verbose=False)
        self.workdir = Path(workdir) if workdir is not None else None
        self.runner_config = runner_config or RunnerConfig()
        self.namespace = namespace
        if cache is not None:
            self.cache = cache
        elif self.workdir is not None:
            self.cache = FsCache(self.workdir / "cache")
        else:
            self.cache = MemoryCache()
        self._ckpt_root = str(self.workdir / "task_ckpts") if self.workdir else None

    def _specs(self, config_matrix: Mapping[str, Any] | MatrixBase) -> list[TaskSpec]:
        return as_matrix(config_matrix).task_list(namespace=self.namespace)

    # -- paper API ------------------------------------------------------------
    def run(
        self,
        config_matrix: Mapping[str, Any] | MatrixBase,
        dry_run: bool = False,
        force: bool = False,
        cache: bool = True,
    ) -> ResultSet:
        """Execute the matrix and block until every task has a result."""
        specs = self._specs(config_matrix)
        if dry_run:
            # Paper semantics: report what *would* run, execute nothing.
            for spec in specs:
                try:
                    self.provider.task_dry(spec)
                except Exception:
                    pass  # providers must never take the run down
            return ResultSet(
                [TaskResult(spec=s, status="skipped", value=None) for s in specs]
            )
        return ResultSet(
            self._stream_specs(specs, force=force, cache=cache)
        ).materialize()

    # -- streaming API ---------------------------------------------------------
    def stream(
        self,
        config_matrix: Mapping[str, Any] | MatrixBase,
        force: bool = False,
        cache: bool = True,
    ) -> Iterator[TaskResult]:
        """Yield each task's final result as soon as it completes.

        Cached results arrive first (before any execution starts), then live
        results in completion order — consume incrementally to analyse or
        plot a sweep while its stragglers are still running. Wrap in
        ``ResultSet`` for ordered, lazy assembly.
        """
        return self._stream_specs(self._specs(config_matrix), force=force, cache=cache)

    def _stream_specs(
        self, specs: list[TaskSpec], force: bool, cache: bool
    ) -> Iterator[TaskResult]:
        runner = Runner(
            self.exp_func,
            cache=self.cache if cache else NullCache(),
            provider=self.provider,
            config=self.runner_config,
            checkpoint_root=self._ckpt_root,
            manifest_extra={"namespace": self.namespace},
        )
        return runner.stream(specs, force=force)

    # -- cache maintenance ------------------------------------------------------
    def invalidate(self, **partial_params: Any) -> int:
        """Delete every cached result whose task assignment matches the
        partial params dict — per-axis invalidation, e.g.
        ``eng.invalidate(arch="llama3.2-3b")`` drops that model's whole
        sweep column while every other cached cell survives.

        Matching is against the param reprs recorded in each entry's
        manifest (every key in ``partial_params`` must be present and
        equal), and is namespace-aware: only entries written under this
        Memento's namespace are touched. Returns the number of entries
        removed. With no arguments, every entry of this namespace goes.
        """
        from .cache import param_repr

        want = {k: param_repr(v) for k, v in partial_params.items()}
        ns = str(self.namespace) if self.namespace else None
        n = 0
        for key in list(self.cache.keys()):
            man = self.cache.manifest(key)
            if man is None:
                continue
            man_ns = man.get("namespace") or None
            if man_ns != ns:
                continue
            params = man.get("params")
            if params is None:
                continue  # entry predates param manifests; leave it alone
            if all(params.get(k) == v for k, v in want.items()):
                self.cache.invalidate(key)
                n += 1
        return n

    # -- cluster API ------------------------------------------------------------
    def stream_distributed(
        self,
        config_matrix: Mapping[str, Any] | MatrixBase,
        queue_dir: str | Path,
        lease_s: float = 120.0,
        publish: bool = True,
        max_attempts: int | None = None,
        owner: str | None = None,
        distributed_config: DistributedConfig | None = None,
    ) -> Iterator[TaskResult]:
        """Cooperatively drain ``config_matrix`` with other launcher hosts,
        yielding each task's final result as soon as it is known *anywhere*.

        Every participating host calls this with the same matrix + queue_dir
        (a shared filesystem) and a shared ``workdir`` (the FsCache is how
        results travel between hosts). Cache hits stream out first; then the
        host's full local Runner (thread pool, retries, timeouts, straggler
        speculation) drains the queue while completions from *other* hosts —
        discovered by polling ``done/`` + the shared cache — interleave into
        the same stream. A background thread renews the lease of every
        locally-claimed task, so tasks need not call ``ctx.heartbeat()`` to
        stay alive; host death is covered by lease expiry + re-claim.

        Failures are retried across hosts: up to ``max_attempts`` queue-level
        attempts (each one a full local run, including this host's own
        ``RunnerConfig.retries``) may land on any mix of hosts, after which
        the task surfaces as ``failed`` carrying the original error and
        traceback from ``done/<key>.json``.
        """
        specs = self._specs(config_matrix)
        queue = FileQueue(queue_dir, lease_s=lease_s, owner=owner)
        if publish:
            queue.publish(specs)
        runner = Runner(
            self.exp_func,
            cache=self.cache,
            provider=self.provider,
            config=self.runner_config,
            checkpoint_root=self._ckpt_root,
            manifest_extra={"namespace": self.namespace},
        )
        cfg = distributed_config or DistributedConfig()
        if max_attempts is not None:
            # explicit argument wins over (or fills in) the config object
            cfg = replace(cfg, max_attempts=max_attempts)
        return stream_distributed(runner, queue, specs, cfg)

    def run_distributed(
        self,
        config_matrix: Mapping[str, Any] | MatrixBase,
        queue_dir: str | Path,
        lease_s: float = 120.0,
        publish: bool = True,
        max_attempts: int | None = None,
        owner: str | None = None,
        distributed_config: DistributedConfig | None = None,
    ) -> ResultSet:
        """Blocking collector over :meth:`stream_distributed` — every host
        gets the full matrix's ResultSet (ours + peers'), in matrix order,
        with failure results carrying the real error from whichever host
        recorded it."""
        return ResultSet(
            self.stream_distributed(
                config_matrix,
                queue_dir,
                lease_s=lease_s,
                publish=publish,
                max_attempts=max_attempts,
                owner=owner,
                distributed_config=distributed_config,
            )
        ).materialize()
