"""Exception taxonomy for the Memento engine.

Every failure mode the runner distinguishes gets its own type so retry /
quarantine / notification policies can dispatch on it.
"""
from __future__ import annotations


class MementoError(Exception):
    """Base class for all Memento engine errors."""


class ConfigMatrixError(MementoError):
    """The configuration matrix is malformed (schema, empty axis, bad exclude)."""


class HashingError(MementoError):
    """A parameter value cannot be canonicalised into a stable hash."""


class CacheError(MementoError):
    """The result cache is unreadable / unwritable."""


class CacheCorruptionError(CacheError):
    """A cache entry exists but fails integrity checks; it will be quarantined."""


class TaskFailedError(MementoError):
    """A task raised; carries the serialized traceback from the worker."""

    def __init__(self, key: str, message: str, traceback_str: str = ""):
        super().__init__(f"task {key} failed: {message}")
        self.key = key
        self.message = message
        self.traceback_str = traceback_str


class TaskTimeoutError(TaskFailedError):
    """A task exceeded its hard timeout and was abandoned."""

    def __init__(self, key: str, timeout_s: float):
        super().__init__(key, f"exceeded hard timeout of {timeout_s:.1f}s")
        self.timeout_s = timeout_s


class RetriesExhaustedError(TaskFailedError):
    """A task failed more times than the retry budget allows."""


class CheckpointError(MementoError):
    """Task-level checkpoint save/restore failed."""


class QueueError(MementoError):
    """The distributed file-queue protocol hit an unrecoverable state."""


class LeaseExpiredError(QueueError):
    """A worker's claim lease expired and the task was reclaimed elsewhere."""
