"""Version-compat shims over the moving parts of the JAX API surface.

The repo targets the newest stable API names; everything older is adapted
here so call sites stay clean. Currently covered:

  * ``shard_map`` — moved from ``jax.experimental.shard_map`` to ``jax``;
    the replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.
  * ``tree_flatten_with_path`` — ``jax.tree.flatten_with_path`` only exists
    on newer jax; ``jax.tree_util.tree_flatten_with_path`` is the stable
    spelling.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax

try:
    from jax import shard_map as _shard_map_impl
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map_impl).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """``jax.shard_map`` with the replication-check kwarg normalized."""
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: check}
    )


def tree_flatten_with_path(tree: Any):
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)
