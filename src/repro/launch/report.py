"""Assemble the roofline report from the Memento-cached dry-run results.

Usage:
    PYTHONPATH=src python -m repro.launch.report            # print tables
    PYTHONPATH=src python -m repro.launch.report --json out.json
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Any

from repro.configs.base import ALL_SHAPES, SHAPES_BY_NAME, shape_applicable
from repro.configs.registry import get_config, list_archs
from repro.core import ConfigMatrix, FsCache
from repro.launch.dryrun import RESULTS_DIR, sweep_matrix


def load_results(meshes=(False, True)) -> tuple[list[dict], list[dict]]:
    """(compiled rows, skipped rows) from the dry-run cache."""
    cache = FsCache(RESULTS_DIR / "cache")
    matrix = ConfigMatrix.from_dict(sweep_matrix(list(meshes)))
    rows, missing = [], []
    for task in matrix.tasks():
        entry = cache.get(task.key)
        if entry is None:
            missing.append(task.params)
            continue
        rows.append(entry.value)
    skipped = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                skipped.append({"arch": arch, "shape": shape.name, "why": why})
    if missing:
        print(f"WARNING: {len(missing)} cells missing from cache: {missing[:4]} ...")
    return rows, skipped


def _fmt_seconds(x: float) -> str:
    if x >= 100:
        return f"{x:8.1f}"
    if x >= 1:
        return f"{x:8.3f}"
    return f"{x:8.4f}"


def baseline_table(rows: list[dict], mesh: str = "16x16") -> str:
    hdr = (
        f"| {'arch':26s} | {'shape':11s} | {'profile':14s} | t_comp(s) | t_mem(s) | "
        f"t_coll(s) | bottleneck | useful | roofl% | HBM GiB/dev |"
    )
    sep = "|" + "|".join("-" * (len(c) + 2) for c in hdr.split("|")[1:-1]) + "|"
    lines = [hdr, sep]
    for v in sorted(rows, key=lambda v: (v["arch"], v["shape"])):
        if v["mesh"] != mesh or not v.get("roofline"):
            continue
        r = v["roofline"]
        lines.append(
            f"| {v['arch']:26s} | {v['shape']:11s} | {v['profile']:14s} | "
            f"{_fmt_seconds(r['t_compute'])} | {_fmt_seconds(r['t_memory'])} | "
            f"{_fmt_seconds(r['t_collective'])} | {r['bottleneck']:10s} | "
            f"{100*r['useful_flops_fraction']:5.1f}% | {100*r['roofline_fraction']:5.1f}% | "
            f"{r['per_device_memory_bytes']/2**30:11.2f} |"
        )
    return "\n".join(lines)


def collective_detail(rows: list[dict], mesh: str = "16x16") -> str:
    lines = []
    for v in sorted(rows, key=lambda v: (v["arch"], v["shape"])):
        if v["mesh"] != mesh or not v.get("roofline"):
            continue
        r = v["roofline"]
        ops = ", ".join(
            f"{k}:{b/2**30:.2f}GiB(x{r['op_counts'].get(k, 0)})"
            for k, b in sorted(r["op_bytes"].items(), key=lambda kv: -kv[1])
            if b > 0
        )
        lines.append(f"  {v['arch']:26s} {v['shape']:11s} {ops or '(none)'}")
    return "\n".join(lines)


def pick_hillclimb_cells(rows: list[dict]) -> dict[str, dict]:
    """worst roofline fraction, most collective-bound, most paper-representative."""
    sp = [v for v in rows if v["mesh"] == "16x16" and v.get("roofline")]
    worst = min(sp, key=lambda v: v["roofline"]["roofline_fraction"])
    coll = max(
        sp,
        key=lambda v: v["roofline"]["t_collective"]
        / max(v["roofline"]["step_time_lower_bound"], 1e-9),
    )
    # "most representative of the paper's technique": the paper is the
    # orchestration layer, whose heaviest managed workload is the biggest
    # training cell — the one a Memento-run sweep spends its time on.
    train = [v for v in sp if v["shape"] == "train_4k"]
    rep = max(train, key=lambda v: v["roofline"]["model_flops"])
    return {"worst_roofline": worst, "most_collective": coll, "representative": rep}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows, skipped = load_results()
    print(f"{len(rows)} compiled cells, {len(skipped)} skipped cells\n")
    print(baseline_table(rows, args.mesh))
    print("\nSkipped (per assignment):")
    for s in skipped:
        print(f"  {s['arch']:26s} {s['shape']:11s} {s['why']}")
    print("\nCollective breakdown:")
    print(collective_detail(rows, args.mesh))
    picks = pick_hillclimb_cells(rows)
    print("\nHillclimb picks:")
    for k, v in picks.items():
        print(f"  {k:16s} -> {v['arch']} x {v['shape']}")
    if args.json:
        Path(args.json).write_text(json.dumps({"rows": rows, "skipped": skipped}, indent=1, default=str))


if __name__ == "__main__":
    main()
