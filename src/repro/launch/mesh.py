"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod = 256 chips as (16, 16) -> ("data", "model");
multi-pod = 2 x 256 as (2, 16, 16) -> ("pod", "data", "model").

The dry-run launcher sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; everything else (tests, benches) sees the real single
CPU device and uses ``make_test_mesh``.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            "visible. The dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import (repro/launch/dryrun.py does this)."
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def devices_required(n: int) -> bool:
    """True when at least ``n`` XLA devices are visible.

    Multi-device tests gate on this to *skip* (not fail) on 1-device CI:
    ``pytest.mark.skipif(not devices_required(2), ...)``. The CI
    sharded-smoke lane sets ``--xla_force_host_platform_device_count=8``
    so the same tests run there for real.
    """
    return len(jax.devices()) >= n


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many devices exist (CPU tests: 1x1)."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"test mesh {dict(zip(axes, shape))} needs {n} devices but only "
            f"{len(devices)} are visible. Forcing host devices must happen "
            "before the first jax import: set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} in the "
            "environment (tests should gate on mesh.devices_required() to "
            "skip instead of failing on 1-device CI)."
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
