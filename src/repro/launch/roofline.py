"""Roofline model for TPU v5e from compiled dry-run artifacts.

Hardware constants (assignment-specified):
    peak bf16 compute: 197 TFLOP/s per chip
    HBM bandwidth:     819 GB/s per chip
    ICI link:          ~50 GB/s per link

Sources:
  * ``compiled.cost_analysis()`` -> HLO_FLOPs, HLO_bytes. On this backend the
    numbers are per-device (the SPMD-partitioned module), verified against a
    hand-computed matmul in tests.
  * collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO
    text and sum, per collective op, the bytes each device moves over ICI
    using standard ring-algorithm factors:
        all-gather:        out_local * (n-1)/n      (receives the other shards)
        reduce-scatter:    in_local  * (n-1)/n
        all-reduce:        2 * in_local * (n-1)/n   (RS + AG)
        all-to-all:        in_local  * (n-1)/n
        collective-permute: in_local                (one hop send)
    with n = participants per replica group, parsed from replica_groups.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (we model one serialized link — conservative)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  bf16[16,4096,128]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(line: str) -> int:
    """Bytes of the op result (first shape(s) on the line, incl. tuples)."""
    head = line.split(" = ", 1)
    if len(head) != 2:
        return 0
    lhs_types = head[1]
    # result type is everything before the op name; find the op name position
    m = re.search(r"\)? *(" + "|".join(_COLLECTIVES) + r")", lhs_types)
    region = lhs_types[: m.start()] if m else lhs_types
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(region))


def _operand_bytes(line: str) -> int:
    """Bytes of operands (shapes inside the call parens)."""
    m = re.search(r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", line)
    if not m:
        return 0
    args = line[m.end() :]
    depth = 1
    out = []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out.append(ch)
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall("".join(out)))


def _group_size(line: str, total_devices: int) -> int:
    # iota form: replica_groups=[8,32]<=[...] -> groups of 32
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2,3},{...}} -> first group size
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\{\}", line)
    if m:
        return total_devices
    return total_devices


@dataclass
class CollectiveStats:
    per_device_bytes: float = 0.0
    op_bytes: dict[str, float] = field(default_factory=dict)
    op_counts: dict[str, int] = field(default_factory=dict)
    unattributed_comps: int = 0


# --------------------------------------------------------------------------
# Loop-aware parsing: scan bodies appear once in the HLO text but execute
# trip-count times. We reconstruct computations, while-op edges, and trip
# counts (the s32 constant in the loop condition), then weight each
# computation's collectives by the product of enclosing trip counts.
# --------------------------------------------------------------------------
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls|body|branch_computations)=\{?%?([\w.\-]+(?:, *%?[\w.\-]+)*)\}?")
_S32_CONST_RE = re.compile(r"s32\[\] constant\((\d+)\)")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _split_computations(hlo_text: str) -> tuple[dict[str, list[str]], str | None]:
    """Computation name -> body lines. Headers are column-0 lines ending in
    '{'; the name is the token before the first '(' (names may contain dots,
    dashes, 'wide.' prefixes and nested-paren arg lists, so no full-line
    regex — just the prefix token)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: str | None = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{") and "(" in line:
            name = line.split("(")[0].strip()
            is_entry = name.startswith("ENTRY")
            name = name.replace("ENTRY", "").strip().lstrip("%").strip()
            if not name:
                cur = None
                continue
            cur = name
            comps[cur] = []
            if is_entry:
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(_COMMENT_RE.sub("", line.strip()))
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    consts = [int(m.group(1)) for l in cond_lines for m in _S32_CONST_RE.finditer(l)]
    return max(consts) if consts else 1


def _comp_multipliers(comps: dict[str, list[str]], entry: str | None) -> dict[str, float]:
    mult: dict[str, float] = {}
    if entry is None:
        return {name: 1.0 for name in comps}

    def visit(name: str, m: float) -> None:
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for line in comps[name]:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                visit(body, m * _trip_count(comps.get(cond, [])))
                continue
            cm = _CALL_RE.search(line)
            if cm and "while(" not in line:
                for callee in re.split(r", *%?", cm.group(1)):
                    visit(callee, m)

    visit(entry, 1.0)
    return mult


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    comps, entry = _split_computations(hlo_text)
    if not comps:
        comps, entry = {"__all__": [l.strip() for l in hlo_text.splitlines()]}, "__all__"
    mult = _comp_multipliers(comps, entry)
    stats = CollectiveStats()
    for name, lines in comps.items():
        weight = mult.get(name)
        if weight is None:
            # Unreached in the call graph (parser gap): count once rather
            # than zero, and flag it.
            if any(f" {c}(" in l or f"{c}-start(" in l for l in lines for c in _COLLECTIVES):
                stats.unattributed_comps += 1
                weight = 1.0
            else:
                continue
        if weight == 0.0:
            continue
        _accumulate(lines, total_devices, stats, weight)
    return stats


def _accumulate(
    lines: list[str], total_devices: int, stats: CollectiveStats, weight: float
) -> None:
    for stripped in lines:
        op = next(
            (
                c
                for c in _COLLECTIVES
                if f" {c}(" in stripped or f"{c}-start(" in stripped
            ),
            None,
        )
        if op is None:
            continue
        if f"{op}-done" in stripped:
            continue  # paired with -start; don't double count
        n = _group_size(stripped, total_devices)
        if n <= 1:
            continue
        # Post-SPMD HLO body lines carry only RESULT shapes (operands are
        # bare refs), so byte costs derive from the result + op semantics.
        r = _result_bytes(stripped)
        if op == "all-gather":
            moved = r * (n - 1) / n  # result = gathered; each device receives the rest
        elif op == "reduce-scatter":
            moved = r * (n - 1)  # operand = result * n; ring cost = operand*(n-1)/n
        elif op == "all-reduce":
            moved = 2.0 * r * (n - 1) / n  # operand == result; RS + AG
        elif op == "all-to-all":
            moved = r * (n - 1) / n  # operand size == result size
        else:  # collective-permute
            moved = r
        moved *= weight
        stats.per_device_bytes += moved
        stats.op_bytes[op] = stats.op_bytes.get(op, 0.0) + moved
        stats.op_counts[op] = stats.op_counts.get(op, 0) + int(weight)


@dataclass
class Roofline:
    """Three-term roofline for one compiled (arch x shape x mesh) cell."""

    arch: str
    shape: str
    mesh: str
    chips: int
    # Primary terms: analytic op-accounting (costmodel.py); raw XLA
    # cost_analysis numbers are recorded alongside (while bodies counted
    # once — see costmodel.py docstring).
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    raw_cost_analysis_flops: float = 0.0
    raw_cost_analysis_bytes: float = 0.0
    collective_bytes_per_device: float = 0.0
    model_flops: float = 0.0  # 6 * N_active * D tokens (training) or fwd equivalent
    per_device_memory_bytes: float = 0.0
    op_bytes: dict[str, float] = field(default_factory=dict)
    op_counts: dict[str, int] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): how much compiled compute is
        'useful' model math (catches remat/redundancy waste)."""
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound = useful compute time / bound step time."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        lb = self.step_time_lower_bound
        return t_useful / lb if lb else 0.0

    def to_dict(self) -> dict[str, Any]:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_fraction=self.useful_flops_fraction,
            roofline_fraction=self.roofline_fraction,
            step_time_lower_bound=self.step_time_lower_bound,
        )
        return d


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6*N*D for training, 2*N*D for inference forward passes."""
    if shape.kind == "train":
        return 6.0 * n_params_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_params_active * shape.tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch


def predict_decode_step(
    cfg,
    n_params: int,
    batch: int,
    mesh_shape: tuple[int, int] = (1, 1),
    dtype_bytes: int = 2,
) -> Roofline:
    """Analytic roofline for ONE sharded decode step (no HLO needed).

    The serving sweep records this next to measured ``itl_p50`` so the
    B15 benchmark can report measured/predicted ratios per mesh. Terms:

      * compute — 2*N*B flops over ``data*model`` chips,
      * memory  — every device streams its 1/model weight shard once per
        step (decode is weight-bandwidth-bound; KV reads are second-order
        at serving batch sizes and deliberately excluded from the bound),
      * collective — tensor parallelism's two all-reduces per layer
        (attention o-proj + mlp down-proj) of (B, d_model) activations,
        ring cost ``2 * x * (model-1)/model`` each; zero at model=1.
    """
    data, model = (int(x) for x in mesh_shape)
    chips = max(data * model, 1)
    model = max(model, 1)
    flops = 2.0 * n_params * batch / chips
    weight_bytes = n_params * dtype_bytes / model
    act = batch * cfg.d_model * dtype_bytes
    coll = 2.0 * cfg.n_layers * (2.0 * act * (model - 1) / model)
    return Roofline(
        arch=cfg.name,
        shape=f"decode_b{batch}",
        mesh=f"{data}x{model}",
        chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=weight_bytes,
        collective_bytes_per_device=coll,
        model_flops=2.0 * n_params * batch,
        per_device_memory_bytes=weight_bytes,
    )


def format_table(rows: list[Roofline]) -> str:
    hdr = (
        f"{'arch':26s} {'shape':12s} {'mesh':9s} {'t_comp(s)':>10s} {'t_mem(s)':>10s} "
        f"{'t_coll(s)':>10s} {'bound':>10s} {'useful%':>8s} {'roofl%':>7s} {'GB/dev':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:26s} {r.shape:12s} {r.mesh:9s} {r.t_compute:>10.4f} "
            f"{r.t_memory:>10.4f} {r.t_collective:>10.4f} {r.bottleneck:>10s} "
            f"{100*r.useful_flops_fraction:>7.1f}% {100*r.roofline_fraction:>6.1f}% "
            f"{r.per_device_memory_bytes/2**30:>7.2f}"
        )
    return "\n".join(lines)
