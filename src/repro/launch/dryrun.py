import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")  # SPMD warning floods

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, WITHOUT allocating any real tensors
(ShapeDtypeStruct stand-ins everywhere):

  * a compiled SPMD executable for the production mesh,
  * ``compiled.memory_analysis()``  -> proves the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    -> per-device FLOPs/bytes for §Roofline,
  * the post-SPMD HLO collective schedule -> collective bytes for §Roofline.

The full 40-cell sweep is itself a Memento configuration matrix (the
paper's technique orchestrating its own evaluation): results are cached by
task hash under ``results/dryrun`` — interrupt and re-run freely.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all                 # single-pod sweep
  python -m repro.launch.dryrun --all --multipod      # 2-pod sweep
  python -m repro.launch.dryrun --all --both          # both meshes
"""
import argparse
import json
import time
import traceback
from dataclasses import dataclass
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)
from repro.configs.registry import get_config, list_archs
from repro.core import ConfigMatrix, ConsoleNotificationProvider, Context, Memento, RunnerConfig
from repro.launch import costmodel as cm
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import lm
from repro.models.schema import count_params, is_spec
from repro.serve.step import (
    decode_state_specs,
    make_decode_step,
    make_prefill_step,
    serve_param_specs,
    token_specs,
)
from repro.sharding.rules import ShardingCtx, get_profile
from repro.train.step import batch_specs, make_train_setup, make_train_step

RESULTS_DIR = Path(os.environ.get("REPRO_RESULTS", "results")) / "dryrun"


# ==========================================================================
# Cell definition
# ==========================================================================
def profile_name_for(cfg: ModelConfig, shape: ShapeConfig, override: str = "") -> str:
    if override:
        return override
    if shape.kind in ("train", "prefill"):
        return cfg.train_profile or cfg.sharding_profile
    if shape.name == "long_500k":
        return "decode_long"
    return cfg.decode_profile or "decode_default"


def active_param_count(cfg: ModelConfig) -> int:
    """Params that do math for one token (MODEL_FLOPS = 6 * N_active * D)."""
    schema = lm.model_schema(cfg)
    total = count_params(schema)
    # Embedding gather costs no FLOPs; tied unembed still does the matmul.
    total -= cfg.padded_vocab * cfg.d_model
    if cfg.tie_embeddings:
        total += cfg.padded_vocab * cfg.d_model
    if cfg.moe is not None:
        n_moe_layers = sum(
            1 for k in cfg.first_blocks if k == "attn_moe"
        ) + cfg.n_pattern_groups * sum(1 for k in cfg.block_pattern if k == "attn_moe")
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert
        total -= n_moe_layers * (cfg.moe.n_experts - cfg.moe.top_k) * per_expert
    return int(total)


@dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    profile: str
    ok: bool
    compile_s: float = 0.0
    error: str = ""
    roofline: dict[str, Any] | None = None
    memory: dict[str, Any] | None = None


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, profile_override: str = ""
) -> CellResult:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    applicable, why = shape_applicable(cfg, shape)
    if not applicable:
        return CellResult(arch, shape_name, mesh_name, "-", ok=True, error=f"SKIP: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    pname = profile_name_for(cfg, shape, profile_override)
    sctx = ShardingCtx(mesh=mesh, profile=get_profile(pname))
    chips = mesh_chip_count(mesh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            setup = make_train_setup(cfg, shape, sctx)
            fn = make_train_step(setup)
            args = (setup.abstract_state(), setup.abstract_batch())
            lowered = jax.jit(fn, donate_argnums=(0,)).lower(*args)
        elif shape.kind == "prefill":
            fn = make_prefill_step(cfg, sctx)
            params = serve_param_specs(cfg, sctx)
            args = (params, batch_specs(cfg, shape, sctx))
            lowered = jax.jit(fn).lower(*args)
        else:  # decode
            fn = make_decode_step(cfg, sctx)
            params = serve_param_specs(cfg, sctx)
            states = decode_state_specs(cfg, shape, sctx)
            args = (params, states, token_specs(shape, sctx))
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(*args)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    memory = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
    }
    per_device_bytes = (
        memory["argument_bytes"] + memory["temp_bytes"] + memory["output_bytes"]
        - memory["alias_bytes"]
    )

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = rf.parse_collectives(hlo, chips)
    cost = cm.analytic_cost(cfg, shape, chips)

    roof = rf.Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_per_device=cost.flops_per_device,
        hlo_bytes_per_device=cost.bytes_per_device,
        raw_cost_analysis_flops=raw_flops,
        raw_cost_analysis_bytes=raw_bytes,
        collective_bytes_per_device=coll.per_device_bytes,
        model_flops=rf.model_flops(cfg, shape, active_param_count(cfg)),
        per_device_memory_bytes=per_device_bytes,
        op_bytes=coll.op_bytes,
        op_counts=coll.op_counts,
    )
    return CellResult(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        profile=pname,
        ok=True,
        compile_s=compile_s,
        roofline=roof.to_dict(),
        memory=memory,
    )


# ==========================================================================
# Memento-orchestrated sweep
# ==========================================================================
def dryrun_exp(ctx: Context) -> dict[str, Any]:
    """The Memento experiment function: one dry-run cell per task."""
    try:
        res = run_cell(
            ctx["arch"], ctx["shape"], ctx["multi_pod"], ctx.settings.get("profile", "")
        )
    except Exception as e:  # captured into the result, run continues
        res = CellResult(
            ctx["arch"], ctx["shape"], "2x16x16" if ctx["multi_pod"] else "16x16",
            "-", ok=False, error=f"{type(e).__qualname__}: {e}\n{traceback.format_exc()}",
        )
    return res.__dict__


def config_revision(archs) -> str:
    """Fingerprint of every arch config + sharding profile, so the Memento
    cache key changes whenever the configuration (not just the cell name)
    changes — stale-result reuse is impossible by construction."""
    from repro.core.hashing import stable_hash
    from repro.sharding.rules import PROFILES

    payload = {
        "configs": {a: get_config(a) for a in archs},
        "profiles": {k: (v.rules, v.zero1, v.fsdp_params) for k, v in PROFILES.items()},
    }
    return stable_hash(payload)[:16]


def sweep_matrix(meshes: list[bool], archs=None, shapes=None) -> dict[str, Any]:
    archs = archs or list_archs()
    shapes = shapes or [s.name for s in ALL_SHAPES]
    exclude = []
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            appl, _ = shape_applicable(cfg, SHAPES_BY_NAME[s])
            if not appl:
                # Keep skipped cells OUT of the compile queue; they are
                # reported as skipped rows by the report generator.
                for mp in meshes:
                    exclude.append({"arch": a, "shape": s, "multi_pod": mp})
    return {
        "parameters": {
            "arch": archs,
            "shape": shapes,
            "multi_pod": meshes,
            "rev": [config_revision(archs)],
        },
        "settings": {},
        "exclude": exclude,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both", action="store_true", help="single-pod AND multi-pod")
    ap.add_argument("--all", action="store_true", help="full sweep via Memento")
    ap.add_argument("--profile", default="", help="sharding profile override")
    ap.add_argument("--force", action="store_true", help="ignore the result cache")
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args()

    if args.all or (not args.arch):
        meshes = [False, True] if args.both else [args.multipod]
        matrix = sweep_matrix(meshes)
        if args.profile:
            matrix["settings"]["profile"] = args.profile
        eng = Memento(
            dryrun_exp,
            ConsoleNotificationProvider(),
            workdir=str(RESULTS_DIR),
            runner_config=RunnerConfig(
                max_workers=args.workers, retries=0, enable_speculation=False
            ),
        )
        results = eng.run(matrix, force=args.force)
        rows, failed, skipped = [], [], []
        for r in results:
            if not r.ok:
                failed.append(r)
                continue
            v = r.value
            if v.get("error", "").startswith("SKIP"):
                skipped.append(v)
            elif v.get("roofline"):
                rows.append(v)
            else:
                failed.append(r)
        print(f"\n=== dry-run sweep: {len(rows)} compiled, {len(skipped)} skipped, "
              f"{len(failed)} failed ===")
        for v in rows:
            rl = v["roofline"]
            print(
                f"  {v['arch']:26s} {v['shape']:12s} {v['mesh']:9s} {v['profile']:15s} "
                f"compile={v['compile_s']:6.1f}s bottleneck={rl['bottleneck']:10s} "
                f"mem/dev={rl['per_device_memory_bytes']/2**30:6.2f}GiB"
            )
        for v in skipped:
            print(f"  {v['arch']:26s} {v['shape']:12s} SKIPPED ({v['error'][6:]})")
        for r in failed:
            err = r.error or (r.value or {}).get("error", "")
            print(f"  FAILED {r.spec.params}: {str(err)[:400]}")
        raise SystemExit(1 if failed else 0)

    res = run_cell(args.arch, args.shape, args.multipod, args.profile)
    print(json.dumps(res.__dict__, indent=2, default=str))


if __name__ == "__main__":
    main()
