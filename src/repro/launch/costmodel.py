"""Analytic per-cell cost model: FLOPs and HBM bytes per device per step.

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts every while-loop
body exactly ONCE (verified in tests/test_roofline.py with a trip-count
sweep), and this framework deliberately scans layers/microbatches, so raw
cost_analysis under-reports large models by 2-3 orders of magnitude. The
roofline therefore uses this explicit op-accounting model, validated against
XLA ground truth on a fully-unrolled small cell (whisper-tiny; see
EXPERIMENTS.md §Dry-run validation), with raw cost_analysis numbers recorded
alongside for transparency. Collective bytes ARE taken from the HLO, with a
loop-aware parser that multiplies by scan trip counts (roofline.py).

Conventions:
  * flops count multiply+add as 2
  * attention is causal: average K length = T/2 (window: min(window, T/2))
  * backward = 2x forward; full remat adds one extra forward of the scanned
    stack (the unembed/xent sits outside the remat scope)
  * per-device numbers divide global totals by the chip count — SPMD keeps
    per-chip work uniform for every sharding profile we emit
  * HBM traffic is a napkin model: weight bytes per pass, optimizer/grad
    state traffic, residual-stream activations (K_ACT tensor-passes per
    layer), KV-cache reads for decode, logits traffic for the chunked xent
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.configs.base import ModelConfig, ShapeConfig

BF16 = 2
F32 = 4
K_ACT_FWD = 16  # residual-stream tensor passes per layer, forward
K_ACT_BWD = 32  # and backward


# ==========================================================================
# FLOPs
# ==========================================================================
def _attn_flops(cfg: ModelConfig, T: float, kv_len: float, causal: bool) -> float:
    """GQA attention for T query tokens against kv_len keys (per layer)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * T * d * (nq * hd) * 2 + 2 * T * d * (nkv * hd) * 2  # q,o + k,v
    eff_kv = kv_len / 2 if causal else kv_len
    core = 2 * 2 * T * eff_kv * nq * hd  # scores + AV
    return proj + core


def _mla_flops(cfg: ModelConfig, T: float, kv_len: float, decode: bool) -> float:
    m = cfg.mla
    d, nq = cfg.d_model, cfg.n_heads
    qk = m.nope_dim + m.rope_dim
    fl = 2 * T * d * m.q_lora + 2 * T * m.q_lora * nq * qk  # q path
    fl += 2 * T * d * (m.kv_lora + m.rope_dim)  # kv down
    if decode:
        # absorbed: q_abs, scores vs ckv+rope, ctx, v_b expansion, o
        fl += 2 * T * nq * m.nope_dim * m.kv_lora
        fl += 2 * T * kv_len * nq * (m.kv_lora + m.rope_dim)
        fl += 2 * T * kv_len * nq * m.kv_lora
        fl += 2 * T * nq * m.kv_lora * m.v_dim
    else:
        eff = kv_len / 2
        fl += 2 * T * m.kv_lora * nq * (m.nope_dim + m.v_dim)  # k_b, v_b
        fl += 2 * 2 * T * eff * nq * qk  # scores+AV (v_dim~nope_dim)
    fl += 2 * T * (nq * m.v_dim) * d  # o proj
    return fl


def _moe_flops(cfg: ModelConfig, T: float) -> float:
    mo = cfg.moe
    d = cfg.d_model
    fl = 2 * T * d * mo.n_experts  # router
    fl += 6 * T * mo.top_k * d * mo.d_ff_expert  # routed experts (3 matmuls)
    fl += 6 * T * d * (mo.n_shared * mo.d_ff_expert)  # shared experts
    return fl


def _mlp_flops(cfg: ModelConfig, T: float, d_ff: int | None = None) -> float:
    return 6 * T * cfg.d_model * (d_ff or cfg.d_ff)


def _rglru_flops(cfg: ModelConfig, T: float) -> float:
    d, dr, K = cfg.d_model, cfg.d_rnn, cfg.conv_width
    fl = 2 * T * d * dr * 2  # two input projections
    fl += 2 * T * dr * dr * 2  # two gate matmuls
    fl += 2 * T * K * dr + 10 * T * dr  # conv + scan elementwise
    fl += 2 * T * dr * d  # out projection
    return fl


def _mlstm_flops(cfg: ModelConfig, T: float, chunk: int = 64) -> float:
    d = cfg.d_model
    dp = int(cfg.mlstm_proj_factor * d)
    nh = cfg.n_heads
    dh = dp // nh
    L = max(1, min(chunk, int(T) or 1))
    fl = 2 * T * d * dp * 2  # up projections
    fl += 2 * T * cfg.conv_width * dp
    fl += 3 * 2 * T * dh * dp  # block-diag qkv
    fl += 2 * 2 * T * dp * nh / dp * 0  # gates negligible
    # chunked core: intra (scores+AV over L) + inter/state (dh^2 per token x2)
    fl += T * nh * (4 * L * dh + 4 * dh * dh)
    fl += 2 * T * dp * d  # down
    return fl


def _slstm_flops(cfg: ModelConfig, T: float) -> float:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ffs = int(cfg.slstm_proj_factor * d)
    fl = 2 * T * d * 4 * d  # W gates
    fl += 8 * T * d * dh  # recurrent R per step (4 gates, block-diag)
    fl += 6 * T * d * ffs  # post FFN
    return fl


def _block_flops(cfg: ModelConfig, kind: str, T: float, kv_len: float, decode: bool) -> float:
    causal = True
    if kind in ("attn_mlp", "attn_moe"):
        if cfg.attn_kind == "mla":
            a = _mla_flops(cfg, T, kv_len, decode)
        else:
            a = _attn_flops(cfg, T, kv_len, causal and not decode)
            if decode:  # decode attends full cache, not half
                a += 2 * 2 * T * (kv_len / 2) * cfg.n_heads * cfg.resolved_head_dim
        f = _moe_flops(cfg, T) if kind == "attn_moe" else _mlp_flops(cfg, T)
        return a + f
    if kind == "local_attn":
        eff = min(cfg.window_size, kv_len)
        a = _attn_flops(cfg, T, 2 * eff if decode else min(2 * eff, kv_len), True)
        return a + _mlp_flops(cfg, T)
    if kind == "rglru":
        return _rglru_flops(cfg, T) + _mlp_flops(cfg, T)
    if kind == "mlstm":
        return _mlstm_flops(cfg, T)
    if kind == "slstm":
        return _slstm_flops(cfg, T)
    if kind == "cross_attn_mlp":
        a = _attn_flops(cfg, T, kv_len, not decode)
        # cross attention: q/o projections on T, scores vs enc_seq
        x = 2 * T * cfg.d_model * cfg.n_heads * cfg.resolved_head_dim * 2
        x += 2 * 2 * T * cfg.enc_seq * cfg.n_heads * cfg.resolved_head_dim
        return a + x + _mlp_flops(cfg, T)
    raise ValueError(kind)


def _layers(cfg: ModelConfig) -> list[str]:
    return list(cfg.first_blocks) + list(cfg.block_pattern) * cfg.n_pattern_groups


def forward_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global forward FLOPs of one step of this cell."""
    decode = shape.kind == "decode"
    B = shape.global_batch
    if decode:
        T = float(B)  # one token per sequence
        kv_len = float(shape.seq_len)
    else:
        T = float(shape.tokens)
        kv_len = float(shape.seq_len)
    fl = sum(_block_flops(cfg, k, T, kv_len, decode) for k in _layers(cfg))
    fl += 2 * T * cfg.d_model * cfg.padded_vocab  # unembed
    if cfg.enc_dec and not decode:
        enc_T = float(B * cfg.enc_seq)
        enc_fl = _attn_flops(cfg, enc_T, cfg.enc_seq, causal=False) + _mlp_flops(cfg, enc_T)
        fl += cfg.n_enc_layers * enc_fl
    if cfg.prefix_len and not decode:
        fl += 2 * B * cfg.prefix_len * cfg.d_model * cfg.d_model  # prefix proj
    return fl


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    f = forward_flops(cfg, shape)
    if shape.kind != "train":
        return f
    factor = 3.0  # fwd + bwd
    if cfg.remat == "full":
        factor += 1.0  # recompute of the scanned stack; xent ~unrematted (small vs total)
    return factor * f


# ==========================================================================
# HBM bytes
# ==========================================================================
def param_bytes(cfg: ModelConfig) -> int:
    from repro.models import lm as _lm
    from repro.models.schema import count_params

    return count_params(_lm.model_schema(cfg))


def cell_bytes_per_device(
    cfg: ModelConfig, shape: ShapeConfig, chips: int, int8_moments: bool = False
) -> float:
    """Per-device HBM traffic of one step (napkin model, documented above)."""
    n_params = param_bytes(cfg)
    d = cfg.d_model
    n_layers = len(_layers(cfg))
    V = cfg.padded_vocab

    if shape.kind == "train":
        n_micro = max(1, cfg.train_microbatches)
        T_loc = shape.tokens / chips
        T_micro = T_loc / n_micro
        passes = 3.0 + (1.0 if cfg.remat == "full" else 0.0)
        w = n_params * BF16 / chips  # weights fully sharded (ZeRO-1/3) once gathered
        weights = n_micro * passes * w
        opt = n_params / chips * ((4 + 1 + 1) * 2 if int8_moments else (4 + 4 + 4) * 2)
        grads = n_micro * n_params / chips * 2 * F32
        acts = n_micro * n_layers * (K_ACT_FWD + K_ACT_BWD) * T_micro * d * BF16
        logits = n_micro * 3 * T_micro * V * BF16
        return weights + opt + grads + acts + logits

    if shape.kind == "prefill":
        T_loc = shape.tokens / chips
        weights = n_params * BF16 / chips
        acts = n_layers * K_ACT_FWD * T_loc * d * BF16
        kv_write = _decode_state_bytes(cfg, shape) / chips
        logits = 3 * (shape.global_batch / chips) * V * BF16  # last position only
        return weights + acts + kv_write + logits

    # decode: weights + full cache read + small activations
    B_loc = shape.global_batch / chips
    weights = n_params * BF16 / chips
    cache = _decode_state_bytes(cfg, shape) / chips * 2  # read + write-back
    acts = n_layers * K_ACT_FWD * B_loc * d * BF16
    logits = 3 * B_loc * V * BF16
    return weights + cache + acts + logits


def _decode_state_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global bytes of the decode state (KV caches / recurrent states)."""
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in _layers(cfg):
        if kind in ("attn_mlp", "attn_moe"):
            if cfg.attn_kind == "mla":
                total += B * S * (cfg.mla.kv_lora + cfg.mla.rope_dim) * BF16
            else:
                total += 2 * B * S * cfg.n_kv_heads * hd * BF16
        elif kind == "local_attn":
            total += 2 * B * min(cfg.window_size, S) * cfg.n_kv_heads * hd * BF16
        elif kind == "rglru":
            total += B * cfg.d_rnn * F32 + B * (cfg.conv_width - 1) * cfg.d_rnn * F32
        elif kind == "mlstm":
            dp = int(cfg.mlstm_proj_factor * cfg.d_model)
            nh = cfg.n_heads
            dh = dp // nh
            total += B * nh * (dh * dh + dh + 1) * F32 + B * (cfg.conv_width - 1) * dp * F32
        elif kind == "slstm":
            total += 4 * B * cfg.d_model * F32
        elif kind == "cross_attn_mlp":
            total += 2 * B * S * cfg.n_kv_heads * hd * BF16
            total += 2 * B * cfg.enc_seq * cfg.n_kv_heads * hd * BF16
    return total


@dataclass
class AnalyticCost:
    flops_total: float
    flops_per_device: float
    bytes_per_device: float
    state_bytes_total: float


def analytic_cost(
    cfg: ModelConfig, shape: ShapeConfig, chips: int, int8_moments: bool = False
) -> AnalyticCost:
    fl = cell_flops(cfg, shape)
    return AnalyticCost(
        flops_total=fl,
        flops_per_device=fl / chips,
        bytes_per_device=cell_bytes_per_device(cfg, shape, chips, int8_moments),
        state_bytes_total=_decode_state_bytes(cfg, shape),
    )
