"""Train-step factory: microbatched grad accumulation, ZeRO-1 sharding,
bf16 parameter gathers, optional bf16 gradient-reduction compression.

State layout (all leaves carry NamedShardings via the schema system):
    state = {"params": fp32 master @ zero1 spec,
             "opt":    {"m","v","step"} @ zero1 spec,
             "step":   int32 scalar}

Per step:
  1. compute params = cast(master, bf16) constrained to the *compute* spec —
     under dp_tp this is the ZeRO-1 all-gather, done in bf16 (half the bytes
     of a fp32 gather: a recorded distributed-optimization trick);
  2. scan over microbatches accumulating fp32 grads constrained to the
     zero1 spec (XLA turns the constraint into per-microbatch
     reduce-scatters that overlap with the next microbatch's compute);
  3. AdamW on the sharded shards; masters never leave their shard.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models.schema import ParamSpec, abstract_params, init_params, is_spec
from repro.sharding.rules import ShardingCtx, pspec_for
from repro.train.optimizer import AdamW, AdamWConfig

F32 = jnp.float32


# ==========================================================================
# ZeRO-1 sharding of optimizer/master state
# ==========================================================================
# Dims safe to carry extra ZeRO sharding: "outer" dims whose sharding the
# SPMD propagator cannot profitably push into attention/matmul contractions.
# head/state dims are excluded — a head_dim-sharded master layout was
# measured to pull partial-sum dots into the attention backward (3.6 TB/step
# of score-sized all-reduces on qwen2.5-14b, whose 40 heads defeat the
# head-count sharding and leave head_dim as the first divisible dim).
_ZERO1_SAFE_AXES = {
    "layer", "embed", "vocab", "mlp", "expert", "expert_mlp",
    "kv_lora", "q_lora", "rnn", "conv", "frames",
}


def zero1_pspec(
    spec: ParamSpec, base: P, ctx: ShardingCtx, axes: tuple[str, ...] = ("data", "model", "pod")
) -> P:
    """ZeRO sharding of masters/moments/grad-accum: extend the param's pspec
    with every mesh axis in ``axes`` it does not already use, greedily, on
    the first divisible SAFE dims (standard fully-sharded optimizer state)."""
    if ctx.mesh is None or not ctx.profile.zero1:
        return base
    entries = list(base) + [None] * (len(spec.shape) - len(base))
    used = {a for e in entries if e for a in ((e,) if isinstance(e, str) else e)}
    for axis in axes:
        if axis not in ctx.mesh.shape or axis in used:
            continue
        n = ctx.mesh.shape[axis]
        for i, dim in enumerate(spec.shape):
            if spec.axes[i] not in _ZERO1_SAFE_AXES:
                continue
            cur = entries[i]
            cur_axes = (cur,) if isinstance(cur, str) else tuple(cur or ())
            shard = 1
            for a in cur_axes:
                shard *= ctx.mesh.shape[a]
            if dim % (shard * n) == 0:
                entries[i] = cur_axes + (axis,) if cur_axes else axis
                used.add(axis)
                break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def _state_pspec_tree(
    schema: Any, ctx: ShardingCtx, zero1: bool,
    zero1_axes: tuple[str, ...] = ("data", "model", "pod"),
) -> Any:
    def one(spec: ParamSpec) -> P:
        if ctx.mesh is None:
            return P()
        base = pspec_for(spec.shape, spec.axes, ctx.profile, ctx.mesh)
        return zero1_pspec(spec, base, ctx, zero1_axes) if zero1 else base

    return jax.tree.map(one, schema, is_leaf=is_spec)


def _to_shardings(pspecs: Any, ctx: ShardingCtx) -> Any:
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, pspecs)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), pspecs)


def _abstract(schema: Any, pspecs: Any, ctx: ShardingCtx, dtype=None) -> Any:
    def one(spec: ParamSpec, ps: P):
        dt = dtype or spec.dtype
        if ctx.mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, dt)
        return jax.ShapeDtypeStruct(spec.shape, dt, sharding=NamedSharding(ctx.mesh, ps))

    return jax.tree.map(one, schema, pspecs, is_leaf=is_spec)


# ==========================================================================
# Train state
# ==========================================================================
@dataclass
class TrainSetup:
    cfg: ModelConfig
    shape: ShapeConfig
    sctx: ShardingCtx
    opt: AdamW
    param_schema: Any
    opt_schema: Any
    master_pspecs: Any  # zero1 specs for masters + moments
    compute_pspecs: Any  # profile specs used during fwd/bwd
    accum_pspecs: Any = None  # microbatch grad accumulator (model/pod-sharded)
    grad_compress_bf16: bool = True

    # -- abstract state for the dry-run (no allocation) ---------------------
    def abstract_state(self) -> dict[str, Any]:
        return {
            "params": _abstract(self.param_schema, self.master_pspecs["params"], self.sctx),
            "opt": _abstract(self.opt_schema, self.master_pspecs["opt"], self.sctx),
            "step": jax.ShapeDtypeStruct(
                (), jnp.int32,
                sharding=(NamedSharding(self.sctx.mesh, P()) if self.sctx.mesh else None),
            ),
        }

    def abstract_batch(self) -> dict[str, Any]:
        return batch_specs(self.cfg, self.shape, self.sctx)

    # -- real state for smoke-scale runs -------------------------------------
    def init_state(self, key: jax.Array) -> dict[str, Any]:
        params = init_params(self.param_schema, key)
        return {"params": params, "opt": self.opt.init(params), "step": jnp.zeros((), jnp.int32)}


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, sctx: ShardingCtx) -> dict[str, Any]:
    """ShapeDtypeStructs for one global batch of this (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    mesh = sctx.mesh

    def sds(shp, dtype, axes):
        if mesh is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        return jax.ShapeDtypeStruct(
            shp, dtype, sharding=NamedSharding(mesh, pspec_for(shp, axes, sctx.profile, mesh))
        )

    tok_len = S - cfg.prefix_len if cfg.prefix_len else S
    out = {
        "tokens": sds((B, tok_len), jnp.int32, ("batch", "seq")),
        "labels": sds((B, tok_len), jnp.int32, ("batch", "seq")),
    }
    if cfg.prefix_len:
        out["prefix_embeds"] = sds(
            (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16, ("batch", None, "embed_act")
        )
    if cfg.enc_dec:
        out["enc_embeds"] = sds(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16, ("batch", "frames", "embed_act")
        )
    return out


def make_train_setup(
    cfg: ModelConfig,
    shape: ShapeConfig,
    sctx: ShardingCtx,
    opt_cfg: AdamWConfig | None = None,
) -> TrainSetup:
    opt = AdamW(opt_cfg or AdamWConfig())
    param_schema = lm.model_schema(cfg)
    opt_schema = opt.state_schema(param_schema)
    # FSDP profiles keep compute weights at the master (fully-sharded)
    # layout; XLA inserts per-layer all-gathers inside the scan. DP profiles
    # hoist one bf16 gather per step (ZeRO-1 semantics).
    compute = _state_pspec_tree(param_schema, sctx, zero1=sctx.profile.fsdp_params)
    masters = {
        "params": _state_pspec_tree(param_schema, sctx, zero1=True),
        "opt": _state_pspec_tree(opt_schema, sctx, zero1=True),
    }
    # Microbatch grad accumulator: sharded over model/pod only. Grads of
    # TP-sharded weights are naturally model-sharded and grads of replicated
    # weights are computed redundantly per model rank (slicing is free), so
    # per-microbatch cross-shard reduction happens only over DATA partials of
    # a 16x-smaller tensor; the data-axis reduction to the full ZeRO layout
    # is deferred to one reshard after the loop (measured on qwen2.5-14b:
    # ~420 GiB/step of per-micro grad all-reduce -> ~30 GiB).
    # (A deferred data-axis reduction via a model-sharded accumulator was
    # tried and REFUTED: +13% collective bytes — XLA re-gathered activation
    # grads to match the accumulator layout. See EXPERIMENTS.md SSPerf.)
    accum = masters["params"]
    return TrainSetup(
        cfg=cfg, shape=shape, sctx=sctx, opt=opt,
        param_schema=param_schema, opt_schema=opt_schema,
        master_pspecs=masters, compute_pspecs=compute, accum_pspecs=accum,
    )


# ==========================================================================
# The step
# ==========================================================================
def _constrain_tree(tree: Any, pspecs: Any, ctx: ShardingCtx) -> Any:
    if ctx.mesh is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, s)),
        tree,
        pspecs,
    )


def make_train_step(setup: TrainSetup) -> Callable[[dict, dict], tuple[dict, dict]]:
    cfg, shape, sctx = setup.cfg, setup.shape, setup.sctx
    n_micro = max(1, cfg.train_microbatches)
    assert shape.global_batch % n_micro == 0, (
        f"{cfg.name}: global batch {shape.global_batch} not divisible by "
        f"{n_micro} microbatches"
    )
    compute_dt = jnp.dtype(cfg.compute_dtype)

    def train_step(state: dict[str, Any], batch: dict[str, Any]):
        # 1) bf16 parameter gather (ZeRO-1 -> compute layout). The
        # optimization_barrier pins the gather here: without it XLA's
        # sharding propagation may keep weights at the ZeRO layout and
        # partial-sum the consuming dots instead — measured on
        # qwen2.5-14b as a 3.6 TB/step all-reduce of fp32 attention
        # scores (head_dim-sharded masters poisoning the contraction).
        compute_params = jax.tree.map(lambda p: p.astype(compute_dt), state["params"])
        compute_params = _constrain_tree(compute_params, setup.compute_pspecs, sctx)
        if sctx.mesh is not None:
            # Pins the bf16 cast BEFORE any gather: without the barrier the
            # simplifier swaps convert/all-gather and moves fp32 masters over
            # ICI (2x bytes), and under ZeRO layouts propagation can even
            # push the master sharding into consumer dots (measured 3.6
            # TB/step of score-sized all-reduces on qwen2.5-14b).
            compute_params = jax.lax.optimization_barrier(compute_params)

        def loss_fn(params, mb):
            loss, metrics = lm.forward_train(params, cfg, mb, sctx)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(compute_params, batch)
            if setup.grad_compress_bf16:
                # Cross-shard gradient reduction rides in bf16 (half the ICI
                # bytes); the barrier stops XLA re-fusing the reduction into
                # fp32. The optimizer math upcasts after the reshard.
                grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
                grads = _constrain_tree(grads, setup.master_pspecs["params"], sctx)
                if sctx.mesh is not None:
                    grads = jax.lax.optimization_barrier(grads)
                grads = jax.tree.map(lambda g: g.astype(F32), grads)
            else:
                grads = jax.tree.map(lambda g: g.astype(F32), grads)
                grads = _constrain_tree(grads, setup.master_pspecs["params"], sctx)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )
            acc_pspecs = setup.accum_pspecs or setup.master_pspecs["params"]
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, F32), state["params"]
            )
            zero_grads = _constrain_tree(zero_grads, acc_pspecs, sctx)

            def mb_body(carry, mb):
                acc, loss_acc = carry
                (loss, metrics), g = grad_fn(compute_params, mb)
                if setup.grad_compress_bf16:
                    # Cross-replica reduction rides in bf16; accumulate fp32.
                    g = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
                acc = jax.tree.map(lambda a, x: a + x.astype(F32), acc, g)
                acc = _constrain_tree(acc, acc_pspecs, sctx)
                return (acc, loss_acc + loss), metrics

            unroll = bool(int(os.environ.get("REPRO_UNROLL_SCANS", "0")))
            (grads, loss_sum), metrics = jax.lax.scan(
                mb_body, (zero_grads, jnp.zeros((), F32)), micro,
                unroll=True if unroll else 1,
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            # One deferred data-axis reshard to the ZeRO layout.
            grads = _constrain_tree(grads, setup.master_pspecs["params"], sctx)
            loss = loss_sum / n_micro
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        # 3) sharded AdamW on masters.
        new_params, new_opt, opt_metrics = setup.opt.update(
            grads, state["opt"], state["params"]
        )
        new_params = _constrain_tree(new_params, setup.master_pspecs["params"], sctx)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = {**metrics, **opt_metrics, "loss_mean": loss}
        return new_state, metrics

    return train_step
