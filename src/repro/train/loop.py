"""Training loop wired into Memento checkpointing + the checkpoint store.

A training run is a Memento *task*: the loop checkpoints sharded state every
``ckpt_every`` steps (async), heartbeats the task lease, and on restart
``Context.restore``/CheckpointStore pick up at the last complete step with
the data pipeline resuming deterministically from the step counter. Kill the
process at any point and re-run: the task's identity (config hash) routes it
back to the same checkpoint directory.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt.store import CheckpointStore
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.task import Context
from repro.data.pipeline import DataConfig, Prefetcher, make_batch_fn
from repro.sharding.rules import ShardingCtx
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainSetup, make_train_setup, make_train_step


@dataclass
class TrainRunConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = ""
    log_every: int = 10
    seed: int = 0
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    data: DataConfig | None = None


def train_run(
    cfg: ModelConfig,
    shape: ShapeConfig,
    sctx: ShardingCtx,
    run: TrainRunConfig,
    ctx: Context | None = None,
    on_metrics: Callable[[int, dict], None] | None = None,
) -> dict[str, Any]:
    """Run (or resume) a training segment; returns the final metrics."""
    setup = make_train_setup(cfg, shape, sctx, run.opt)
    step_fn = jax.jit(make_train_step(setup), donate_argnums=(0,))

    store = CheckpointStore(run.ckpt_dir or f"checkpoints/{cfg.name}-{shape.name}")
    start_step = 0
    state = None
    latest = store.latest_step()
    if latest is not None:
        like = setup.init_state(jax.random.PRNGKey(run.seed))
        start_step, state = store.restore(like)
        if ctx is not None:
            ctx.progress(f"resumed from checkpoint step {start_step}")
    if state is None:
        state = setup.init_state(jax.random.PRNGKey(run.seed))

    fetch = make_batch_fn(cfg, shape, run.data)
    prefetch = Prefetcher(fetch, start_step=start_step, prefetch=2)
    history: list[dict[str, float]] = []
    t0 = time.time()
    try:
        for step, batch in prefetch:
            if step >= run.steps:
                break
            state, metrics = step_fn(state, batch)
            if ctx is not None:
                ctx.heartbeat()
            if (step + 1) % run.log_every == 0 or step + 1 == run.steps:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step + 1
                history.append(m)
                if on_metrics is not None:
                    on_metrics(step + 1, m)
            if (step + 1) % run.ckpt_every == 0:
                store.save(step + 1, state, blocking=False)
    finally:
        prefetch.close()
    store.wait()
    store.save(run.steps, state, blocking=True)
    wall = time.time() - t0

    result = {
        "final_step": run.steps,
        "wall_s": wall,
        "history": history,
        "loss_first": history[0]["loss"] if history else None,
        "loss_last": history[-1]["loss"] if history else None,
    }
    if ctx is not None and ctx.checkpoints is not None:
        ctx.checkpoint({"summary": result})
    return result
