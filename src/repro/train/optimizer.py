"""Optimizers: AdamW with fp32 or int8-quantised moments, LR schedules,
global-norm clipping.

int8 moments (beyond-paper memory optimization, cf. 8-bit Adam
[arXiv:2110.02861], adapted to blockwise absmax scales): each moment tensor
is stored as int8 codes + one fp32 scale per 128-element block of the
flattened tensor — 1.03 bytes/param instead of 4. ``m`` is quantised
linearly; ``v`` is quantised in the SQRT domain (codes store sqrt(v)) so
the absolute error lands on the update's denominator instead of its square
— linear-quantised v zeroes out small entries and blows up their updates
(observed divergence on a quadratic; the test asserts convergence).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
Q_BLOCK = 128


# ==========================================================================
# Blockwise int8 quantisation
# ==========================================================================
class Q8(NamedTuple):
    codes: jax.Array  # int8, original shape
    scales: jax.Array  # fp32, (ceil(size / Q_BLOCK),)


def q8_quantize(x: jax.Array) -> Q8:
    shape = x.shape
    flat = x.astype(F32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % Q_BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, Q_BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127).astype(jnp.int8)
    return Q8(codes=codes.reshape(-1)[:n].reshape(shape), scales=scales)


def q8_dequantize(q: Q8) -> jax.Array:
    shape = q.codes.shape
    flat = q.codes.astype(F32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % Q_BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, Q_BLOCK)
    return (flat * q.scales[:, None]).reshape(-1)[:n].reshape(shape)


# ==========================================================================
# Schedules
# ==========================================================================
@dataclass(frozen=True)
class Schedule:
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_ratio: float = 0.1
    kind: str = "cosine"  # cosine | linear | const

    def __call__(self, step: jax.Array) -> jax.Array:
        s = step.astype(F32)
        warm = jnp.minimum(s / jnp.maximum(self.warmup_steps, 1), 1.0)
        if self.kind == "const":
            decay = 1.0
        else:
            frac = jnp.clip(
                (s - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1),
                0.0,
                1.0,
            )
            if self.kind == "cosine":
                decay = self.min_ratio + (1 - self.min_ratio) * 0.5 * (
                    1 + jnp.cos(jnp.pi * frac)
                )
            else:
                decay = 1.0 - (1.0 - self.min_ratio) * frac
        return self.base_lr * warm * decay


# ==========================================================================
# AdamW
# ==========================================================================
@dataclass(frozen=True)
class AdamWConfig:
    schedule: Schedule = field(default_factory=Schedule)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    int8_moments: bool = False


def _global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(F32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = _global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(F32) * factor), grads), norm


class AdamW:
    """Functional AdamW over arbitrary pytrees of fp32 master params."""

    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg

    # -- state ---------------------------------------------------------------
    def init(self, params: Any) -> dict[str, Any]:
        if self.cfg.int8_moments:
            # dict (not Q8 NamedTuple) so the state pytree matches
            # state_schema()/update() and checkpoints round-trip as plain trees.
            zeros_q = lambda p: {
                "codes": jnp.zeros(p.shape, jnp.int8),
                "scales": jnp.ones(((int(np.prod(p.shape)) + Q_BLOCK - 1) // Q_BLOCK,), F32),
            }
            m = jax.tree.map(zeros_q, params)
            v = jax.tree.map(zeros_q, params)
        else:
            m = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            v = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}

    def state_schema(self, param_schema: Any) -> dict[str, Any]:
        """ParamSpec tree for the optimizer state (for abstract lowering)."""
        from repro.models.schema import ParamSpec, is_spec

        def moment(spec: ParamSpec):
            if self.cfg.int8_moments:
                nblk = (spec.size + Q_BLOCK - 1) // Q_BLOCK
                return {
                    "codes": ParamSpec(spec.shape, spec.axes, dtype=jnp.int8, init="zeros"),
                    "scales": ParamSpec((nblk,), (None,), dtype=F32, init="ones"),
                }
            return ParamSpec(spec.shape, spec.axes, dtype=F32, init="zeros")

        m = jax.tree.map(moment, param_schema, is_leaf=is_spec)
        return {
            "m": m,
            "v": jax.tree.map(moment, param_schema, is_leaf=is_spec),
            "step": ParamSpec((), (), dtype=jnp.int32, init="zeros"),
        }

    # -- update ---------------------------------------------------------------
    def update(
        self, grads: Any, state: dict[str, Any], params: Any
    ) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
        cfg = self.cfg
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        lr = cfg.schedule(step)
        bc1 = 1.0 - cfg.b1 ** step.astype(F32)
        bc2 = 1.0 - cfg.b2 ** step.astype(F32)

        def leaf_update(g, m, v, p):
            if cfg.int8_moments:
                m_f = q8_dequantize(m)
                v_sqrt = q8_dequantize(v)
                v_f = v_sqrt * v_sqrt
            else:
                m_f, v_f = m, v
            m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
            v_new = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
            m_hat = m_new / bc1
            v_hat = v_new / bc2
            upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
            p32 = p.astype(F32)
            p_new = p32 - lr * (upd + cfg.weight_decay * p32)
            if cfg.int8_moments:
                return (
                    p_new.astype(p.dtype),
                    q8_quantize(m_new),
                    q8_quantize(jnp.sqrt(jnp.maximum(v_new, 0.0))),
                )
            return p_new.astype(p.dtype), m_new, v_new

        is_q8 = lambda x: isinstance(x, Q8) or (
            isinstance(x, dict) and set(x.keys()) == {"codes", "scales"}
        )

        def as_q8(x):
            return Q8(**x) if isinstance(x, dict) else x

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = jax.tree.flatten(state["m"], is_leaf=is_q8)[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_q8)[0]
        flat_p = jax.tree.flatten(params)[0]
        outs = [
            leaf_update(g, as_q8(m) if cfg.int8_moments else m,
                        as_q8(v) if cfg.int8_moments else v, p)
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)
        ]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in outs])
        pack = (lambda q: {"codes": q.codes, "scales": q.scales}) if cfg.int8_moments else (lambda x: x)
        new_m = jax.tree.unflatten(treedef, [pack(o[1]) for o in outs])
        new_v = jax.tree.unflatten(treedef, [pack(o[2]) for o in outs])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
