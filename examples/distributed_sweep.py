"""Distributed sweep demo: several launcher "hosts" drain one matrix.

Spawns N worker processes, each a full `Memento.run_distributed` participant
on a shared queue directory + shared result cache — exactly what N real
launcher hosts on one shared filesystem would run. The parent is itself a
participant: it streams results as they complete anywhere, renders the
cluster-wide per-host progress line, and ends up with the full ResultSet.

    PYTHONPATH=src python examples/distributed_sweep.py [--hosts 3] [--serve]

``--serve`` swaps the toy task for a real (smoke-scale) serving sweep via
``experiments.serve_sweep_distributed`` — the distributed serve sweep from
the ROADMAP. One model compile per host, so expect ~a minute on CPU.
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import tempfile
import time


def simulated_experiment(ctx):
    """A stand-in for a real experiment: sleeps, then returns a metric."""
    time.sleep(0.05 + 0.01 * (ctx["width"] % 3))
    return {"width": ctx["width"], "depth": ctx["depth"],
            "score": ctx["width"] * ctx["depth"]}


MATRIX = {"parameters": {"width": [64, 128, 256, 512], "depth": [2, 4, 8]}}


def _worker(root: str, owner: str) -> None:
    from repro.core import CallbackNotificationProvider, Memento, RunnerConfig

    eng = Memento(
        simulated_experiment,
        notification_provider=CallbackNotificationProvider(lambda e: None),
        workdir=os.path.join(root, "workdir"),
        runner_config=RunnerConfig(max_workers=2, enable_speculation=False),
    )
    eng.run_distributed(MATRIX, queue_dir=os.path.join(root, "queue"), owner=owner)


def main_toy(n_hosts: int) -> None:
    from repro.core import (
        DistributedConfig,
        Memento,
        ProgressNotificationProvider,
        RunnerConfig,
    )

    root = tempfile.mkdtemp(prefix="memento_distributed_")
    print(f"shared dir: {root}  ({n_hosts} worker hosts + this one)")
    mp = multiprocessing.get_context("fork")
    workers = [
        mp.Process(target=_worker, args=(root, f"host-{i}"))
        for i in range(n_hosts)
    ]
    for p in workers:
        p.start()

    prov = ProgressNotificationProvider(total=12)
    eng = Memento(
        simulated_experiment,
        notification_provider=prov,
        workdir=os.path.join(root, "workdir"),
        runner_config=RunnerConfig(max_workers=2, enable_speculation=False),
    )
    t0 = time.time()
    results = []
    for r in eng.stream_distributed(
        MATRIX,
        queue_dir=os.path.join(root, "queue"),
        owner="parent",
        distributed_config=DistributedConfig(progress_every_s=0.5),
    ):
        results.append(r)
        print(f"  {r.spec.describe()} -> {r.status} on {r.host}")
    for p in workers:
        p.join()
    print(f"\n{len(results)} results in {time.time() - t0:.2f}s; "
          f"best score: {max(r.value['score'] for r in results)}")


def _serve_matrix():
    from repro.experiments import serve_matrix

    return serve_matrix(
        ["llama3.2-3b"], backends=["xla"], scheduler={"n_slots": [2, 4]},
        cache_len=64, n_requests=4, prompt_lens=(5, 9, 13), max_new_tokens=4,
        warmup=False,
    )


def _serve_worker(root: str, owner: str) -> None:
    from repro.experiments import serve_sweep_distributed

    serve_sweep_distributed(
        _serve_matrix(), queue_dir=os.path.join(root, "queue"),
        workdir=os.path.join(root, "workdir"), owner=owner,
    )


def main_serve(n_hosts: int) -> None:
    from repro.experiments import serve_sweep_distributed

    root = tempfile.mkdtemp(prefix="memento_distserve_")
    mp = multiprocessing.get_context("spawn")  # each host needs its own jax
    workers = [
        mp.Process(target=_serve_worker, args=(root, f"serve-host-{i}"))
        for i in range(max(n_hosts - 1, 0))
    ]
    for p in workers:
        p.start()
    res = serve_sweep_distributed(
        _serve_matrix(), queue_dir=os.path.join(root, "queue"),
        workdir=os.path.join(root, "workdir"), owner="parent",
    )
    for p in workers:
        p.join()
    for r in res:
        v = r.value
        print(f"n_slots={r.spec.params['n_slots']} cell on {r.host}: "
              f"{v['tokens_per_s']:.1f} tok/s (status={r.status})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--hosts", type=int, default=3, help="worker processes")
    ap.add_argument("--serve", action="store_true",
                    help="run a real smoke-scale serving sweep instead of the toy task")
    args = ap.parse_args()
    (main_serve if args.serve else main_toy)(args.hosts)
