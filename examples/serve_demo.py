"""Batched serving demo: prefill + iterative decode with the Engine.

Generates greedily from three architectures (dense GQA, hybrid
RG-LRU+window, xLSTM) at reduced scale, demonstrating dense caches, ring
buffers, and recurrent state through one API.

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.serve.engine import Engine, ServeConfig
from repro.sharding.rules import ShardingCtx

for arch in ("llama3.2-3b", "recurrentgemma-2b", "xlstm-1.3b"):
    cfg = get_config(arch).reduced()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ShardingCtx.null(), ServeConfig(max_new_tokens=8, cache_len=64))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)}
    out = eng.generate(prompt)
    print(f"{arch:22s} generated {out.tokens.shape[1]} tokens/seq: {out.tokens.tolist()}")
