"""Continuous-batching serving demo, driven through the Memento core.

A serving sweep is an experiment matrix like any other: three state
families (dense GQA KV, hybrid RG-LRU + window ring buffer, xLSTM recurrent
matrix state) crossed with scheduler settings, run through
``repro.experiments.serve_sweep`` so the sweep inherits caching and
streaming — re-run the demo and every row returns instantly from cache.
Watch ``decode_traces`` stay at 1: requests join/leave mid-decode on one
fixed-shape jitted step.

    PYTHONPATH=src python examples/serve_demo.py
"""
import repro.core as memento
from repro.experiments import serve_matrix, serve_sweep

matrix = serve_matrix(
    ["llama3.2-3b", "recurrentgemma-2b", "xlstm-1.3b"],
    backends=["xla"],
    scheduler={"n_slots": [2]},
    cache_len=64,
    n_requests=3,
    prompt_lens=(12, 6, 9),
    max_new_tokens=8,
    warmup=False,
)

eng = memento.Memento(
    serve_sweep,
    memento.ConsoleNotificationProvider(verbose=False),
    workdir=".memento-serve-demo",
    namespace="serve",
    runner_config=memento.RunnerConfig(max_workers=1, enable_speculation=False),
)

for r in eng.stream(matrix):
    if not r.ok:
        print(r.summary())
        continue
    v = r.value
    print(
        f"{v['arch']:22s} [{r.status:6s}] {v['generated_tokens']} tokens "
        f"@ {v['tokens_per_s']:.1f} tok/s  p50={v['latency_p50_s']*1e3:.0f}ms "
        f"decode_traces={v['decode_traces']}"
    )
    for i, toks in enumerate(v["tokens"]):
        print(f"  req{i} -> {len(toks)} tokens: {toks}")
