"""Continuous-batching serving demo.

Drives the slot-based scheduler directly: requests with different prompt
and output lengths are submitted while earlier ones are mid-decode, short
requests retire early, and freed slots are backfilled from the queue — all
on one fixed-shape jitted decode step (watch ``decode_traces`` stay at 1).
Runs across three state families (dense GQA KV, hybrid RG-LRU + window
ring buffer, xLSTM recurrent matrix state) through one API.

    PYTHONPATH=src python examples/serve_demo.py
"""
import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import lm
from repro.models.schema import init_params
from repro.serve.request import Request
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.sharding.rules import ShardingCtx

for arch in ("llama3.2-3b", "recurrentgemma-2b", "xlstm-1.3b"):
    cfg = get_config(arch).reduced()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    sched = Scheduler(cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=2, cache_len=64))

    rng = np.random.default_rng(1)
    rids = [
        sched.submit(Request(rng.integers(0, cfg.vocab_size, size=p).astype(np.int32), max_new_tokens=m))
        for p, m in ((12, 4), (6, 8))
    ]
    for _ in range(3):  # two in flight...
        sched.step()
    rids.append(  # ...a third arrives mid-decode and backfills the first free slot
        sched.submit(Request(rng.integers(0, cfg.vocab_size, size=9).astype(np.int32), max_new_tokens=5))
    )
    sched.run()

    print(f"{arch:22s} {sched.stats()}")
    for rid in rids:
        rs = sched.result(rid)
        print(
            f"  req{rid} slot={rs.slot} prompt={len(rs.request.prompt):2d} "
            f"-> {len(rs.tokens)} tokens ({rs.finish_reason}): {rs.tokens}"
        )
