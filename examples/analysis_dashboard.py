"""Distributed serve sweep with a live fleet dashboard + post-run analysis.

Two worker processes cooperatively drain one serve-sweep matrix through the
file queue while the parent serves a live dashboard: per-host throughput,
queue depth, ETA, and failure drill-down with the real tracebacks the
distributed runtime propagates. Open the printed URL in a browser while it
runs (or curl ``/api/state``).

When the sweep finishes, the results render as a grouped comparison table
twice — once through the Python API (``repro.analysis.compare``), once
through the CLI (``python -m repro.analysis table``) — and the two outputs
are asserted token-for-token identical.

    PYTHONPATH=src python examples/analysis_dashboard.py [--fast] [--port 8321]

``--fast`` swaps the real serve model for a synthetic workload (no compile;
finishes in seconds) — the orchestration, dashboard, and analysis paths are
identical.
"""
import argparse
import multiprocessing
import os
import shutil
import subprocess
import sys
import tempfile
import time

import repro.core as memento
from repro.analysis import AnalysisNotificationProvider, Dashboard, compare
from repro.analysis.metrics import MetricFrame
from repro.core import DistributedConfig, RunnerConfig
from repro.experiments import serve_matrix, serve_sweep


def fast_sweep(ctx):
    """Synthetic stand-in for serve_sweep: same result-dict shape, no model.
    One param combination fails on purpose so the dashboard's failure
    drill-down has a real traceback to show."""
    import random

    rng = random.Random(ctx.key)
    time.sleep(0.2 + rng.random() * 0.3)
    if ctx["n_slots"] == 2 and ctx["chunk_budget"] == 16:
        raise RuntimeError("synthetic failure: n_slots=2 chunk_budget=16 "
                           "is the demo's broken cell")
    toks = 64 * ctx["n_slots"]
    wall = 0.5 + rng.random() * 0.2
    return {
        "n_slots": ctx["n_slots"],
        "chunk_budget": ctx["chunk_budget"],
        "tokens_per_s": toks / wall,
        "wall_s": wall,
        "itl_p50_s": 0.004 + rng.random() * 0.002,
        "accept_rate": 0.8 + rng.random() * 0.15,
        "generated_tokens": float(toks),
    }


def build_matrix(fast: bool):
    if fast:
        return memento.ConfigMatrix.from_dict(
            {"parameters": {"n_slots": [2, 4], "chunk_budget": [0, 16, 32]}}
        )
    return serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"n_slots": [2, 4], "chunk_budget": [0, 16]},
        cache_len=64, page_size=8, n_requests=4, prompt_lens=(4, 9, 17, 6),
        max_new_tokens=4, warmup=False,
    )


def worker(root: str, owner: str, fast: bool, journal: str) -> None:
    """One drain participant: full local Runner against the shared queue,
    teeing its events into the shared journal the dashboard tails."""
    prov = AnalysisNotificationProvider(journal_path=journal)
    eng = memento.Memento(
        fast_sweep if fast else serve_sweep,
        notification_provider=prov,
        workdir=os.path.join(root, "w"),
        namespace="serve",
        runner_config=RunnerConfig(max_workers=1, retries=0,
                                   enable_speculation=False),
    )
    eng.run_distributed(
        build_matrix(fast),
        queue_dir=os.path.join(root, "q"),
        owner=owner,
        distributed_config=DistributedConfig(
            poll_s=0.05, claim_ahead=1, progress_every_s=0.5
        ),
    )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="synthetic workload, no model compile")
    ap.add_argument("--port", type=int, default=8321)
    ap.add_argument("--linger", type=float, default=0.0,
                    help="keep the dashboard up this many seconds after "
                         "the sweep finishes")
    args = ap.parse_args()

    root = tempfile.mkdtemp(prefix="memento_dash_demo_")
    journal = os.path.join(root, "events.jsonl")
    matrix = build_matrix(args.fast)
    total = len(matrix.task_list())

    # The parent owns the dashboard; workers append to the shared journal
    # and the dashboard provider tails it — exactly the multi-host layout,
    # just on one machine.
    prov = AnalysisNotificationProvider(total=total)
    dash = Dashboard(prov, port=args.port)
    url = dash.start()
    print(f"dashboard: {url}   (state: {url}/api/state)")

    mp = multiprocessing.get_context("fork")
    procs = [
        mp.Process(target=worker, args=(root, f"host{i}", args.fast, journal))
        for i in range(2)
    ]
    t0 = time.time()
    for p in procs:
        p.start()
    offset = 0
    while any(p.is_alive() for p in procs):
        offset = prov.replay_journal(journal, offset)
        time.sleep(0.2)
    for p in procs:
        p.join()
    prov.replay_journal(journal, offset)
    state = prov.state()
    print(f"\nsweep drained in {time.time() - t0:.1f}s: "
          f"{state['done']} done, {state['failed']} failed, "
          f"hosts={list(state['hosts'])}")
    for f in state["failures"]:
        print(f"  failure on {f['host']}: {f['error']}")

    # -- post-run analysis: API table == CLI table, token for token --------
    eng = memento.Memento(
        fast_sweep if args.fast else serve_sweep,
        notification_provider=memento.CallbackNotificationProvider(lambda e: None),
        workdir=os.path.join(root, "w"),
        namespace="serve",
    )
    results = eng.run_distributed(
        build_matrix(args.fast), queue_dir=os.path.join(root, "q"),
        publish=False,
    )
    csv_path = os.path.join(root, "results.csv")
    results.to_csv(csv_path)

    frame = MetricFrame.from_results_csv(csv_path)
    rows, cols = ["n_slots"], ["chunk_budget"]
    api_table = compare(
        frame, rows=rows, cols=cols, metric="tokens_per_s", agg="mean"
    ).to_markdown()

    cli = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "table",
         "--csv", csv_path, "--rows", *rows, "--cols", *cols,
         "--metric", "tokens_per_s", "--agg", "mean", "--format", "md"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src"),
             os.environ.get("PYTHONPATH", "")])},
    )
    cli_table = cli.stdout.strip()
    print("\ntokens/s by n_slots x chunk_budget:\n")
    print(api_table)
    assert cli_table == api_table, (
        "CLI and API tables differ:\n--- CLI ---\n"
        f"{cli_table}\n--- API ---\n{api_table}"
    )
    print("\nCLI table output is token-for-token identical to the API table.")

    if args.linger:
        print(f"dashboard stays up {args.linger:.0f}s — {url}")
        time.sleep(args.linger)
    dash.stop()
    shutil.rmtree(root, ignore_errors=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
