"""Quickstart — the paper's demo, verbatim shape.

A configuration matrix over (dataset x preprocessing x model), run in
parallel with caching, checkpointing, and notifications. The "models" are
tiny JAX ridge/logistic classifiers so the example runs in seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

import repro.core as memento


# -- datasets (synthetic stand-ins for load_digits / load_wine / ...) --------
def make_blobs(seed, n=256, d=16, classes=3):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    centers = jax.random.normal(k1, (classes, d)) * 3
    y = jax.random.randint(k2, (n,), 0, classes)
    x = centers[y] + jax.random.normal(k2, (n, d))
    return x, y


def dataset_a():
    return make_blobs(0)


def dataset_b():
    return make_blobs(1, d=32, classes=4)


# -- preprocessing ------------------------------------------------------------
def identity(x):
    return x


def standardize(x):
    return (x - x.mean(0)) / (x.std(0) + 1e-6)


# -- models -------------------------------------------------------------------
def logistic_regression(x, y, steps=200, lr=0.5):
    classes = int(y.max()) + 1
    w = jnp.zeros((x.shape[1], classes))

    def loss(w):
        logits = x @ w
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y])

    g = jax.jit(jax.grad(loss))
    for _ in range(steps):
        w = w - lr * g(w)
    return float((jnp.argmax(x @ w, 1) == y).mean())


def nearest_centroid(x, y, **_):
    classes = int(y.max()) + 1
    cents = jnp.stack([x[y == c].mean(0) for c in range(classes)])
    pred = jnp.argmin(((x[:, None] - cents[None]) ** 2).sum(-1), 1)
    return float((pred == y).mean())


# -- the experiment function ---------------------------------------------------
def exp_func(context: memento.Context):
    # Paper workflow: restore a checkpoint if this task was interrupted.
    if context.checkpoint_exists():
        return context.restore()["result"]
    x, y = context["dataset"]()
    x = context["preprocessing"](x)
    acc = context["model"](x, y, steps=context.settings["steps"])
    result = {"accuracy": acc}
    context.checkpoint({"result": result})
    return result


# The configuration matrix conveniently specifies the experiments to be run.
config_matrix = {
    "parameters": {
        "dataset": [dataset_a, dataset_b],
        "preprocessing": [identity, standardize],
        "model": [logistic_regression, nearest_centroid],
    },
    "settings": {"steps": 200},
    "exclude": [
        # skip the known-uninteresting combination, as in the paper
        {"dataset": dataset_b, "model": nearest_centroid, "preprocessing": identity},
    ],
}

if __name__ == "__main__":
    notif_provider = memento.ConsoleNotificationProvider()
    results = memento.Memento(exp_func, notif_provider, workdir=".memento-quickstart").run(
        config_matrix
    )
    print()
    for r in results:
        ds = r.spec.params["dataset"].__name__
        pp = r.spec.params["preprocessing"].__name__
        mdl = r.spec.params["model"].__name__
        print(f"{ds:10s} {pp:12s} {mdl:20s} -> {r.value['accuracy']:.3f} [{r.status}]")
    print("\nRe-run this script: every task now comes from the cache.")
