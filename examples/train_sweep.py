"""End-to-end driver: Memento orchestrating a learning-rate sweep of real
(reduced-config) LM training runs, with checkpoint/resume fault tolerance.

Each task trains a small llama-style model for a few hundred steps on the
deterministic synthetic pipeline; kill the process at any time and re-run —
finished cells come from cache, the interrupted cell resumes from its last
sharded checkpoint.

The sweep runs through the v2 experiment API: the matrix is composed with
the algebra (lr axis x int8 axis, a callable exclude for the known-divergent
combo), the experiment function is the shared ``repro.experiments.train_sweep``
adapter, and results stream in as each cell lands.

    PYTHONPATH=src python examples/train_sweep.py [--steps 200]
"""
import argparse

import repro.core as memento
from repro.core import ConfigMatrix
from repro.experiments import train_sweep

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workdir", default=".memento-train-sweep")
    args = ap.parse_args()

    lr_axis = ConfigMatrix.from_dict(
        {
            "parameters": {"arch": ["llama3.2-3b"], "lr": [1e-3, 3e-3, 1e-2]},
            "settings": {"steps": args.steps, "workdir": args.workdir,
                         "ckpt_every": 50, "log_every": 20},
        }
    )
    int8_axis = ConfigMatrix.from_dict({"parameters": {"int8_opt": [False, True]}})
    # Product over disjoint axes, minus the known-divergent combo.
    matrix = (lr_axis * int8_axis).where(
        lambda p: not (p["lr"] == 1e-2 and p["int8_opt"])
    )

    eng = memento.Memento(
        train_sweep,
        memento.ConsoleNotificationProvider(),
        workdir=args.workdir,
        namespace="train",
        runner_config=memento.RunnerConfig(max_workers=1, retries=1, enable_speculation=False),
    )
    print(f"{len(matrix.task_list())} cells; streaming results as they land:")
    results = []
    for r in eng.stream(matrix):
        results.append(r)
        if r.ok:
            v = r.value
            print(f"  lr={v['lr']:<8g} int8={str(v['int8']):5s} "
                  f"{v['loss_first']:.3f} -> {v['loss_last']:.3f}  [{r.status}]")
        else:
            print(f"  {r.summary()}")

    rs = memento.ResultSet(results)
    print("\nfinal loss pivot (lr x int8):")
    print(rs.pivot("lr", "int8_opt", lambda r: r.value["loss_last"]))
    if rs.failed:
        print(f"{len(rs.failed)} failed tasks (fix + re-run resumes from cache).")
