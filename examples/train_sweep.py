"""End-to-end driver: Memento orchestrating a learning-rate sweep of real
(reduced-config) LM training runs, with checkpoint/resume fault tolerance.

Each task trains a small llama-style model for a few hundred steps on the
deterministic synthetic pipeline; kill the process at any time and re-run —
finished cells come from cache, the interrupted cell resumes from its last
sharded checkpoint.

    PYTHONPATH=src python examples/train_sweep.py [--steps 200]
"""
import argparse

import repro.core as memento
from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig
from repro.sharding.rules import ShardingCtx
from repro.train.loop import TrainRunConfig, train_run
from repro.train.optimizer import AdamWConfig, Schedule


def train_task(ctx: memento.Context):
    cfg = get_config(ctx["arch"]).reduced()
    shape = ShapeConfig("sweep", "train", seq_len=64, global_batch=8)
    run = TrainRunConfig(
        steps=ctx.settings["steps"],
        ckpt_every=50,
        log_every=20,
        ckpt_dir=f"{ctx.settings['workdir']}/ckpt-{ctx.key[:10]}",
        opt=AdamWConfig(
            schedule=Schedule(base_lr=ctx["lr"], warmup_steps=20, total_steps=ctx.settings["steps"]),
            int8_moments=ctx["int8_opt"],
        ),
        data=DataConfig(seed=0, vocab_size=cfg.vocab_size, noise=0.05),
    )
    res = train_run(cfg, shape, ShardingCtx.null(), run, ctx=ctx)
    return {"lr": ctx["lr"], "int8": ctx["int8_opt"],
            "loss_first": res["loss_first"], "loss_last": res["loss_last"]}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workdir", default=".memento-train-sweep")
    args = ap.parse_args()

    matrix = {
        "parameters": {
            "arch": ["llama3.2-3b"],
            "lr": [1e-3, 3e-3, 1e-2],
            "int8_opt": [False, True],
        },
        "settings": {"steps": args.steps, "workdir": args.workdir},
        "exclude": [{"lr": 1e-2, "int8_opt": True}],  # known-divergent combo
    }
    eng = memento.Memento(
        train_task,
        memento.ConsoleNotificationProvider(),
        workdir=args.workdir,
        runner_config=memento.RunnerConfig(max_workers=1, retries=1, enable_speculation=False),
    )
    results = eng.run(matrix)
    print("\nlr sweep results (loss first -> last):")
    for r in sorted(results.ok, key=lambda r: (r.value["int8"], r.value["lr"])):
        v = r.value
        print(f"  lr={v['lr']:<8g} int8={str(v['int8']):5s} "
              f"{v['loss_first']:.3f} -> {v['loss_last']:.3f}  [{r.status}]")
    if results.failed:
        print(f"{len(results.failed)} failed tasks (fix + re-run resumes from cache).")
