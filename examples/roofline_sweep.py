"""The assignment's 40-cell dry-run sweep AS a Memento experiment — the
paper's technique orchestrating this repo's own evaluation.

    PYTHONPATH=src python examples/roofline_sweep.py --arch qwen3-8b
    PYTHONPATH=src python examples/roofline_sweep.py            # everything

Results cache under results/dryrun; interrupt and re-run freely. Render the
report with:  PYTHONPATH=src python -m repro.launch.report
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--both", action="store_true", help="single-pod AND 2-pod meshes")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.dryrun"]
    if args.arch:
        cmd += ["--arch", args.arch, "--shape", "train_4k"]
    else:
        cmd += ["--all"] + (["--both"] if args.both else [])
    raise SystemExit(subprocess.call(cmd))
