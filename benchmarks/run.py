"""Benchmark harness — one function per paper claim + roofline summaries.

The Memento paper's claims (demo paper, no numeric tables) map to:
  B1  configuration-matrix expansion scales to large experiment sets
  B2  parallel execution beats sequential for embarrassingly-parallel tasks
  B3  result caching makes re-runs ~free
  B4  in-task checkpointing bounds lost work on interruption
  B5  failure isolation: one broken task does not poison a run
plus framework-level benchmarks:
  B6  per-kernel interpret-mode microbenches (us_per_call vs jnp oracle)
  B7  train-step wall time for a tiny model (CPU, smoke scale)
  B8  dry-run roofline summary (from the cached sweep, if present)
  B9  continuous-batching serve throughput under Poisson arrivals
  B10 paged-KV serving: mixed prompt sizes multiplexed over a fixed page
      pool vs the contiguous per-slot baseline (tokens/s, p50/p95 latency,
      peak cache bytes)

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import statistics
import time


def _t(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def _row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


def bench_matrix_expansion() -> None:
    from repro.core import ConfigMatrix

    for n_axes, width in ((4, 10), (5, 12)):
        m = ConfigMatrix.from_dict(
            {"parameters": {f"p{i}": list(range(width)) for i in range(n_axes)}}
        )
        total = width ** n_axes
        us = _t(lambda: m.task_list(), n=2)
        _row(
            f"B1_matrix_expand_{total}_tasks", us,
            f"{total/ (us/1e6):.0f} tasks/s incl hashing",
        )


def bench_parallel_speedup() -> None:
    from repro.core import ConfigMatrix, Memento, RunnerConfig

    def sleepy(ctx):
        time.sleep(0.05)
        return ctx["i"]

    matrix = {"parameters": {"i": list(range(8))}}
    seq = Memento(sleepy, runner_config=RunnerConfig(max_workers=1, enable_speculation=False))
    par = Memento(sleepy, runner_config=RunnerConfig(max_workers=8, enable_speculation=False))
    t_seq = _t(lambda: seq.run(matrix, cache=False), n=2, warmup=0)
    t_par = _t(lambda: par.run(matrix, cache=False), n=2, warmup=0)
    _row("B2_sequential_8x50ms", t_seq)
    _row("B2_parallel_8workers", t_par, f"speedup={t_seq/t_par:.2f}x")


def bench_cache_speedup(tmpdir="/tmp/repro_bench_cache") -> None:
    import shutil

    from repro.core import Memento

    shutil.rmtree(tmpdir, ignore_errors=True)

    def work(ctx):
        time.sleep(0.05)
        return ctx["i"] ** 2

    eng = Memento(work, workdir=tmpdir)
    matrix = {"parameters": {"i": list(range(6))}}
    t_cold = _t(lambda: eng.run(matrix), n=1, warmup=0)
    t_warm = _t(lambda: eng.run(matrix), n=3, warmup=0)
    _row("B3_cold_run_6x50ms", t_cold)
    _row("B3_cached_rerun", t_warm, f"speedup={t_cold/max(t_warm,1e-9):.1f}x")


def bench_checkpoint_overhead(tmpdir="/tmp/repro_bench_ckpt") -> None:
    import shutil

    import jax.numpy as jnp

    from repro.ckpt.store import CheckpointStore

    shutil.rmtree(tmpdir, ignore_errors=True)
    state = {"w": jnp.ones((512, 512)), "m": jnp.ones((512, 512)), "step": jnp.ones(())}
    store = CheckpointStore(tmpdir)
    us_sync = _t(lambda: store.save(1, state, blocking=True), n=3)
    def async_save():
        store.save(2, state, blocking=False)
    us_async = _t(async_save, n=3)
    store.wait()
    _row("B4_ckpt_save_2MB_sync", us_sync)
    _row("B4_ckpt_save_2MB_async_enqueue", us_async, f"hidden={us_sync/max(us_async,1):.1f}x")


def bench_failure_isolation() -> None:
    from repro.core import Memento, RunnerConfig

    def half_broken(ctx):
        if ctx["i"] % 2:
            raise RuntimeError("boom")
        return ctx["i"]

    eng = Memento(
        half_broken,
        runner_config=RunnerConfig(max_workers=4, retries=0, enable_speculation=False),
    )
    us = _t(lambda: eng.run({"parameters": {"i": list(range(8))}}, cache=False), n=2, warmup=0)
    res = eng.run({"parameters": {"i": list(range(8))}}, cache=False)
    _row("B5_half_failing_run", us, f"ok={len(res.ok)} failed={len(res.failed)} isolated=True")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, blk_q=128, blk_k=128))
    rf_ = jax.jit(
        lambda q, k, v: ref.sdpa_ref(
            q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
            k.transpose(0, 2, 1, 3).reshape(B * H, S, D),
            v.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        )
    )
    us_k = _t(lambda: jax.block_until_ready(fa(q, k, v)))
    us_r = _t(lambda: jax.block_until_ready(rf_(q, k, v)))
    _row("B6_flash_attn_256_interp", us_k, f"oracle={us_r:.0f}us (interpret-mode CPU; TPU target)")

    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 256, 256)))
    b = jax.random.normal(ks[1], (2, 256, 256))
    rg = jax.jit(lambda a, b: ops.rglru_op(a, b, blk_t=128, blk_d=256))
    rr = jax.jit(lambda a, b: ref.rglru_ref(a, b))
    _row("B6_rglru_256x256_interp", _t(lambda: jax.block_until_ready(rg(a, b))),
         f"oracle={_t(lambda: jax.block_until_ready(rr(a, b))):.0f}us")


def bench_train_step() -> None:
    import jax

    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_config
    from repro.sharding.rules import ShardingCtx
    from repro.train.step import make_train_setup, make_train_step
    from repro.data.pipeline import make_batch_fn

    cfg = get_config("llama3.2-3b").reduced()
    shape = ShapeConfig("bench", "train", seq_len=64, global_batch=4)
    setup = make_train_setup(cfg, shape, ShardingCtx.null())
    step = jax.jit(make_train_step(setup), donate_argnums=(0,))
    holder = {"state": setup.init_state(jax.random.PRNGKey(0))}
    batch = make_batch_fn(cfg, shape)(0)

    def once():
        # thread the (donated) state through iterations
        s, m = step(holder["state"], batch)
        holder["state"] = s
        jax.block_until_ready(m["loss_mean"])

    us = _t(once, n=3)
    toks = shape.tokens
    _row("B7_train_step_smoke_llama", us, f"{toks/(us/1e6):.0f} tok/s CPU smoke")


def bench_serve_throughput() -> None:
    """B9: continuous-batching scheduler under Poisson arrivals with mixed
    prompt/output lengths. Reports aggregate tokens/s and p50/p95 request
    latency (submit -> last token)."""
    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models import lm
    from repro.models.schema import init_params
    from repro.serve.request import Request
    from repro.serve.scheduler import Scheduler, SchedulerConfig
    from repro.sharding.rules import ShardingCtx

    cfg = get_config("llama3.2-3b").reduced()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    sched = Scheduler(
        cfg, params, ShardingCtx.null(), SchedulerConfig(n_slots=4, cache_len=64)
    )

    rng = np.random.default_rng(0)
    n_req = 12
    arrivals = np.cumsum(rng.exponential(scale=0.05, size=n_req))  # Poisson process
    prompt_lens = rng.choice([4, 8, 12], size=n_req)
    out_lens = rng.choice([4, 8], size=n_req)
    requests = [
        Request(
            rng.integers(0, cfg.vocab_size, size=int(p)).astype(np.int32),
            max_new_tokens=int(o),
        )
        for p, o in zip(prompt_lens, out_lens)
    ]

    # Warm every prompt-length bucket (prefill/admit compile per length) and
    # the decode step so the measured run sees steady-state latencies.
    for p in sorted(set(int(x) for x in prompt_lens)):
        sched.submit(Request(np.zeros(p, np.int32), max_new_tokens=2))
    sched.run()

    rids = []
    t0 = time.perf_counter()
    i = 0
    while i < n_req or sched.pending or sched.num_active:
        now = time.perf_counter() - t0
        while i < n_req and arrivals[i] <= now:
            rids.append(sched.submit(requests[i]))
            i += 1
        if not sched.step() and i < n_req:
            time.sleep(max(0.0, arrivals[i] - (time.perf_counter() - t0)))
    wall = time.perf_counter() - t0

    done = [sched.result(r) for r in rids]
    toks = sum(len(r.tokens) for r in done)
    lat = np.array([r.latency_s for r in done])
    p50, p95 = np.percentile(lat, 50), np.percentile(lat, 95)
    _row(
        "B9_serve_poisson_12req_4slots",
        wall * 1e6,
        f"{toks / wall:.1f} tok/s p50={p50 * 1e3:.0f}ms p95={p95 * 1e3:.0f}ms "
        f"decode_traces={sched.decode_traces}",
    )


def bench_serve_paged() -> None:
    """B10: paged-KV serving memory under mixed 32..2048-token prompts.

    Drives the scheduler twice over the same workload — paged pool vs
    contiguous per-slot rows — and reports tokens/s, p50/p95 latency, and
    peak cache bytes. The paged pool is sized at half the contiguous
    capacity: short requests pack around the long ones, and peak bytes
    track live tokens (pages in use), not n_slots x cache_len.
    """
    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models import lm as _lm
    from repro.models.schema import init_params
    from repro.serve.request import Request
    from repro.serve.scheduler import Scheduler, SchedulerConfig
    from repro.sharding.rules import ShardingCtx

    cfg = get_config("llama3.2-3b").reduced()
    params = init_params(_lm.model_schema(cfg), jax.random.PRNGKey(0))
    cache_len = 2176  # one 2048-token prompt + decode headroom
    n_slots, page = 4, 64

    rng = np.random.default_rng(0)
    prompt_lens = [32, 64, 2048, 128, 32, 256, 512, 32]
    requests = [
        Request(
            rng.integers(0, cfg.vocab_size, size=p).astype(np.int32),
            max_new_tokens=8,
        )
        for p in prompt_lens
    ]

    for label, kw in (
        ("contig", dict(paged=False)),
        # Half the contiguous pool: admission multiplexes pages across slots.
        ("paged", dict(paged=True, page_size=page, n_pages=(n_slots * cache_len) // (2 * page))),
    ):
        sched = Scheduler(
            cfg, params, ShardingCtx.null(),
            SchedulerConfig(n_slots=n_slots, cache_len=cache_len, **kw),
        )
        # Warm compile per bucket so the measured run is steady-state.
        for p in sorted({len(r.prompt) for r in requests}):
            sched.submit(Request(np.zeros(p, np.int32), max_new_tokens=2))
        sched.run()
        # Peak/deferral counters must describe the measured run, not warmup.
        if sched.pool is not None:
            sched.pool.reset_peaks()
        sched.deferred_admissions = 0

        t0 = time.perf_counter()
        rids = [sched.submit(r) for r in requests]
        sched.run()
        wall = time.perf_counter() - t0
        done = [sched.result(r) for r in rids]
        toks = sum(len(r.tokens) for r in done)
        lat = np.array([r.latency_s for r in done])
        p50, p95 = np.percentile(lat, 50), np.percentile(lat, 95)
        cb = sched.paged_cache_bytes()
        _row(
            f"B10_serve_{label}_8req_{n_slots}slots",
            wall * 1e6,
            f"{toks / wall:.1f} tok/s p50={p50 * 1e3:.0f}ms p95={p95 * 1e3:.0f}ms "
            + (
                f"peak_cache_bytes={cb['peak_bytes']} "
                f"(contiguous_equiv={cb['contiguous_bytes']}, "
                f"pool={sched.pool.stats()['n_pages']}p x {page}tok) "
                f"deferred={sched.stats()['deferred_admissions']} "
                f"decode_traces={sched.decode_traces}"
                if label == "paged"
                else f"cache_bytes={n_slots}x{cache_len} rows "
                f"decode_traces={sched.decode_traces}"
            ),
        )


def bench_roofline_summary() -> None:
    try:
        from repro.launch.report import load_results

        rows, skipped = load_results()
    except Exception as e:
        _row("B8_roofline", 0.0, f"no cached sweep ({e})")
        return
    sp = [v for v in rows if v.get("mesh") == "16x16" and v.get("roofline")]
    for v in sorted(sp, key=lambda v: (v["arch"], v["shape"])):
        r = v["roofline"]
        _row(
            f"B8_{v['arch']}_{v['shape']}",
            r["step_time_lower_bound"] * 1e6,
            f"bottleneck={r['bottleneck']} roofline_frac={r['roofline_fraction']:.3f}",
        )


def main() -> None:
    print("name,us_per_call,derived")
    bench_matrix_expansion()
    bench_parallel_speedup()
    bench_cache_speedup()
    bench_checkpoint_overhead()
    bench_failure_isolation()
    bench_kernels()
    bench_train_step()
    bench_serve_throughput()
    bench_serve_paged()
    bench_roofline_summary()


if __name__ == "__main__":
    main()
