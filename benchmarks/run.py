"""Benchmark harness — one function per paper claim + roofline summaries.

The Memento paper's claims (demo paper, no numeric tables) map to:
  B1  configuration-matrix expansion scales to large experiment sets
      (including composed matrices: products, filters, derived params)
  B2  parallel execution beats sequential for embarrassingly-parallel tasks
  B3  result caching makes re-runs ~free
  B4  in-task checkpointing bounds lost work on interruption
  B5  failure isolation: one broken task does not poison a run
plus framework-level benchmarks, which since the Experiment API v2 run
*through* Memento via the ``repro.experiments`` adapters (so they exercise
caching/streaming/retries end-to-end, not hand-rolled loops):
  B6  per-kernel interpret-mode microbenches (us_per_call vs jnp oracle)
  B7  train-sweep cell wall time for a tiny model (CPU, smoke scale)
  B8  dry-run roofline summary (from the cached sweep, if present)
  B9  continuous-batching serve throughput under Poisson arrivals
  B10 paged-KV serving: mixed prompt sizes multiplexed over a fixed page
      pool vs the contiguous per-slot baseline (tokens/s, p50/p95 latency,
      peak cache bytes) — one matrix, ``paged`` as an axis
  B11 chunked prefill: mixed 32–4096-token prompts with the unified
      token-budget step on vs off — p50/p95 *inter-token* latency for
      in-flight decodes at equal throughput, ``chunk_budget`` as an axis
  B12 distributed drain: the same matrix drained through the file-queue by
      1/2/4 single-threaded worker processes on one shared tmpdir —
      tasks/s, speedup, and scaling efficiency; plus a kill-one-worker row
      showing lease recovery completing the matrix anyway
  B13 prompt-prefix sharing: warm vs cold TTFT + peak page bytes on a
      shared-system-prompt workload, ``prefix_sharing`` as an axis
  B14 speculative decoding: drafted multi-token steps with batched verify
      on the mixed-length Poisson workload — decode tokens per model step
      and inter-token latency, ``speculative`` as an axis, token identity
      asserted against the non-speculative row
  B16 layered serving core: 1x1 vs (data)x1 step times with slot ranges
      and pool slices partitioned across the data axis, plus the pure-host
      plan layer's us/step (``plan_us_per_step``, gated by policy.json)

Prints ``name,us_per_call,derived`` CSV rows, and **persists** every run
as a versioned record ``benchmarks/records/BENCH_<n>.json`` (rows + git
commit + timestamp + mode) — the repo's queryable perf trajectory (see
``repro.analysis.trajectory``). After writing, the run is auto-diffed
against the latest same-mode record on the current commit's *lineage*
and ``WARN,...`` lines flag >30% tok/s regressions.
Identity rows (B11/B13/B14 token mismatches) make the process exit
nonzero so CI cannot silently pass on corrupted outputs.

``--smoke`` runs B1–B5 at tiny sizes (seconds, no model compiles) plus
tiny B9/B10/B11/B13/B14 serve rows (one smoke-scale model compile) — the
CI end-to-end exercise of the experiment *and* serving layers.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import statistics
import subprocess
import sys
import time
from datetime import datetime, timezone


def _t(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


# Every _row() call lands here; write_records() persists the run. Identity
# rows report ok=False on mismatch, which turns into a nonzero exit.
_RECORDS: list[dict] = []
_FAILED: list[str] = []
_RECORDS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "records")


def _row(name: str, us: float, derived: str = "", ok: bool = True,
         metrics: dict | None = None) -> None:
    print(f"{name},{us:.1f},{derived}")
    rec: dict = {
        "name": name,
        "value": round(us, 1),
        "unit": "us_per_call",
        "derived": derived,
        "ok": bool(ok),
    }
    # Examiner-style metric extraction: the throughput figure embedded in
    # the derived text becomes a first-class record field the perf diff
    # can compare across runs; ``metrics`` adds fields with no textual form
    # (anything named in benchmarks/policy.json must land here).
    m = re.search(r"([0-9][0-9.]*) tok/s", derived)
    if m:
        rec["tok_s"] = float(m.group(1))
    if metrics:
        rec.update({k: v for k, v in metrics.items() if v is not None})
    _RECORDS.append(rec)
    if not ok:
        _FAILED.append(name)


def _git_commit() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def write_records(mode: str, records_dir: str | None = None) -> str | None:
    """Persist this run's rows as the next ``BENCH_<n>.json`` record."""
    if not _RECORDS:
        return None
    d = records_dir or _RECORDS_DIR
    os.makedirs(d, exist_ok=True)
    ns = [
        int(m.group(1))
        for f in os.listdir(d)
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", f))
    ]
    n = max(ns, default=0) + 1
    path = os.path.join(d, f"BENCH_{n}.json")
    payload = {
        "schema": 1,
        "record": n,
        "mode": mode,
        "git_commit": _git_commit(),
        "timestamp": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "rows": _RECORDS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"RECORD,{path},{len(_RECORDS)} rows")
    return path


def diff_records(new_path: str, records_dir: str | None = None) -> list[str]:
    """Diff ``new_path`` against its baseline; returns ``WARN,...`` lines
    for regressions under the checked-in policy thresholds.

    Delegates to ``repro.analysis.trajectory`` so these verdicts and the
    ``python -m repro.analysis regressions`` CLI are identical by
    construction. Thresholds come from ``benchmarks/policy.json`` (falling
    back to the built-in >30% tok/s rule if it's gone), so tightening a
    bound is a reviewed diff on the policy file, not a CI-config edit. The
    baseline is the latest earlier record of the same mode whose commit is
    on the current commit's lineage — a record produced on a diverged
    branch is never the comparison point. Rows are matched by name; rows
    where *either* side has no extracted value for a policy's metric are
    skipped, so a baseline without the metric can't fabricate a WARN.
    """
    from repro.analysis.trajectory import (
        BenchRecord,
        Trajectory,
        detect_regressions,
        find_baseline,
        load_policies,
    )

    new = BenchRecord.load(new_path)
    traj = Trajectory.load(records_dir or _RECORDS_DIR)
    baseline = find_baseline(traj, new)
    policies = load_policies(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "policy.json")
    )
    return [r.warn_line() for r in detect_regressions(new, baseline, policies)]


def _value(result):
    """Unwrap a TaskResult, surfacing the captured failure instead of a
    NoneType error on ``.value`` access."""
    if not result.ok:
        raise RuntimeError(
            f"benchmark task failed: {result.summary()}\n{result.traceback_str or ''}"
        )
    return result.value


def bench_matrix_expansion(smoke: bool = False) -> None:
    from repro.core import ConfigMatrix

    shapes = ((3, 6),) if smoke else ((4, 10), (5, 12))
    for n_axes, width in shapes:
        m = ConfigMatrix.from_dict(
            {"parameters": {f"p{i}": list(range(width)) for i in range(n_axes)}}
        )
        total = width ** n_axes
        us = _t(lambda: m.task_list(), n=2)
        _row(
            f"B1_matrix_expand_{total}_tasks", us,
            f"{total/ (us/1e6):.0f} tasks/s incl hashing",
        )

    # Composed expansion: product of two matrices, a callable exclude, and a
    # derived parameter — the v2 algebra on the same hot path.
    w = 4 if smoke else 8
    m1 = ConfigMatrix.from_dict({"parameters": {"a": list(range(w)), "b": list(range(w))}})
    m2 = ConfigMatrix.from_dict({"parameters": {"c": list(range(w))}})
    comp = (m1 * m2).where(lambda p: p["a"] != p["c"]).derive("ab", lambda p: p["a"] * p["b"])
    n_tasks = len(comp.task_list())
    us = _t(lambda: comp.task_list(), n=2)
    _row(
        f"B1_matrix_algebra_{n_tasks}_tasks", us,
        f"(m1*m2).where(a!=c).derive(ab) -> {n_tasks}/{w**3} tasks",
    )


def bench_parallel_speedup(smoke: bool = False) -> None:
    from repro.core import Memento, RunnerConfig

    delay = 0.02 if smoke else 0.05
    n_tasks = 4 if smoke else 8

    def sleepy(ctx):
        time.sleep(ctx.settings["delay"])
        return ctx["i"]

    matrix = {"parameters": {"i": list(range(n_tasks))}, "settings": {"delay": delay}}
    seq = Memento(sleepy, runner_config=RunnerConfig(max_workers=1, enable_speculation=False))
    par = Memento(sleepy, runner_config=RunnerConfig(max_workers=n_tasks, enable_speculation=False))
    t_seq = _t(lambda: seq.run(matrix, cache=False), n=2, warmup=0)
    t_par = _t(lambda: par.run(matrix, cache=False), n=2, warmup=0)
    _row(f"B2_sequential_{n_tasks}x{delay*1e3:.0f}ms", t_seq)
    _row(f"B2_parallel_{n_tasks}workers", t_par, f"speedup={t_seq/t_par:.2f}x")


def bench_cache_speedup(tmpdir="/tmp/repro_bench_cache", smoke: bool = False) -> None:
    import shutil

    from repro.core import Memento

    shutil.rmtree(tmpdir, ignore_errors=True)
    delay = 0.02 if smoke else 0.05
    n_tasks = 4 if smoke else 6

    def work(ctx):
        time.sleep(ctx.settings["delay"])
        return ctx["i"] ** 2

    eng = Memento(work, workdir=tmpdir)
    matrix = {"parameters": {"i": list(range(n_tasks))}, "settings": {"delay": delay}}
    t_cold = _t(lambda: eng.run(matrix), n=1, warmup=0)
    t_warm = _t(lambda: eng.run(matrix), n=3, warmup=0)
    _row(f"B3_cold_run_{n_tasks}x{delay*1e3:.0f}ms", t_cold)
    _row("B3_cached_rerun", t_warm, f"speedup={t_cold/max(t_warm,1e-9):.1f}x")


def bench_checkpoint_overhead(tmpdir="/tmp/repro_bench_ckpt", smoke: bool = False) -> None:
    import shutil

    import jax.numpy as jnp

    from repro.ckpt.store import CheckpointStore

    shutil.rmtree(tmpdir, ignore_errors=True)
    dim = 64 if smoke else 512
    state = {"w": jnp.ones((dim, dim)), "m": jnp.ones((dim, dim)), "step": jnp.ones(())}
    store = CheckpointStore(tmpdir)
    us_sync = _t(lambda: store.save(1, state, blocking=True), n=3)
    def async_save():
        store.save(2, state, blocking=False)
    us_async = _t(async_save, n=3)
    store.wait()
    mb = state["w"].nbytes * 2 / 1e6
    _row(f"B4_ckpt_save_{mb:.1f}MB_sync", us_sync)
    _row(f"B4_ckpt_save_{mb:.1f}MB_async_enqueue", us_async, f"hidden={us_sync/max(us_async,1):.1f}x")


def bench_failure_isolation() -> None:
    from repro.core import Memento, RunnerConfig

    def half_broken(ctx):
        if ctx["i"] % 2:
            raise RuntimeError("boom")
        return ctx["i"]

    eng = Memento(
        half_broken,
        runner_config=RunnerConfig(max_workers=4, retries=0, enable_speculation=False),
    )
    us = _t(lambda: eng.run({"parameters": {"i": list(range(8))}}, cache=False), n=2, warmup=0)
    res = eng.run({"parameters": {"i": list(range(8))}}, cache=False)
    _row("B5_half_failing_run", us, f"ok={len(res.ok)} failed={len(res.failed)} isolated=True")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, D = 1, 256, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    fa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, blk_q=128, blk_k=128))
    rf_ = jax.jit(
        lambda q, k, v: ref.sdpa_ref(
            q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
            k.transpose(0, 2, 1, 3).reshape(B * H, S, D),
            v.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        )
    )
    us_k = _t(lambda: jax.block_until_ready(fa(q, k, v)))
    us_r = _t(lambda: jax.block_until_ready(rf_(q, k, v)))
    _row("B6_flash_attn_256_interp", us_k, f"oracle={us_r:.0f}us (interpret-mode CPU; TPU target)")

    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 256, 256)))
    b = jax.random.normal(ks[1], (2, 256, 256))
    rg = jax.jit(lambda a, b: ops.rglru_op(a, b, blk_t=128, blk_d=256))
    rr = jax.jit(lambda a, b: ref.rglru_ref(a, b))
    _row("B6_rglru_256x256_interp", _t(lambda: jax.block_until_ready(rg(a, b))),
         f"oracle={_t(lambda: jax.block_until_ready(rr(a, b))):.0f}us")


def bench_train_sweep() -> None:
    """B7: one training cell through Memento + experiments.train_sweep."""
    import shutil

    from repro.core import Memento, RunnerConfig
    from repro.experiments import train_matrix, train_sweep

    # Fresh checkpoint dir: a leftover final checkpoint would make the run
    # resume at its last step and train nothing.
    shutil.rmtree("/tmp/repro_bench_train", ignore_errors=True)
    matrix = train_matrix(
        ["llama3.2-3b"], lrs=[1e-3], steps=8, seq_len=64, global_batch=4,
        ckpt_every=1000, log_every=4,
        workdir="/tmp/repro_bench_train",
    )
    eng = Memento(
        train_sweep, namespace="train",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    t0 = time.perf_counter()
    res = eng.run(matrix, cache=False)
    us = (time.perf_counter() - t0) * 1e6
    v = _value(res[0])
    _row(
        "B7_train_sweep_smoke_llama", us,
        f"{v['tokens_per_s']:.0f} tok/s CPU smoke (incl compile), "
        f"loss {v['loss_first']:.3f} -> {v['loss_last']:.3f}",
    )


def bench_serve_throughput() -> None:
    """B9: continuous-batching scheduler under Poisson arrivals with mixed
    prompt lengths, driven through Memento + experiments.serve_sweep."""
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep

    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"n_slots": [4]},
        cache_len=64, n_requests=12, prompt_lens=(4, 8, 12),
        max_new_tokens=8, arrival_rate_hz=20.0, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    res = eng.run(matrix, cache=False)
    v = _value(res[0])
    _row(
        "B9_serve_poisson_12req_4slots",
        v["wall_s"] * 1e6,
        f"{v['tokens_per_s']:.1f} tok/s p50={v['latency_p50_s']*1e3:.0f}ms "
        f"p95={v['latency_p95_s']*1e3:.0f}ms decode_traces={v['decode_traces']}",
    )


def bench_serve_paged() -> None:
    """B10: paged-KV serving memory under mixed 32..2048-token prompts.

    One Memento matrix with ``paged`` as an axis replays the same workload
    through the page pool (sized at half the contiguous capacity) and the
    contiguous per-slot baseline; short requests pack around the long ones,
    and peak bytes track live pages, not n_slots x cache_len.
    """
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep

    cache_len, n_slots, page = 2176, 4, 64
    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"paged": [False, True]},
        cache_len=cache_len, n_slots=n_slots, page_size=page,
        n_pages=(n_slots * cache_len) // (2 * page),
        n_requests=8, prompt_lens=(32, 64, 2048, 128, 32, 256, 512, 32),
        max_new_tokens=8, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        label = "paged" if v["paged"] else "contig"
        extra = (
            f"peak_cache_bytes={v['peak_cache_bytes']} "
            f"(contiguous_equiv={v['contiguous_cache_bytes']}) "
            f"deferred={v['deferred_admissions']} "
            if v["paged"]
            else f"cache_bytes={n_slots}x{cache_len} rows "
        )
        _row(
            f"B10_serve_{label}_8req_{n_slots}slots",
            v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s p50={v['latency_p50_s']*1e3:.0f}ms "
            f"p95={v['latency_p95_s']*1e3:.0f}ms {extra}"
            f"decode_traces={v['decode_traces']}",
        )


def bench_serve_chunked(smoke: bool = False) -> None:
    """B11: chunked prefill vs whole-prompt prefill on a mixed-size prompt
    workload.

    One Memento matrix with ``chunk_budget`` as the axis replays the same
    Poisson-timed arrival trace — long prompts land *while short requests
    are mid-decode*, so each whole-prompt admission stalls every in-flight
    decode on the chunking-off row — and reports the p50/p95 *inter-token*
    latency streaming clients feel, at comparable throughput. Greedy token
    identity between the two rows is checked here too — the unified step
    is a scheduling change, not a sampling change.
    """
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep

    if smoke:
        cache_len, page, prompts, budget = 64, 8, (8, 40, 12, 33), 16
        rate = 0.0
    else:
        cache_len, page, budget, rate = 4224, 64, 256, 6.0
        prompts = (32, 32, 64, 4096, 32, 64, 2048, 32, 128, 32)
    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"chunk_budget": [0, budget]},
        cache_len=cache_len, n_slots=4, page_size=page,
        n_requests=len(prompts), prompt_lens=prompts,
        max_new_tokens=8 if not smoke else 16,
        arrival_rate_hz=rate, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    tokens = {}
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        label = f"chunked_{v['chunk_budget']}" if v["chunk_budget"] else "chunking_off"
        tokens[label] = v["tokens"]
        _row(
            f"B11_serve_{label}_{len(prompts)}req",
            v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s itl_p50={v['itl_p50_s']*1e3:.0f}ms "
            f"itl_p95={v['itl_p95_s']*1e3:.0f}ms chunk_steps={v['chunk_steps']} "
            f"chunk_traces={v['chunk_traces']} decode_traces={v['decode_traces']}",
        )
    vals = list(tokens.values())
    if len(vals) == 2:
        if vals[0] != vals[1]:
            _row("B11_token_identity", 0.0, "MISMATCH between chunked and off",
                 ok=False)
        else:
            _row("B11_token_identity", 0.0, "identical tokens")


def bench_serve_prefix(smoke: bool = False) -> None:
    """B13: prompt-prefix sharing on a shared-system-prompt workload.

    One Memento matrix with ``prefix_sharing`` as the axis drives the same
    workload in which every prompt starts with one shared system prompt. A
    primer request registers the prefix pages before the timed window (its
    solo TTFT is reported as ttft_cold); with sharing on, every timed
    request adopts the registered pages instead of recomputing them —
    warm-prefix TTFT drops below the no-sharing arm's cold-prefix TTFT on
    the identical contended workload, and peak page bytes drop below the
    no-sharing baseline because N slots map one physical copy of the
    prefix. Greedy token identity between the two rows is asserted:
    sharing is a memory/latency change, not a sampling change.
    """
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep

    if smoke:
        cache_len, page, budget, shared_len = 96, 8, 16, 32
        prompts, rate, max_new = (4, 9, 6, 4), 0.0, 4
    else:
        cache_len, page, budget, shared_len = 4224, 64, 256, 1024
        prompts, rate, max_new = (32, 64, 32, 128, 32, 64, 32, 96), 6.0, 8
    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"prefix_sharing": [False, True]},
        cache_len=cache_len, n_slots=4, page_size=page, chunk_budget=budget,
        n_requests=len(prompts), prompt_lens=prompts,
        shared_prefix_len=shared_len, prime_prefix=True,
        max_new_tokens=max_new, arrival_rate_hz=rate, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    rows = {}
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        label = "sharing_on" if v["prefix_sharing"] else "sharing_off"
        rows[label] = v
        warm = v["ttft_warm_p50_s"] or v["ttft_p50_s"]
        _row(
            f"B13_serve_prefix_{label}_{len(prompts)}req",
            v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s ttft_cold={v['ttft_cold_s']*1e3:.0f}ms "
            f"ttft_warm_p50={warm*1e3:.0f}ms prefix_hits={v['prefix_hits']} "
            f"hit_tokens={v['prefix_hit_tokens']} "
            f"peak_cache_bytes={v['peak_cache_bytes']}",
        )
    if len(rows) == 2:
        on, off = rows["sharing_on"], rows["sharing_off"]
        if on["tokens"] != off["tokens"]:
            _row("B13_token_identity", 0.0, "MISMATCH between sharing on and off",
                 ok=False)
        else:
            _row("B13_token_identity", 0.0, "identical tokens")
        # cold baseline = the sharing-off arm's TTFT p50: the same timed
        # requests under the same contention, just with cold prefixes (the
        # primer's solo ttft_cold is uncontended and not comparable)
        warm_lt_cold = (on["ttft_warm_p50_s"] or float("inf")) < off["ttft_p50_s"]
        mem_lt_off = on["peak_cache_bytes"] < off["peak_cache_bytes"]
        _row(
            "B13_prefix_wins", 0.0,
            f"warm_ttft_lt_cold={warm_lt_cold} "
            f"({(on['ttft_warm_p50_s'] or 0) * 1e3:.0f}ms vs "
            f"{off['ttft_p50_s'] * 1e3:.0f}ms) "
            f"peak_bytes_lt_nosharing={mem_lt_off} "
            f"({on['peak_cache_bytes']} vs {off['peak_cache_bytes']})",
        )


def bench_serve_spec(smoke: bool = False) -> None:
    """B14: speculative decoding on the mixed-length Poisson workload.

    One Memento matrix with ``speculative`` as the axis replays the same
    arrival trace with and without drafted multi-token steps. The drafter
    is the oracle ReplayDrafter (a muted reference pass collects the
    greedy continuations first), so the row measures the substrate —
    batched verify, rollback, page growth — at the high-acceptance end
    rather than any particular draft heuristic; prefix sharing is off so
    the oracle's reference pass cannot warm the timed rows. Reports
    decode tokens per model step (the figure speculation improves: each
    verify call emits accepted+1 tokens) and inter-token latency; greedy
    token identity between the two rows is asserted — acceptance keeps
    exactly the longest run matching what sequential decode would emit.
    """
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep

    if smoke:
        cache_len, page, budget = 96, 8, 16
        prompts, rate, max_new = (6, 20, 9, 14, 32, 12), 20.0, 16
    else:
        cache_len, page, budget, rate = 4224, 64, 256, 6.0
        prompts = (32, 32, 64, 2048, 32, 64, 1024, 32, 128, 32)
        max_new = 32
    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"speculative": [False, True]},
        cache_len=cache_len, n_slots=4, page_size=page, chunk_budget=budget,
        n_requests=len(prompts), prompt_lens=prompts,
        max_new_tokens=max_new, arrival_rate_hz=rate,
        draft_k=7, drafter="oracle", prefix_sharing=False, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    rows = {}
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        label = "spec_on" if v["speculative"] else "spec_off"
        rows[label] = v
        extra = (
            f"spec_steps={v['spec_steps']} replays={v['spec_replays']} "
            f"accept_rate={(v['accept_rate'] or 0.0):.2f} "
            f"fallbacks={v['spec_fallbacks']} verify_traces={v['verify_traces']} "
            if v["speculative"]
            else f"decode_steps={v['decode_steps']} "
        )
        _row(
            f"B14_serve_{label}_{len(prompts)}req",
            v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s "
            f"tok_per_step={v['tokens_per_model_step']:.2f} "
            f"itl_p50={v['itl_p50_s']*1e3:.1f}ms {extra}",
        )
    if len(rows) == 2:
        on, off = rows["spec_on"], rows["spec_off"]
        if on["tokens"] != off["tokens"]:
            _row("B14_token_identity", 0.0,
                 "MISMATCH between speculative and off", ok=False)
        else:
            _row("B14_token_identity", 0.0, "identical tokens")
        ratio = on["tokens_per_model_step"] / off["tokens_per_model_step"]
        itl_better = on["itl_p50_s"] <= off["itl_p50_s"]
        # The ratio is count-based (tokens / model steps), not wall-clock,
        # so the >=1.5x bar is deterministic given the oracle drafter; ITL
        # is wall-clock and reported informationally.
        _row(
            "B14_spec_wins", 0.0,
            f"tok_per_step={ratio:.2f}x (>=1.5x required) "
            f"itl_p50_improved={itl_better} "
            f"({on['itl_p50_s']*1e3:.1f}ms vs {off['itl_p50_s']*1e3:.1f}ms)",
            ok=ratio >= 1.5,
        )


def bench_serve_sharded(smoke: bool = False) -> None:
    """B15: tensor-parallel sharded stepping vs the single-device step.

    One Memento matrix with ``mesh_shape`` as the axis replays the same
    greedy workload on 1 device and on a (1, model) test mesh (forced host
    devices off-TPU). Greedy token identity across meshes is asserted —
    sharded stepping must be a pure layout change — along with unchanged
    decode/chunk trace counts (one compile per bucket, never per mesh).
    Each row reports measured inter-token latency next to the analytic
    roofline prediction for that mesh (launch/roofline.py): on forced host
    devices the measured/predicted ratio is meaningless in magnitude, but
    the per-mesh predictions are exactly what a real v5e run would be
    gated on.
    """
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep
    from repro.launch.mesh import devices_required

    model = 2 if smoke else 4
    if not devices_required(model):
        _row(
            "B15_serve_sharded", 0.0,
            f"skipped: needs {model} XLA devices, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={model} "
            "before running (CI sharded-smoke lane does)",
        )
        return
    if smoke:
        cache_len, page, budget, max_new = 96, 8, 16, 8
        prompts = (6, 20, 9, 14)
    else:
        cache_len, page, budget, max_new = 512, 16, 64, 16
        prompts = (16, 48, 24, 96, 32, 8)
    meshes = ["1x1", f"1x{model}"]
    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"mesh_shape": meshes},
        cache_len=cache_len, n_slots=4, page_size=page, chunk_budget=budget,
        n_requests=len(prompts), prompt_lens=prompts,
        max_new_tokens=max_new, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    rows = {}
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        rows[v["mesh"]] = v
        _row(
            f"B15_serve_sharded_{v['mesh']}",
            v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s "
            f"itl_p50={v['itl_p50_s']*1e3:.1f}ms "
            f"pred={v['predicted_step_ms']:.3f}ms "
            f"({v['predicted_bottleneck']}-bound) "
            f"ratio={v['itl_p50_s']*1e3/v['predicted_step_ms']:.0f}x "
            f"decode_traces={v['decode_traces']} "
            f"chunk_traces={v['chunk_traces']} devices={v['mesh_devices']}",
        )
    if len(rows) == len(meshes):
        base = rows[meshes[0]]
        sharded = rows[meshes[1]]
        if base["tokens"] != sharded["tokens"]:
            _row("B15_sharded_token_identity", 0.0,
                 f"MISMATCH between {meshes[0]} and {meshes[1]}", ok=False)
        else:
            _row("B15_sharded_token_identity", 0.0,
                 f"identical tokens across {' vs '.join(meshes)}")
        traces_ok = (
            base["decode_traces"] == sharded["decode_traces"]
            and base["chunk_traces"] == sharded["chunk_traces"]
        )
        _row(
            "B15_sharded_trace_bound", 0.0,
            f"decode_traces {base['decode_traces']}=={sharded['decode_traces']} "
            f"chunk_traces {base['chunk_traces']}=={sharded['chunk_traces']} "
            "(one compile per bucket, never per mesh)",
            ok=traces_ok,
        )


def bench_serve_layered(smoke: bool = False) -> None:
    """B16: layered serving core — data-parallel slots + planner overhead.

    One Memento matrix with ``mesh_shape`` as the axis replays the same
    greedy workload on one device and on a (data, 1) mesh, where each data
    shard owns a contiguous slot range and its own page-pool slice (the
    layered core's data-axis partitioning; ``data > 1`` used to merely
    replicate pool state). Greedy token identity across the two rows is
    asserted — partitioning is a layout change, not a scheduling change —
    and each row reports the pure-host plan layer's cost per scheduler
    step next to the step time. ``plan_us_per_step`` is persisted as a
    record field gated by benchmarks/policy.json: the planner must stay
    microseconds against millisecond device steps, and a doubling is a
    regression even when tok/s holds.
    """
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep
    from repro.launch.mesh import devices_required

    data = 2
    if not devices_required(data):
        _row(
            "B16_serve_layered", 0.0,
            f"skipped: needs {data} XLA devices, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data} "
            "before running (CI sharded-smoke lane does)",
        )
        return
    if smoke:
        cache_len, page, budget, max_new = 96, 8, 16, 8
        prompts = (6, 20, 9, 14)
    else:
        cache_len, page, budget, max_new = 512, 16, 64, 16
        prompts = (16, 48, 24, 96, 32, 8)
    meshes = ["1x1", f"{data}x1"]
    matrix = serve_matrix(
        ["llama3.2-3b"], backends=["xla"],
        scheduler={"mesh_shape": meshes},
        cache_len=cache_len, n_slots=4, page_size=page, chunk_budget=budget,
        n_requests=len(prompts), prompt_lens=prompts,
        max_new_tokens=max_new, warmup=True,
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    rows = {}
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        rows[v["mesh"]] = v
        _row(
            f"B16_serve_layered_{v['mesh']}",
            v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s "
            f"itl_p50={v['itl_p50_s']*1e3:.1f}ms "
            f"plan={v['plan_us_per_step']:.0f}us/step "
            f"({(v['plan_frac'] or 0.0)*100:.1f}% of wall) "
            f"decode_traces={v['decode_traces']} devices={v['mesh_devices']}",
            metrics={"plan_us_per_step": v["plan_us_per_step"]},
        )
    if len(rows) == len(meshes):
        base, dp = rows[meshes[0]], rows[meshes[1]]
        if base["tokens"] != dp["tokens"]:
            _row("B16_layered_token_identity", 0.0,
                 f"MISMATCH between {meshes[0]} and {meshes[1]}", ok=False)
        else:
            _row("B16_layered_token_identity", 0.0,
                 f"identical tokens across {' vs '.join(meshes)}")


def bench_serve_smoke() -> None:
    """Tiny B9/B10/B11 rows for CI: one smoke-scale model, second-scale
    workloads, still through Memento + serve_sweep end-to-end."""
    from repro.core import Memento, RunnerConfig
    from repro.experiments import serve_matrix, serve_sweep

    matrix = (
        serve_matrix(
            ["llama3.2-3b"], backends=["xla"],
            scheduler={"paged": [False, True]},
            cache_len=64, n_slots=2, n_requests=4, prompt_lens=(4, 9, 17, 6),
            max_new_tokens=4, warmup=False,
        )
        + serve_matrix(
            ["llama3.2-3b"], backends=["xla"],
            scheduler={"chunk_budget": [16]},
            cache_len=64, n_slots=2, page_size=8, n_requests=3,
            prompt_lens=(40, 8, 21), max_new_tokens=4, warmup=False,
        )
    )
    eng = Memento(
        serve_sweep, namespace="serve",
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    for r in eng.run(matrix, cache=False):
        v = _value(r)
        if v.get("chunk_budget"):
            label = "B11_smoke_chunked"
            extra = f"chunk_steps={v['chunk_steps']} chunk_traces={v['chunk_traces']}"
        elif v["paged"]:
            label = "B10_smoke_paged"
            extra = f"peak_cache_bytes={v['peak_cache_bytes']}"
        else:
            label = "B9_smoke_contig"
            extra = f"p95={v['latency_p95_s']*1e3:.0f}ms"
        _row(
            label, v["wall_s"] * 1e6,
            f"{v['tokens_per_s']:.1f} tok/s decode_traces={v['decode_traces']} {extra}",
        )


def _b12_task(ctx):
    time.sleep(ctx.settings["delay"])
    return ctx["i"]


def _b12_worker(root: str, n: int, delay: float, owner: str, lease_s: float,
                die_after: float = 0.0) -> None:
    import os

    from repro.core import (
        CallbackNotificationProvider,
        DistributedConfig,
        Memento,
        RunnerConfig,
    )

    if die_after:
        # Simulated host death: hard-kill this worker mid-drain, claims and
        # all. The survivors must finish the matrix via lease expiry.
        import threading

        threading.Timer(die_after, lambda: os._exit(29)).start()
    matrix = {"parameters": {"i": list(range(n))}, "settings": {"delay": delay}}
    eng = Memento(
        _b12_task,
        notification_provider=CallbackNotificationProvider(lambda e: None),
        workdir=os.path.join(root, "w"),
        runner_config=RunnerConfig(max_workers=1, enable_speculation=False, retries=0),
    )
    eng.run_distributed(
        matrix,
        queue_dir=os.path.join(root, "q"),
        lease_s=lease_s,
        owner=owner,
        # local disk, not NFS: poll tightly so completion latency, not the
        # poll cadence, dominates the tail
        distributed_config=DistributedConfig(
            poll_s=0.05, claim_ahead=1, progress_every_s=60.0
        ),
    )


def _b12_assemble(root: str):
    """A quiet parent-side engine that only assembles results (runs nothing
    itself by the time it is called — everything is cached/done)."""
    from repro.core import CallbackNotificationProvider, Memento

    return Memento(
        _b12_task,
        notification_provider=CallbackNotificationProvider(lambda e: None),
        workdir=f"{root}/w",
    )


def bench_distributed(smoke: bool = False) -> None:
    """B12: multi-host drain scaling.

    Each worker is a real OS process running ``Memento.run_distributed``
    with a single-threaded Runner (so the scaling measured is across the
    file-queue protocol, not across one process's thread pool), all draining
    one matrix on one shared tmpdir. A fresh queue+cache per point keeps the
    points independent; the parent verifies every point produced the full,
    identical ResultSet.
    """
    import multiprocessing
    import shutil
    import tempfile

    from repro.core import Memento

    mp = multiprocessing.get_context("fork")
    n_tasks = 8 if smoke else 32
    delay = 0.02 if smoke else 0.15
    points = (1, 2) if smoke else (1, 2, 4)
    lease_s = 30.0
    base_rate = None
    expected = list(range(n_tasks))
    for n_procs in points:
        root = tempfile.mkdtemp(prefix="repro_b12_")
        try:
            procs = [
                mp.Process(
                    target=_b12_worker,
                    args=(root, n_tasks, delay, f"w{i}", lease_s),
                )
                for i in range(n_procs)
            ]
            t0 = time.perf_counter()
            for p in procs:
                p.start()
            for p in procs:
                p.join(timeout=300)
            wall = time.perf_counter() - t0
            assert all(p.exitcode == 0 for p in procs), [p.exitcode for p in procs]
            matrix = {"parameters": {"i": expected}, "settings": {"delay": delay}}
            res = _b12_assemble(root).run_distributed(
                matrix, queue_dir=f"{root}/q", publish=False
            )
            assert sorted(r.value for r in res) == expected, "ResultSet mismatch"
            rate = n_tasks / wall
            if base_rate is None:
                base_rate = rate
            speedup = rate / base_rate
            _row(
                f"B12_distributed_{n_procs}proc_{n_tasks}tasks",
                wall * 1e6,
                f"{rate:.1f} tasks/s speedup={speedup:.2f}x "
                f"efficiency={speedup / n_procs:.2f}",
            )
        finally:
            shutil.rmtree(root, ignore_errors=True)

    # Kill-one-worker: 2 workers + one that dies mid-drain; lease recovery
    # still completes the full matrix.
    root = tempfile.mkdtemp(prefix="repro_b12k_")
    try:
        kill_lease = 1.0
        die_after = 0.05 if smoke else 0.15  # must land mid-drain
        procs = [
            mp.Process(target=_b12_worker,
                       args=(root, n_tasks, delay, "victim", kill_lease, die_after)),
            mp.Process(target=_b12_worker, args=(root, n_tasks, delay, "s1", kill_lease)),
            mp.Process(target=_b12_worker, args=(root, n_tasks, delay, "s2", kill_lease)),
        ]
        t0 = time.perf_counter()
        for p in procs:
            p.start()
        for p in procs:
            p.join(timeout=300)
        wall = time.perf_counter() - t0
        codes = sorted(p.exitcode for p in procs)
        matrix = {"parameters": {"i": expected}, "settings": {"delay": delay}}
        res = _b12_assemble(root).run_distributed(
            matrix, queue_dir=f"{root}/q", publish=False, lease_s=kill_lease
        )
        complete = sorted(r.value for r in res) == expected
        _row(
            f"B12_distributed_killrecovery_{n_tasks}tasks",
            wall * 1e6,
            f"exitcodes={codes} complete={complete} (lease recovery)",
        )
        assert complete, "kill-one-worker run did not complete the matrix"
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_roofline_summary() -> None:
    try:
        from repro.launch.report import load_results

        rows, skipped = load_results()
    except Exception as e:
        _row("B8_roofline", 0.0, f"no cached sweep ({e})")
        return
    sp = [v for v in rows if v.get("mesh") == "16x16" and v.get("roofline")]
    for v in sorted(sp, key=lambda v: (v["arch"], v["shape"])):
        r = v["roofline"]
        _row(
            f"B8_{v['arch']}_{v['shape']}",
            r["step_time_lower_bound"] * 1e6,
            f"bottleneck={r['bottleneck']} roofline_frac={r['roofline_fraction']:.3f}",
        )


def main(smoke: bool = False) -> None:
    print("name,us_per_call,derived")
    bench_matrix_expansion(smoke)
    if not smoke:
        # Forks worker processes, so it must run before anything imports
        # jax (B4 onward) or leaves thread pools behind (B2/B3): forking a
        # multithreaded XLA process is the documented deadlock case.
        bench_distributed()
    bench_parallel_speedup(smoke)
    bench_cache_speedup(smoke=smoke)
    bench_checkpoint_overhead(smoke=smoke)
    bench_failure_isolation()
    if smoke:
        bench_serve_smoke()
        bench_serve_prefix(smoke=True)
        bench_serve_spec(smoke=True)
        return
    bench_kernels()
    bench_train_sweep()
    bench_serve_throughput()
    bench_serve_paged()
    bench_serve_chunked()
    bench_serve_prefix()
    bench_serve_spec()
    bench_serve_sharded()
    bench_serve_layered()
    bench_roofline_summary()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="B1-B5 only, tiny sizes (CI end-to-end exercise of the experiment layer)",
    )
    ap.add_argument(
        "--distributed-smoke", action="store_true",
        help="tiny B12 only: 1/2-process file-queue drain + kill-recovery row",
    )
    ap.add_argument(
        "--sharded-smoke", action="store_true",
        help="tiny B15+B16 only: sharded vs 1-device stepping and the "
        "data-parallel layered core (needs forced host devices: "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
    )
    ap.add_argument(
        "--records-dir", default=None,
        help="where BENCH_<n>.json records land (default: benchmarks/records)",
    )
    ap.add_argument(
        "--no-records", action="store_true",
        help="print rows only, do not persist a BENCH_<n>.json record",
    )
    args = ap.parse_args()
    if args.distributed_smoke:
        print("name,us_per_call,derived")
        bench_distributed(smoke=True)
        mode = "distributed-smoke"
    elif args.sharded_smoke:
        print("name,us_per_call,derived")
        bench_serve_sharded(smoke=True)
        bench_serve_layered(smoke=True)
        mode = "sharded-smoke"
    else:
        main(smoke=args.smoke)
        mode = "smoke" if args.smoke else "full"
    if not args.no_records:
        path = write_records(mode, args.records_dir)
        if path:
            for w in diff_records(path, args.records_dir):
                print(w)
    if _FAILED:
        print(f"IDENTITY/WIN FAILURES: {','.join(_FAILED)}", file=sys.stderr)
        sys.exit(1)
