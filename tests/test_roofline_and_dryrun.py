"""Roofline machinery: the while-body-once cost_analysis calibration, the
loop-aware collective parser, the analytic cost model, and a real (small
mesh) lower+compile of train/serve steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES_BY_NAME, ShapeConfig
from repro.configs.registry import get_config
from repro.launch import costmodel as cm
from repro.launch import roofline as rf
from repro.launch.mesh import make_test_mesh
from repro.sharding.rules import ShardingCtx, get_profile


class TestCostAnalysisCalibration:
    def test_xla_counts_while_bodies_once(self):
        """The measured fact that justifies the analytic model: scan trip
        count does not change cost_analysis flops."""

        def make(n):
            def f(w, x):
                def body(c, _):
                    return jnp.tanh(c @ w), None

                c, _ = jax.lax.scan(body, x, None, length=n)
                return c.sum()

            return f

        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        flops = []
        for n in (2, 8):
            ca = jax.jit(make(n)).lower(w, x).compile().cost_analysis()
            ca = ca[0] if isinstance(ca, list) else ca
            flops.append(ca["flops"])
        assert flops[0] == flops[1]  # the undercount this framework corrects


SAMPLE_HLO = """\
HloModule test

%inner_body (arg: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %ar = f32[128,128]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %t = (s32[], f32[128,128]) tuple(%i, %ar)
}

%inner_cond (arg: (s32[], f32[128,128])) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%outer_body (arg: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %w = (s32[], f32[128,128]) while(%arg), condition=%inner_cond, body=%inner_body
  ROOT %t2 = (s32[], f32[128,128]) tuple(%j, %gte)
}

%outer_cond (arg: (s32[], f32[128,128])) -> pred[] {
  %c2 = s32[] constant(3)
  ROOT %lt2 = pred[] compare(%j, %c2), direction=LT
}

ENTRY %main (p: f32[128,128]) -> f32[128,128] {
  %ag = f32[256,128]{1,0} all-gather(%p), channel_id=2, replica_groups=[128,2]<=[256], dimensions={0}
  %w0 = (s32[], f32[128,128]) while(%init), condition=%outer_cond, body=%outer_body
  ROOT %r = f32[128,128] get-tuple-element(%w0), index=1
}
"""


class TestCollectiveParser:
    def test_nested_loop_multipliers(self):
        comps, entry = rf._split_computations(SAMPLE_HLO)
        assert entry == "main"
        mult = rf._comp_multipliers(comps, entry)
        assert mult["outer_body"] == 3.0
        assert mult["inner_body"] == 15.0  # 3 * 5

    def test_byte_accounting(self):
        stats = rf.parse_collectives(SAMPLE_HLO, 256)
        # all-gather at entry: result 256*128*4 bytes * (2-1)/2, once
        ag = 256 * 128 * 4 * (1 / 2)
        # all-reduce inside nested loops: result 128*128*4, group 16,
        # 2*(n-1)/n ring factor, 15 executions
        ar = 2 * 128 * 128 * 4 * (15 / 16) * 15
        assert stats.op_bytes["all-gather"] == pytest.approx(ag)
        assert stats.op_bytes["all-reduce"] == pytest.approx(ar)
        assert stats.unattributed_comps == 0

    def test_group_size_forms(self):
        assert rf._group_size("replica_groups=[16,16]<=[16,16]T(1,0)", 256) == 16
        assert rf._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}", 256) == 4
        assert rf._group_size("replica_groups={}", 256) == 256


class TestAnalyticCostModel:
    def test_train_flops_close_to_6nd(self):
        """For a dense arch, cell_flops should be ~ (4/3)*6*N*D with full
        remat (8*N*D) within attention/unembed slack."""
        cfg = get_config("qwen3-8b")
        shape = SHAPES_BY_NAME["train_4k"]
        from repro.launch.dryrun import active_param_count

        n = active_param_count(cfg)
        got = cm.cell_flops(cfg, shape)
        lower = 0.8 * 8 * n * shape.tokens  # remat factor 4 => 8ND
        upper = 2.0 * 8 * n * shape.tokens
        assert lower < got < upper, (got, 8 * n * shape.tokens)

    def test_decode_dominated_by_cache_bytes(self):
        cfg = get_config("qwen2.5-14b")
        shape = SHAPES_BY_NAME["decode_32k"]
        b = cm.cell_bytes_per_device(cfg, shape, 256)
        state = cm._decode_state_bytes(cfg, shape) / 256
        assert state * 2 < b < state * 2 + 4e9  # cache read+write dominates

    def test_moe_flops_scale_with_topk_not_experts(self):
        cfg = get_config("deepseek-v2-236b")
        shape = SHAPES_BY_NAME["train_4k"]
        fl = cm.cell_flops(cfg, shape)
        from dataclasses import replace

        cfg_bigger_pool = replace(cfg, moe=replace(cfg.moe, n_experts=320))
        fl2 = cm.cell_flops(cfg_bigger_pool, shape)
        assert abs(fl2 - fl) / fl < 0.02  # router-only delta

    def test_all_cells_have_positive_terms(self):
        from repro.configs.base import ALL_SHAPES, shape_applicable
        from repro.configs.registry import list_archs

        for arch in list_archs():
            cfg = get_config(arch)
            for shape in ALL_SHAPES:
                ok, _ = shape_applicable(cfg, shape)
                if not ok:
                    continue
                c = cm.analytic_cost(cfg, shape, 256)
                assert c.flops_per_device > 0, (arch, shape.name)
                assert c.bytes_per_device > 0, (arch, shape.name)


class TestSmallMeshLowering:
    """The dry-run machinery on a 1x1 mesh with reduced configs: proves the
    train/serve jits lower+compile with shardings end-to-end in-tests."""

    @pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v2-236b", "recurrentgemma-2b"])
    def test_train_step_lowers(self, arch):
        from repro.train.step import make_train_setup, make_train_step

        cfg = get_config(arch).reduced()
        shape = ShapeConfig("t", "train", seq_len=32, global_batch=2)
        mesh = make_test_mesh(1, 1)
        sctx = ShardingCtx(mesh=mesh, profile=get_profile("dp_tp"))
        with mesh:
            setup = make_train_setup(cfg, shape, sctx)
            fn = make_train_step(setup)
            compiled = (
                jax.jit(fn, donate_argnums=(0,))
                .lower(setup.abstract_state(), setup.abstract_batch())
                .compile()
            )
        assert compiled.memory_analysis().temp_size_in_bytes >= 0

    @pytest.mark.parametrize("arch", ["qwen3-8b", "xlstm-1.3b"])
    def test_decode_step_lowers(self, arch):
        from repro.serve.step import (
            decode_state_specs,
            make_decode_step,
            serve_param_specs,
            token_specs,
        )

        cfg = get_config(arch).reduced()
        shape = ShapeConfig("d", "decode", seq_len=64, global_batch=2)
        mesh = make_test_mesh(1, 1)
        sctx = ShardingCtx(mesh=mesh, profile=get_profile("decode_default"))
        with mesh:
            fn = make_decode_step(cfg, sctx)
            compiled = (
                jax.jit(fn, donate_argnums=(1,))
                .lower(
                    serve_param_specs(cfg, sctx),
                    decode_state_specs(cfg, shape, sctx),
                    token_specs(shape, sctx),
                )
                .compile()
            )
        assert compiled is not None
