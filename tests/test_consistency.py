"""Decode-vs-forward consistency: teacher-forcing a sequence through
prefill + step-by-step decode must reproduce the full forward's logits.
This is the strongest functional check of the KV caches / ring buffers /
recurrent states (it catches off-by-one positions, stale slots, bad masks).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models import blocks as blk
from repro.models import lm
from repro.models.layers import rmsnorm, unembed_weight, logits_for_positions
from repro.models.schema import init_params
from repro.sharding.rules import ShardingCtx

# dense GQA, hybrid window+recurrent, pure recurrent, MLA+MoE
CASES = ["llama3.2-3b", "recurrentgemma-2b", "xlstm-1.3b", "deepseek-v2-236b"]


def full_forward_logits(params, cfg, tokens, sctx):
    """All-position logits from a single training-style forward."""
    x, positions, enc_out = lm._embed_inputs(params, cfg, {"tokens": tokens}, sctx)
    x, _, _ = blk.apply_stack(
        params["stack"], cfg, x, mode="train", positions=positions,
        mask_kind="causal", sctx=sctx, enc_out=enc_out,
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_for_positions(x, unembed_weight(params["embed"], cfg), cfg, sctx)


@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.prefix_len or cfg.enc_dec:
        pytest.skip("prefix/enc-dec covered separately")
    sctx = ShardingCtx.null()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    B, S = 2, 24
    prompt = 8
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    ref = full_forward_logits(params, cfg, tokens, sctx)  # (B, S, V)

    # prefill on the prompt, then teacher-force decode the rest
    logits, states = jax.jit(lambda p, b: lm.prefill(p, cfg, b, sctx))(
        params, {"tokens": tokens[:, :prompt]}
    )
    decode = jax.jit(lambda p, s, t: lm.decode_step(p, cfg, s, t, sctx))

    # grow caches to S slots using the serving engine's graft
    from repro.serve.engine import Engine, ServeConfig

    eng = Engine(cfg, params, sctx, ServeConfig(cache_len=S))
    states = eng._grow_states(states, prompt, B)

    outs = [logits[:, 0]]
    for t in range(prompt, S):
        step_logits, states = decode(params, states, tokens[:, t : t + 1])
        outs.append(step_logits[:, 0])

    # prefill's last logit must match forward at position prompt-1;
    # decode at position t must match forward at position t.
    atol = 2e-2  # fp32 compute but different contraction orders
    assert jnp.allclose(outs[0], ref[:, prompt - 1], atol=atol), arch
    for i, t in enumerate(range(prompt, S)):
        got, want = outs[1 + i], ref[:, t]
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < atol, f"{arch}: pos {t} max err {err}"


def test_window_ring_buffer_drops_old_context():
    """With a ring buffer of W slots, decode must only see the last W tokens."""
    cfg = get_config("recurrentgemma-2b").reduced()
    sctx = ShardingCtx.null()
    params = init_params(lm.model_schema(cfg), jax.random.PRNGKey(0))
    B, S = 1, 48  # > window (32)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    ref = full_forward_logits(params, cfg, tokens, sctx)

    from repro.serve.engine import Engine, ServeConfig

    eng = Engine(cfg, params, sctx, ServeConfig(cache_len=S))
    logits, states = jax.jit(lambda p, b: lm.prefill(p, cfg, b, sctx))(
        params, {"tokens": tokens[:, :40]}
    )
    states = eng._grow_states(states, 40, B)
    decode = jax.jit(lambda p, s, t: lm.decode_step(p, cfg, s, t, sctx))
    for t in range(40, S):
        step_logits, states = decode(params, states, tokens[:, t : t + 1])
        err = float(jnp.max(jnp.abs(step_logits[:, 0] - ref[:, t])))
        assert err < 2e-2, f"pos {t}: {err}"
