"""Runner, cache, file-queue, task checkpoints, notifications: fault-injection."""
import os
import threading
import time

import pytest

from repro.core import (
    ConfigMatrix,
    Context,
    FileQueue,
    FsCache,
    Memento,
    MemoryCache,
    RecordingProvider,
    Runner,
    RunnerConfig,
    TaskCheckpointStore,
    drain,
)


def _matrix(n=6):
    return ConfigMatrix.from_dict({"parameters": {"i": list(range(n))}})


def square(ctx: Context):
    return ctx["i"] ** 2


_fail_registry: dict[str, int] = {}


def flaky(ctx: Context):
    """Fails on first attempt for odd i, then succeeds."""
    key = ctx.key
    _fail_registry[key] = _fail_registry.get(key, 0) + 1
    if ctx["i"] % 2 == 1 and _fail_registry[key] == 1:
        raise RuntimeError(f"transient failure i={ctx['i']}")
    return ctx["i"]


def always_fails(ctx: Context):
    raise ValueError(f"broken task i={ctx['i']}")


def slow_then_value(ctx: Context):
    time.sleep(2.0 if ctx["i"] == 0 else 0.01)
    return ctx["i"]


class TestRunner:
    def test_parallel_ok(self):
        r = Runner(square, config=RunnerConfig(max_workers=4, enable_speculation=False))
        results = r.run(_matrix().task_list())
        assert [res.value for res in results] == [i * i for i in range(6)]
        assert all(res.ok for res in results)

    def test_failure_isolation_and_traceback(self):
        def mixed(ctx):
            if ctx["i"] == 3:
                raise ValueError("boom")
            return ctx["i"]

        mixed.__module__ = TestRunner.__module__
        r = Runner(square, config=RunnerConfig(max_workers=2, retries=0, enable_speculation=False))
        r.func = mixed
        results = r.run(_matrix().task_list())
        failed = [x for x in results if not x.ok]
        assert len(failed) == 1
        assert failed[0].spec.params["i"] == 3
        assert "boom" in failed[0].error
        assert "ValueError" in failed[0].traceback_str
        assert sum(1 for x in results if x.ok) == 5

    def test_retry_recovers_transient(self):
        _fail_registry.clear()
        prov = RecordingProvider()
        r = Runner(flaky, provider=prov, config=RunnerConfig(max_workers=2, retries=2, enable_speculation=False))
        results = r.run(_matrix(4).task_list())
        assert all(res.ok for res in results)
        assert "task_retry" in prov.kinds()

    def test_retries_exhausted(self):
        r = Runner(always_fails, config=RunnerConfig(max_workers=2, retries=1, enable_speculation=False))
        results = r.run(_matrix(2).task_list())
        assert all(not res.ok for res in results)
        assert all(res.attempts == 2 for res in results)

    def test_hard_timeout(self):
        def hang(ctx):
            if ctx["i"] == 0:
                time.sleep(30)
            return ctx["i"]

        r = Runner(
            hang,
            config=RunnerConfig(
                max_workers=3, retries=0, task_timeout_s=0.5, enable_speculation=False
            ),
        )
        t0 = time.time()
        results = r.run(_matrix(3).task_list())
        assert time.time() - t0 < 10
        by_i = {res.spec.params["i"]: res for res in results}
        assert by_i[0].status == "timeout"
        assert by_i[1].ok and by_i[2].ok

    def test_straggler_speculation(self):
        r = Runner(
            slow_then_value,
            config=RunnerConfig(
                max_workers=4,
                retries=0,
                enable_speculation=True,
                straggler_min_s=0.3,
                straggler_factor=2.0,
            ),
        )
        prov = RecordingProvider()
        r.provider = prov
        results = r.run(_matrix(6).task_list())
        assert all(res.ok for res in results)
        assert "straggler_respawned" in prov.kinds()

    def test_cache_hits_skip_execution(self, tmp_path):
        calls = []

        def counting(ctx):
            calls.append(ctx["i"])
            return ctx["i"]

        counting.__module__ = TestRunner.__module__
        cache = FsCache(tmp_path / "cache")
        cfg = RunnerConfig(max_workers=2, enable_speculation=False)
        Runner(counting, cache=cache, config=cfg).run(_matrix(4).task_list())
        assert sorted(calls) == [0, 1, 2, 3]
        calls.clear()
        results = Runner(counting, cache=cache, config=cfg).run(_matrix(4).task_list())
        assert calls == []
        assert all(res.status == "cached" for res in results)

    def test_force_ignores_cache(self, tmp_path):
        cache = FsCache(tmp_path / "cache")
        r = Runner(square, cache=cache, config=RunnerConfig(max_workers=2, enable_speculation=False))
        r.run(_matrix(2).task_list())
        results = r.run(_matrix(2).task_list(), force=True)
        assert all(res.status == "ok" for res in results)


class TestFsCache:
    def test_roundtrip_and_manifest(self, tmp_path):
        c = FsCache(tmp_path)
        c.put("k1", {"x": [1, 2, 3]}, manifest={"note": "hi"})
        e = c.get("k1")
        assert e.value == {"x": [1, 2, 3]}
        assert e.manifest["note"] == "hi"
        assert e.manifest["payload_sha256"]

    def test_corruption_quarantined(self, tmp_path):
        c = FsCache(tmp_path)
        c.put("k1", [1, 2, 3])
        payload = tmp_path / "k1" / "result.pkl"
        payload.write_bytes(b"garbage")
        assert c.get("k1") is None  # quarantined, not returned
        assert not (tmp_path / "k1").exists()
        assert list((tmp_path / "_quarantine").iterdir())

    def test_overwrite_idempotent(self, tmp_path):
        c = FsCache(tmp_path)
        c.put("k", 1)
        c.put("k", 2)
        assert c.get("k").value == 2
        assert len(c) == 1


class TestTaskCheckpoints:
    def test_versioned_roundtrip(self, tmp_path):
        s = TaskCheckpointStore(tmp_path, "task1")
        assert not s.exists()
        assert s.save({"step": 1}) == 1
        assert s.save({"step": 2}) == 2
        assert s.restore() == {"step": 2}
        # keeps only two most recent
        s.save({"step": 3})
        files = sorted(p.name for p in (tmp_path / "task1").glob("ckpt-*.pkl"))
        assert files == ["ckpt-2.pkl", "ckpt-3.pkl"]

    def test_context_checkpoint_api(self, tmp_path):
        from repro.core.matrix import TaskSpec

        spec = TaskSpec(index=0, params={"i": 1}, settings={}, key="deadbeef")
        ctx = Context(spec=spec, checkpoints=TaskCheckpointStore(tmp_path, spec.key))
        assert not ctx.checkpoint_exists()
        assert ctx.restore(default={"fresh": True}) == {"fresh": True}
        ctx.checkpoint({"progress": 5})
        assert ctx.checkpoint_exists()
        assert ctx.restore()["progress"] == 5


def queue_work(ctx: Context):
    return ctx["i"] * 10


class TestFileQueue:
    def test_claim_exclusivity(self, tmp_path):
        q1 = FileQueue(tmp_path, lease_s=60, owner="host1")
        q2 = FileQueue(tmp_path, lease_s=60, owner="host2")
        specs = _matrix(1).task_list()
        q1.publish(specs)
        key = specs[0].key
        assert q1.try_claim(key)
        assert not q2.try_claim(key)
        q1.release(key)
        assert q2.try_claim(key)

    def test_expired_lease_reclaimed(self, tmp_path):
        q1 = FileQueue(tmp_path, lease_s=0.1, owner="dead-host")
        q2 = FileQueue(tmp_path, lease_s=60, owner="live-host")
        specs = _matrix(1).task_list()
        q1.publish(specs)
        key = specs[0].key
        assert q1.try_claim(key)
        time.sleep(0.2)
        assert q2.try_claim(key)  # broke the dead lease

    def test_two_hosts_drain_disjointly(self, tmp_path):
        specs = _matrix(12).task_list()
        by_key = {s.key: s for s in specs}
        q = FileQueue(tmp_path, lease_s=60, owner="seed")
        q.publish(specs)
        done: dict[str, list[str]] = {"h1": [], "h2": []}

        def host(name):
            qh = FileQueue(tmp_path, lease_s=60, owner=name)
            res = drain(
                qh, by_key, lambda spec, beat: spec.params["i"], idle_rounds=2, idle_sleep_s=0.05
            )
            done[name] = list(res)

        t1 = threading.Thread(target=host, args=("h1",))
        t2 = threading.Thread(target=host, args=("h2",))
        t1.start(); t2.start(); t1.join(); t2.join()
        assert set(done["h1"]) | set(done["h2"]) == set(by_key)
        assert not (set(done["h1"]) & set(done["h2"]))

    def test_memento_run_distributed(self, tmp_path):
        eng = Memento(queue_work, workdir=tmp_path / "w")
        res = eng.run_distributed(
            {"parameters": {"i": [1, 2, 3]}}, queue_dir=tmp_path / "q"
        )
        assert sorted(r.value for r in res if r.ok) == [10, 20, 30]


class TestMementoFacade:
    def test_paper_snippet_shape(self, tmp_path):
        import repro.core as memento

        notif = memento.RecordingProvider()
        results = memento.Memento(square, notif, workdir=tmp_path).run(
            {"parameters": {"i": [1, 2]}, "settings": {}, "exclude": []}
        )
        assert results.values == [1, 4]
        assert "run_finished" in notif.kinds()

    def test_dry_run_executes_nothing(self):
        hits = []

        def f(ctx):
            hits.append(1)

        res = Memento(f).run({"parameters": {"i": [1, 2, 3]}}, dry_run=True)
        assert hits == []
        assert len(res) == 3
        assert all(r.status == "skipped" for r in res)

    def test_value_by_params(self):
        res = Memento(square).run({"parameters": {"i": [1, 2, 3]}})
        assert res.value_by_params(i=3) == 9
